"""SpMV — the paper's flagship kernel, three ways.

Run:  PYTHONPATH=src python examples/spmv_dataflow.py

1. The HLS view: trace the CSR inner loop, let Algorithm 1 build the
   pipeline (index fetch → value fetch → x gather → FMA), simulate it on
   the Zynq memory model against the fused engine (Fig. 5, one kernel).
2. The TPU view: the same decoupling as a Pallas BSR kernel — scalar-
   prefetched block-column ids drive the data-dependent x-tile DMA
   (interpret mode on CPU), validated against the dense product.
"""

import jax.numpy as jnp
import numpy as np

from repro.dataflow import compile as dataflow_compile
from repro.core.simulator import MemAccess, acp
from repro.kernels import csr_to_bsr, spmv


def main() -> None:
    rng = np.random.default_rng(0)

    # ---- 1. HLS view -------------------------------------------------------
    dim, density = 512, 0.25
    dense = ((rng.random((dim, dim)) < density)
             * rng.normal(size=(dim, dim))).astype(np.float32)
    vals_np = dense[dense != 0]
    cols_np = np.nonzero(dense)[1].astype(np.int32)
    vals, cols = jnp.asarray(vals_np), jnp.asarray(cols_np)
    x = jnp.asarray(rng.normal(size=dim).astype(np.float32))

    def inner_loop(acc, j):
        c = cols[j]
        v = vals[j]
        return acc + v * x[c]

    # the driver in loop mode: carry back-edges recreate the cyclic CDFG,
    # Algorithm 1 builds index fetch -> value fetch -> x gather -> FMA
    compiled = dataflow_compile(inner_loop, jnp.float32(0), jnp.int32(0),
                                loop=True)
    print(compiled.report())

    n = min(len(vals_np), 20_000)
    traces = [MemAccess("cols", np.arange(n) * 4),
              MemAccess("vals", np.arange(n) * 4 + (1 << 24)),
              MemAccess("x", cols_np[:n].astype(np.int64) * 4 + (1 << 25))]
    report = compiled.simulate(n_iters=n, traces=traces, mem=acp(),
                               fifo_depth=32)
    df, cv = report.dataflow, report.conventional
    print(f"\nZynq model, {n} nnz: conventional {cv.cycles_per_iter:.1f} "
          f"cyc/nnz vs dataflow {df.cycles_per_iter:.1f} cyc/nnz "
          f"→ {report.speedup:.1f}x\n")

    # ---- 2. TPU view -------------------------------------------------------
    indptr = np.zeros(dim + 1, np.int64)
    indptr[1:] = np.cumsum((dense != 0).sum(1))
    bvals, bcols = csr_to_bsr(indptr, cols_np, vals_np, (dim, dim),
                              bm=8, bk=128)
    y = spmv(jnp.asarray(bvals), jnp.asarray(bcols), x)
    np.testing.assert_allclose(np.asarray(y)[:dim], dense @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)
    print(f"Pallas BSR SpMV (scalar-prefetch gather): OK — "
          f"{bvals.shape[0]}x{bvals.shape[1]} blocks of "
          f"{bvals.shape[2]}x{bvals.shape[3]}")


if __name__ == "__main__":
    main()

"""Quickstart: the dataflow architectural template in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

1. Decorate an ordinary JAX function with ``dataflow_jit`` — the compiler
   driver traces it into a CDFG, runs Algorithm 1 partitioning, decouples
   access from execute, and schedules the stage pipeline.
2. Inspect the pass pipeline's product with ``.report()``.
3. Execute through every registered backend — ``sequential`` (stage replay),
   ``emulated`` (tick-exact systolic schedule), ``systolic`` (one stage per
   device via shard_map), ``xla`` (the fused baseline) — all bit-compatible
   with the direct call.
4. Stream microbatches through the pipeline (the paper's Fig. 2 schedule).
5. Simulate the Zynq-like memory system to see WHY decoupling wins (Fig. 5).
"""

import os

# one host device per pipeline stage for the systolic backend (must be set
# before jax initializes)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.dataflow import dataflow_jit, execute_backends  # noqa: E402


# -- 1. a kernel with the paper's pathology: a data-dependent gather
#       feeding long-latency floating-point compute
@dataflow_jit(stream_argnums=(1,))
def kernel(table, idx, w):
    g = table[idx]             # irregular load (cache-miss prone)
    h = g * w                  # long-latency fp multiply
    return jnp.tanh(h) + 1.0   # more long-latency compute


def main() -> None:
    table = jnp.arange(1024, dtype=jnp.float32)
    idx = jnp.asarray([3, 997, 41, 512, 7, 800, 64, 2])
    w = jnp.float32(1.5)

    # -- 2. the compiled artifact: CDFG -> Algorithm 1 -> stages -> schedule
    compiled = kernel.lower(table, idx, w)
    print(compiled.cdfg.summary(), "\n")
    print(compiled.report(), "\n")

    # -- 3. every execution backend == the direct (untransformed) call
    ref = np.asarray(kernel.__wrapped__(table, idx, w))
    for name in execute_backends():
        if name not in compiled.backends():
            print(f"backend {name:<10}: unavailable "
                  f"({len(jax.devices())} devices)")
            continue
        got = np.asarray(kernel(table, idx, w, backend=name))
        np.testing.assert_allclose(got, ref, rtol=1e-6)
        print(f"backend {name:<10}: OK (== direct call)")
    print()

    # -- 4. stream microbatches through the systolic pipeline
    T = 6
    idx_stream = jnp.stack([(idx + t) % 1024 for t in range(T)])
    outs = compiled.stream(table, idx_stream, w)
    ref_stream = np.stack(
        [np.asarray(kernel.__wrapped__(table, idx_stream[t], w))
         for t in range(T)])
    np.testing.assert_allclose(np.asarray(outs), ref_stream, rtol=1e-6)
    print(f"systolic stream ({compiled.num_stages} stages, "
          f"{T} microbatches): OK\n")

    # -- 5. why it wins: the Fig. 2/5 schedule report
    report = compiled.simulate(n_iters=3000, microbatches=6)
    print(report.summary())


if __name__ == "__main__":
    main()

"""Quickstart: the dataflow architectural template in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

1. Write an ordinary JAX function with an irregular memory access.
2. Trace it into a CDFG; watch Algorithm 1 cut stages at the memory op and
   at the long-latency multiply (the paper's Fig. 1).
3. Execute the decoupled program — semantically identical to the original.
4. Stream microbatches through the systolic pipeline executor.
5. Simulate the paper's Fig. 2 schedule to see WHY decoupling wins.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CDFG, SystolicPipeline, decouple, partition_cdfg,
                        run_stages_sequential)
from repro.core.simulator import (MemAccess, SimStage, acp,
                                  simulate_conventional, simulate_dataflow)


def main() -> None:
    # -- 1. a kernel with the paper's pathology: a data-dependent gather
    #       feeding long-latency floating-point compute
    def kernel(table, idx, w):
        g = table[idx]             # irregular load (cache-miss prone)
        h = g * w                  # long-latency fp multiply
        return jnp.tanh(h) + 1.0   # more long-latency compute

    table = jnp.arange(1024, dtype=jnp.float32)
    idx = jnp.asarray([3, 997, 41, 512, 7, 800, 64, 2])
    w = jnp.float32(1.5)

    # -- 2. CDFG → Algorithm 1
    cdfg = CDFG.from_function(kernel, table, idx, w)
    print(cdfg.summary(), "\n")
    part = partition_cdfg(cdfg)
    print(part.summary(), "\n")

    # -- 3. decoupled execution == direct execution
    prog = decouple(part)
    out = run_stages_sequential(prog, table, idx, w)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray(kernel(table, idx, w)))
    print("decoupled == direct: OK\n")

    # -- 4. stream microbatches through the systolic pipeline
    T = 6
    idx_stream = jnp.stack([(idx + t) % 1024 for t in range(T)])
    pipe = SystolicPipeline(prog, stream_argnums=(1,))
    outs = pipe.run_emulated(table, idx_stream, w)
    ref = jnp.stack([kernel(table, idx_stream[t], w) for t in range(T)])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               rtol=1e-6)
    print(f"systolic pipeline ({pipe.num_stages} stages, "
          f"{T} microbatches): OK\n")

    # -- 5. why it wins: Fig. 2 in numbers
    n = 3000
    rng = np.random.default_rng(0)
    stages = [
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", rng.integers(0, 4 << 20, n) * 4)]),
        SimStage("fma", ii=6, latency=8),
    ]
    df = simulate_dataflow(stages, acp(), n)
    cv = simulate_conventional(stages, acp(), n)
    print(f"simulated {n} iterations on the Zynq-like memory model:")
    print(f"  conventional (fused) : {cv.cycles_per_iter:6.1f} cycles/iter")
    print(f"  dataflow  (decoupled): {df.cycles_per_iter:6.1f} cycles/iter")
    print(f"  speedup              : {cv.cycles / df.cycles:6.2f}x")


if __name__ == "__main__":
    main()

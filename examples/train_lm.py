"""End-to-end training driver example: a ~100M-parameter LM for a few
hundred steps on the synthetic pipeline, with checkpointing and resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

This uses the same train_loop as the production launcher — prefetching
data FIFO (the template applied to the host boundary), jitted train step,
async atomic checkpoints, and deterministic resume.  On CPU it runs a
width-reduced SmolLM-family config (~2M params) by default; pass --full
for the real smollm-135m (slow on CPU, exact same code path).
"""

import argparse
import tempfile

from repro.configs import load_config, reduced
from repro.launch.train import train_loop


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--full", action="store_true",
                   help="train the real smollm-135m config (CPU: slow)")
    p.add_argument("--ckpt-dir", default=None)
    args = p.parse_args()

    cfg = load_config("smollm-135m")
    if not args.full:
        cfg = reduced(cfg, d_model=128, max_repeats=4)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")

    import logging
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")
    out = train_loop(cfg, steps=args.steps, batch_size=8, seq_len=128,
                     ckpt_dir=ckpt_dir, ckpt_every=50, lr=1e-3)
    first = sum(out["losses"][:10]) / 10
    last = sum(out["losses"][-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({(first - last) / first * 100:.1f}% reduction)")
    print(f"checkpoints in {ckpt_dir}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()

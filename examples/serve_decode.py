"""Batched serving example: prefill + decode with KV cache, int8 option.

Run:  PYTHONPATH=src python examples/serve_decode.py

Shows the serving path end-to-end on a reduced Qwen2.5 config: batched
prefill builds the cache, decode streams tokens; the int8 KV-cache §Perf
feature is toggled to show identical greedy outputs at half the cache
bytes.
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import load_config, reduced
from repro.launch.serve import BatchedServer, Request
from repro.models import init_params


def main() -> None:
    cfg = reduced(load_config("qwen2.5-14b"), max_repeats=2)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)

    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=(12,))
                    .astype(np.int32), 16) for i in range(4)]

    for kv_dtype in ("bf16", "int8"):
        c = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
        server = BatchedServer(c, params, max_len=64)
        t0 = time.time()
        results = server.serve(reqs)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in results)
        print(f"kv={kv_dtype:5s}: {toks} tokens in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s) "
              f"first request: {results[0].tokens[:6]}")


if __name__ == "__main__":
    main()

"""Resumable dry-run matrix driver: runs every (arch × shape × mesh) cell,
skipping cells whose artifact already exists in the output directory.

Run:  PYTHONPATH=src python benchmarks/dryrun_matrix.py [--out DIR]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="experiments/dryrun")
    args = p.parse_args()

    from repro.configs.base import ARCH_IDS, SHAPES
    from repro.launch.dryrun import run_cell

    n_done = n_run = 0
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for multi_pod in (False, True):
                mesh_name = "2x16x16" if multi_pod else "16x16"
                cell = f"{arch}__{shape}__{mesh_name}"
                path = os.path.join(args.out, f"{cell}.json")
                if os.path.exists(path):
                    n_done += 1
                    continue
                run_cell(arch, shape, multi_pod=multi_pod,
                         out_dir=args.out)
                n_run += 1
    print(f"matrix complete: {n_run} ran, {n_done} already present")


if __name__ == "__main__":
    main()

"""Roofline table (EXPERIMENTS.md §Roofline).

Primary terms come from the analytic cost model
(repro/runtime/cost_model.py) — XLA's cost_analysis counts scan bodies
once, not × trip-count, so HLO totals undercount layer-scanned models by
~num_layers.  The dry-run artifacts still provide: compile proof,
memory_analysis, the collective op census (kinds/counts from the real
HLO), and per-partition HLO numbers as a structural cross-check.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES, cell_is_applicable, load_config
from repro.runtime.cost_model import cost_for_cell


def load_records(dirpath: str = "experiments/dryrun",
                 include_variants: bool = False) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("variant") and not include_variants:
            continue  # §Perf variants are reported in EXPERIMENTS.md §Perf
        recs.append(r)
    return recs


def fmt_seconds(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x * 1e9:.1f}ns"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def analytic_row(arch: str, shape_name: str, n_pods: int = 1) -> dict:
    cfg = load_config(arch)
    shape = SHAPES[shape_name]
    if not cell_is_applicable(cfg, shape):
        return {"status": "skip"}
    c = cost_for_cell(cfg, shape, n_pods=n_pods)
    r = c.roofline()
    # MFU-style fraction: useful model flops vs time lower bound
    mult = 6 if shape.kind == "train" else 2
    N = (cfg.active_param_count() if cfg.moe is not None
         else cfg.param_count())
    toks = shape.global_batch * (1 if shape.kind == "decode"
                                 else shape.seq_len)
    chips = 256 * n_pods
    model_flops_chip = mult * N * toks / chips
    mfu_bound = model_flops_chip / 197e12 / r["bound_s"]
    return {"status": "ok", "cost": c, "roofline": r,
            "mfu_at_bound": mfu_bound}


def table(recs: list[dict], mesh: str = "16x16") -> str:
    n_pods = 2 if mesh == "2x16x16" else 1
    lines = [
        "| arch | shape | t_compute | t_memory | t_coll | dominant | "
        "roofline frac | HLO coll ops | fits HBM |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"SKIP (full-attention @500k) | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        a = analytic_row(r["arch"], r["shape"], n_pods)
        rf = a["roofline"]
        coll_counts = r.get("coll", {}).get("count", {})
        coll_str = ",".join(f"{k.split('-')[0][:2]}{v}"
                            for k, v in coll_counts.items() if v)
        fit = r.get("fit", {})
        fits = fit.get("fits_hbm", "?")
        pods = fit.get("pods_needed")
        fitstr = ("yes" if fits else (f"needs {pods} pods" if pods
                                      else "no"))
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_seconds(rf['t_compute_s'])} "
            f"| {fmt_seconds(rf['t_memory_s'])} "
            f"| {fmt_seconds(rf['t_collective_s'])} "
            f"| {rf['dominant']} "
            f"| {a['mfu_at_bound']:.2f} "
            f"| {coll_str or '—'} "
            f"| {fitstr} |")
    return "\n".join(lines)


def summarize(recs: list[dict]) -> dict:
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]
    rows = {}
    for r in ok:
        a = analytic_row(r["arch"], r["shape"], 1)
        rows[(r["arch"], r["shape"])] = a
    by_dom: dict[str, int] = {}
    for a in rows.values():
        d = a["roofline"]["dominant"]
        by_dom[d] = by_dom.get(d, 0) + 1
    worst = sorted(rows.items(), key=lambda kv: kv[1]["mfu_at_bound"])[:5]
    most_coll = sorted(
        rows.items(),
        key=lambda kv: -(kv[1]["roofline"]["t_collective_s"]
                         / (kv[1]["roofline"]["bound_s"] + 1e-12)))[:5]
    return {
        "cells_ok": len(ok),
        "dominant_histogram": by_dom,
        "worst_roofline_fraction": [
            (a, s, round(v["mfu_at_bound"], 3)) for (a, s), v in worst],
        "most_collective_bound": [(a, s) for (a, s), _ in most_coll],
    }


def main() -> dict:
    recs = load_records()
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first")
        return {}
    print(table(recs, "16x16"))
    s = summarize(recs)
    print("\nsummary:", json.dumps(s, indent=1))
    return {"summary": s}


if __name__ == "__main__":
    main()

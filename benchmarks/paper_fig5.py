"""Fig. 5 reproduction: conventional vs dataflow accelerators vs ARM core.

Pipeline per kernel:
  1. trace the loop body → cyclic CDFG (carry back-edges),
  2. Algorithm 1 partition (the *real* partitioner, not a hand decomposition),
  3. derive SimStages: II/latency from the partition, memory-SCC stages
     detected automatically (the DFS pathology), traces attached to memory
     stages in pipeline order,
  4. simulate the three machines over four memory configs (ACP, ACP+64KB,
     HP, HP+64KB) at the **full Table-I iteration counts** — the vectorized
     simulator streams even Floyd–Warshall's 1024^3 iterations chunk by
     chunk, so no steady-state extrapolation is involved (``--quick``
     restores the old extrapolated small-window mode for development).

Checked claims (§V-A):
  * conventional accelerators run below the ARM baseline;
  * dataflow ≫ conventional (paper: 3.3–9.1×, avg 5.6× best-config);
  * caches help conventional more than dataflow (−45.4 % vs −18.7 %);
  * HP (uncached) degrades conventional vs ACP (~40 %);
  * DFS shows no meaningful dataflow gain (memory SCC).

The grid is planned so cells sharing work run together: per kernel, ONE
task simulates the dataflow machine on all four memory configs at once
(windows, burst masks, and each cache geometry resolved a single time —
see ``simulate_dataflow_many``), one task covers the conventional engine
on all four, and one the processor baseline.  Tasks are farmed longest-
first to a small process pool (``--jobs``), and resolved traces are
memoized on disk (``experiments/.rescache``) so repeated runs and the
sweep harness share work; ``--no-rescache`` forces cold resolution.
The PR 2 layout re-resolved every (kernel × machine × memory) cell from
scratch — ~1.5 h on 2 cores for this grid; the shared-resolution planner
plus the vectorized N-way LRU and the fast-path wavefront bring full
regeneration down to minutes (recorded in ``BENCH_sim.json``).
"""

from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import time

import numpy as np

from repro.core.simulator import (simulate_conventional_many,
                                  simulate_dataflow_many,
                                  simulate_processor,
                                  standard_memory_models)
from repro.dataflow import compile as dataflow_compile, fused_stage
from .paper_kernels import ALL_KERNELS, PaperKernel

MEM_NAMES = ("ACP", "ACP+64KB", "HP", "HP+64KB")
SPMV_SCALE = 0.125  # correctness-data scale; traces are full-size anyway
#: The template's FIFO sizing rule: depth must cover the latency a channel
#: has to hide — worst access latency plus stage latency, with margin
#: (§III-B2).  Shallower FIFOs (< ~80 here) make occasional DRAM-latency
#: spikes eat the producer's lead permanently: every spike then stalls
#: the pipeline and the whole run degrades to lockstep backpressure.
FIFO_DEPTH = 256
MAX_OUTSTANDING = 16  # the paper's "multiple outstanding requests"

#: Measured PR 2 baseline for full regeneration of this grid (per-cell
#: timings extrapolated to Table-I iteration counts on the CI container;
#: ROADMAP recorded "~1.5 h on 2 cores" for the same run).
PR2_BASELINE_CPU_S = 10594.0


def _dataflow_mems() -> dict:
    mems = {}
    for mn, mk in standard_memory_models().items():
        m = mk()
        m.max_outstanding = MAX_OUTSTANDING
        mems[mn] = m
    return mems


def build_stages(k: PaperKernel, *, full: bool = True):
    """(dataflow stages, conventional stage) from the compiler driver.

    The driver traces the loop body in loop mode (carry back-edges),
    partitions with Algorithm 1, and classifies memory-in-SCC stages (the
    DFS pathology); traces are attached positionally to memory ops in
    pipeline-stage order."""
    compiled = dataflow_compile(
        k.loop_body, k.carry_example, *k.body_args,
        loop=True,
        nonaliasing_carries=getattr(k, "nonaliasing_carries", ()))
    del full  # --quick truncates the iteration count, not the traces:
    # both modes attach the full-scale windowed traces, so a --quick run
    # is an exact *prefix* of the full run and the v3 rescache serves it
    # from any full-scale artifact with zero cold resolution
    traces = k.full_traces
    df_stages = compiled.sim_stages(traces=list(traces.values()))
    return df_stages, [fused_stage(df_stages)]


def _make_kernel(kname: str) -> PaperKernel:
    mk = ALL_KERNELS[kname]
    return mk(SPMV_SCALE) if kname == "spmv" else mk()


def run_kernel(k: PaperKernel, *, full: bool = False) -> dict:
    """Single-kernel, in-process version of the grid (tests / notebooks).

    ``full=False`` simulates the small window and extrapolates (the
    pre-sweep behaviour); ``full=True`` simulates all Table-I iterations.
    """
    n = k.n_iters_full if full else k.n_iters_sim
    traces = k.full_traces
    df_stages, conv_stages = build_stages(k, full=full)
    base = simulate_processor(k.instrs_per_iter, list(traces.values()), n)
    t_base = base.runtime_s if full else base.scaled_runtime(k.n_iters_full)
    out: dict = {"kernel": k.name,
                 "stages": len(df_stages),
                 "n_iters_simulated": n,
                 "n_iters_full": k.n_iters_full,
                 "fully_simulated": bool(full),
                 "baseline_s": t_base}
    dfs = simulate_dataflow_many(df_stages, _dataflow_mems(), n,
                                 fifo_depths=(FIFO_DEPTH,),
                                 collect_stalls=False)
    cvs = simulate_conventional_many(
        conv_stages, {mn: mk() for mn, mk in
                      standard_memory_models().items()}, n)
    for name in MEM_NAMES:
        df = dfs[(name, FIFO_DEPTH)]
        cv = cvs[name]
        t_df = df.runtime_s if full else df.scaled_runtime(k.n_iters_full)
        t_cv = cv.runtime_s if full else cv.scaled_runtime(k.n_iters_full)
        out[name] = {
            "dataflow_s": t_df,
            "conventional_s": t_cv,
            "dataflow_vs_baseline": t_base / t_df,
            "conventional_vs_baseline": t_base / t_cv,
            "dataflow_vs_conventional": t_cv / t_df,
        }
    return out


def _sim_task(task: tuple) -> tuple:
    """One (kernel, machine) group: all four memory configs resolved in a
    single shared pass — a top-level function so a spawn-based process
    pool can run the grid.  ``workers > 1`` additionally shards the
    dataflow group's resolution over the chunk-graph executor."""
    kname, what, full, workers, server = task
    t0 = time.perf_counter()
    k = _make_kernel(kname)
    n = k.n_iters_full if full else k.n_iters_sim
    traces = k.full_traces
    if what == "processor":
        r = {"": simulate_processor(k.instrs_per_iter,
                                    list(traces.values()), n)}
    elif what == "dataflow":
        df_stages, _ = build_stages(k, full=full)
        grid = simulate_dataflow_many(df_stages, _dataflow_mems(), n,
                                      fifo_depths=(FIFO_DEPTH,),
                                      collect_stalls=False,
                                      workers=workers, server=server)
        r = {mn: grid[(mn, FIFO_DEPTH)] for mn in MEM_NAMES}
    else:
        _, conv_stages = build_stages(k, full=full)
        r = simulate_conventional_many(
            conv_stages, {mn: mk() for mn, mk in
                          standard_memory_models().items()}, n)
    return kname, what, r, time.perf_counter() - t0


#: Rough relative cost of a machine group, for longest-first scheduling.
_MACHINE_WEIGHT = {"dataflow": 3.0, "conventional": 1.2, "processor": 1.0}


def run_all(*, full: bool = True, jobs: int | None = None,
            kernels: tuple[str, ...] | None = None,
            workers: int | None = None,
            server: str | None = None,
            ) -> tuple[dict, dict, int, int]:
    """The full grid; returns (per-kernel results, per-task seconds,
    resolved job count, resolved per-task resolution workers).

    ``workers`` shards each dataflow task's trace resolution over the
    chunk-graph executor (default: leftover cores after the task pool,
    so ≥8-core machines shard the Floyd–Warshall tail instead of
    idling behind one bandwidth-bound worker; resolves to 1 — the
    streaming engine, no extra processes — on the 2-core CI
    container)."""
    kernels = tuple(kernels or ALL_KERNELS)
    if jobs is None:
        # one extra worker over the core count: the three Floyd–Warshall
        # machine groups are near-equal, so exact 2-way packing wastes a
        # core for the whole tail — oversubscription lets the scheduler
        # interleave them and the wall approaches total-CPU / cores
        jobs = min(multiprocessing.cpu_count() + 1, 4) if full \
            else min(2, multiprocessing.cpu_count())
    # the grid's wall clock IS the Floyd–Warshall dataflow task
    # (everything else overlaps under it — see task_s in
    # BENCH_sim.json), so on ≥4 cores always shard it: early in the
    # run the extra worker processes time-share with the other
    # tasks, and once only the tail task remains its workers own
    # the freed cores.  Below 4 cores the streaming engine wins
    # (sharding pays a second cache replay per chunk) — the shared
    # heuristic in repro.core.chunkgraph.default_workers.
    from repro.core.chunkgraph import default_workers
    workers = default_workers(jobs=jobs, explicit=workers, full=full)
    if server == "auto":
        from repro.serve import ensure_daemon
        server = ensure_daemon()
    tasks = [(kn, what, full, workers, server) for kn in kernels
             for what in ("dataflow", "conventional", "processor")]
    tasks.sort(key=lambda t: -(_make_kernel(t[0]).n_iters_full if full
                               else 1) * _MACHINE_WEIGHT[t[1]])
    sims: dict[tuple, object] = {}
    task_s: dict[str, float] = {}
    pool = (multiprocessing.get_context("spawn").Pool(jobs)
            if jobs > 1 else None)
    try:
        results = (pool.imap_unordered(_sim_task, tasks) if pool
                   else map(_sim_task, tasks))
        for kn, what, group, dt in results:
            for mn, r in group.items():
                sims[(kn, what, mn)] = r
            task_s[f"{kn}/{what}"] = dt
            print(f"  [{kn}] {what:<12} all-mems "
                  f"({dt:.1f}s)", flush=True)
    finally:
        if pool is not None:
            pool.close()
            pool.join()

    results_out: dict[str, dict] = {}
    for kn in kernels:
        k = _make_kernel(kn)
        n = k.n_iters_full if full else k.n_iters_sim
        base = sims[(kn, "processor", "")]
        t_base = (base.runtime_s if full
                  else base.scaled_runtime(k.n_iters_full))
        out: dict = {"kernel": kn,
                     "n_iters_simulated": n,
                     "n_iters_full": k.n_iters_full,
                     "fully_simulated": bool(full),
                     "baseline_s": t_base}
        for mn in MEM_NAMES:
            df = sims[(kn, "dataflow", mn)]
            cv = sims[(kn, "conventional", mn)]
            t_df = (df.runtime_s if full
                    else df.scaled_runtime(k.n_iters_full))
            t_cv = (cv.runtime_s if full
                    else cv.scaled_runtime(k.n_iters_full))
            out[mn] = {
                "dataflow_s": t_df,
                "conventional_s": t_cv,
                "dataflow_cycles": df.cycles,
                "conventional_cycles": cv.cycles,
                "dataflow_vs_baseline": t_base / t_df,
                "conventional_vs_baseline": t_base / t_cv,
                "dataflow_vs_conventional": t_cv / t_df,
            }
        results_out[kn] = out
    return results_out, task_s, jobs, workers


def summarize(results: dict) -> dict:
    """Aggregate the paper's headline numbers from the per-kernel table."""
    pipelineable = [r for n, r in results.items() if n != "dfs"]

    def best_vs_best(r):
        """Paper §V-A: best dataflow config vs best conventional config."""
        best_df = min(r[m]["dataflow_s"] for m in MEM_NAMES)
        best_cv = min(r[m]["conventional_s"] for m in MEM_NAMES)
        return best_cv / best_df
    conv_cache_cut = np.mean(
        [1 - r["ACP+64KB"]["conventional_s"] / r["ACP"]["conventional_s"]
         for r in pipelineable])
    df_cache_cut = np.mean(
        [1 - r["ACP+64KB"]["dataflow_s"] / r["ACP"]["dataflow_s"]
         for r in pipelineable])
    summary = {
        "dataflow_vs_conventional_best": {
            n: best_vs_best(r) for n, r in results.items()},
        "avg_best_gain_pipelineable": float(np.mean(
            [best_vs_best(r) for r in pipelineable])),
        "avg_dataflow_vs_baseline_acp_pipelineable": float(np.mean(
            [r["ACP"]["dataflow_vs_baseline"] for r in pipelineable])),
        "conv_runtime_cut_by_cache": float(conv_cache_cut),
        "df_runtime_cut_by_cache": float(df_cache_cut),
        "conv_hp_vs_acp_slowdown": float(np.mean(
            [r["HP"]["conventional_s"] / r["ACP"]["conventional_s"]
             for r in pipelineable])),
    }
    if "dfs" in results:
        summary["dfs_best_gain"] = float(best_vs_best(results["dfs"]))
    return summary


def _rescache_disk_stats() -> dict:
    """Artifact count/bytes in the on-disk store (the workers of a spawn
    pool write there; the parent's in-process stats stay empty)."""
    from repro.core import rescache as _rc
    d = _rc._dir()
    try:
        files = os.listdir(d) if d and os.path.isdir(d) else []
        return {"dir": d, "artifacts": len(files),
                "bytes": sum(os.path.getsize(os.path.join(d, f))
                             for f in files)}
    except OSError:
        return {"dir": d, "artifacts": 0, "bytes": 0}


def main(out_path: str | None = "experiments/paper_fig5.json",
         *, quick: bool = False, jobs: int | None = None,
         kernels: tuple[str, ...] | None = None,
         rescache: bool = True, workers: int | None = None,
         server: str | None = None) -> dict:
    if not rescache:
        # spawn-pool workers inherit the environment, not configure()
        os.environ["REPRO_RESCACHE"] = "0"
        from repro.core import rescache as _rc
        _rc.configure(enabled=False)
    full = not quick
    mode = ("fully simulated (Table-I iteration counts)" if full
            else "extrapolated from a small window (--quick)")
    print(f"Fig. 5 grid — {mode}")
    t0 = time.perf_counter()
    results, task_s, jobs_used, workers_used = run_all(
        full=full, jobs=jobs, kernels=kernels, workers=workers,
        server=server)
    wall_s = time.perf_counter() - t0
    summary = summarize(results)
    print(f"\n{'kernel':<16}{'mem':<10}{'conv/base':>10}{'df/base':>10}"
          f"{'df/conv':>10}")
    for name, r in results.items():
        for m in MEM_NAMES:
            print(f"{name:<16}{m:<10}"
                  f"{r[m]['conventional_vs_baseline']:>10.2f}"
                  f"{r[m]['dataflow_vs_baseline']:>10.2f}"
                  f"{r[m]['dataflow_vs_conventional']:>10.2f}")
    print(f"\nwall-clock: {wall_s:.1f}s")
    print("summary:", json.dumps(summary, indent=1))
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({"results": results, "summary": summary}, f,
                      indent=1, default=float)
    if full and (kernels is None or set(kernels) == set(ALL_KERNELS)):
        # perf trajectory: fig5 grid + vectorized-vs-reference timings
        # (--quick is a dev loop; only real full runs update BENCH)
        from repro.core import rescache as _rc
        from .sweep import measure_perf, update_bench
        update_bench("fig5", {"fully_simulated": True, "results": results,
                              "summary": summary})
        update_bench("fig5_wallclock", {
            "wall_s": wall_s,
            "jobs": jobs_used,
            "resolution_workers": workers_used,
            "resolution_mode": ("served" if server else
                                "streaming" if workers_used < 2 else
                                f"sharded:{workers_used}"),
            "server": server,
            "task_s": task_s,
            "rescache": rescache,
            "rescache_stats": _rc.stats(),  # parent process; workers own
            "rescache_disk": _rescache_disk_stats(),
            "pr2_baseline_cpu_s": PR2_BASELINE_CPU_S,
            "pr2_baseline_wall_2core_s": PR2_BASELINE_CPU_S / 2,
            "speedup_vs_pr2_wall": (PR2_BASELINE_CPU_S / 2) / wall_s,
        })
        update_bench("perf", measure_perf())
    return {"results": results, "summary": summary, "wall_s": wall_s}


def cli() -> dict:
    """Entry point parsing flags from sys.argv (shared with run.py, so
    ``run.py fig5 --quick`` behaves like ``python -m benchmarks.paper_fig5
    --quick``)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small-window extrapolated mode (development)")
    ap.add_argument("--full", action="store_true",
                    help="full Table-I simulation (the default; kept as "
                         "an explicit flag for scripts)")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--kernels", nargs="*", default=None)
    ap.add_argument("--out", default="experiments/paper_fig5.json")
    ap.add_argument("--no-rescache", action="store_true",
                    help="bypass the resolved-trace cache (cold timings)")
    ap.add_argument("--workers", type=int, default=None,
                    help="shard each dataflow task's resolution over N "
                         "processes (chunk-graph executor; default: "
                         "leftover cores after the task pool)")
    ap.add_argument("--server", default=None, metavar="auto|ADDR",
                    help="delegate trace resolution to the resolution "
                         "daemon ('auto' spawns one for this store) — "
                         "bit-identical results, shared across clients")
    a, _ = ap.parse_known_args()
    return main(a.out, quick=a.quick, jobs=a.jobs,
                kernels=tuple(a.kernels) if a.kernels else None,
                rescache=not a.no_rescache, workers=a.workers,
                server=a.server)


if __name__ == "__main__":
    cli()

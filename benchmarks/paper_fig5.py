"""Fig. 5 reproduction: conventional vs dataflow accelerators vs ARM core.

Pipeline per kernel:
  1. trace the loop body → cyclic CDFG (carry back-edges),
  2. Algorithm 1 partition (the *real* partitioner, not a hand decomposition),
  3. derive SimStages: II/latency from the partition, memory-SCC stages
     detected automatically (the DFS pathology), traces attached to memory
     stages in pipeline order,
  4. simulate the three machines over four memory configs (ACP, ACP+64KB,
     HP, HP+64KB) and extrapolate to the Table-I dataset sizes.

Checked claims (§V-A):
  * conventional accelerators run below the ARM baseline;
  * dataflow ≫ conventional (paper: 3.3–9.1×, avg 5.6× best-config);
  * caches help conventional more than dataflow (−45.4 % vs −18.7 %);
  * HP (uncached) degrades conventional vs ACP (~40 %);
  * DFS shows no meaningful dataflow gain (memory SCC).
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.simulator import (MemoryModel, SimStage, acp, acp_cache, hp,
                                  hp_cache, simulate_conventional,
                                  simulate_dataflow, simulate_processor)
from repro.dataflow import compile as dataflow_compile, fused_stage
from .paper_kernels import ALL_KERNELS, PaperKernel


def build_stages(k: PaperKernel) -> tuple[list[SimStage], list[SimStage]]:
    """(dataflow stages, conventional stage) from the compiler driver.

    The driver traces the loop body in loop mode (carry back-edges),
    partitions with Algorithm 1, and classifies memory-in-SCC stages (the
    DFS pathology); traces are attached positionally to memory ops in
    pipeline-stage order."""
    compiled = dataflow_compile(
        k.loop_body, k.carry_example, *k.body_args,
        loop=True,
        nonaliasing_carries=getattr(k, "nonaliasing_carries", ()))
    df_stages = compiled.sim_stages(traces=list(k.traces.values()))
    return df_stages, [fused_stage(df_stages)]


def run_kernel(k: PaperKernel) -> dict:
    df_stages, conv_stages = build_stages(k)
    mems = {"ACP": acp, "ACP+64KB": acp_cache, "HP": hp, "HP+64KB": hp_cache}
    out: dict = {"kernel": k.name,
                 "stages": len(df_stages),
                 "n_iters_sim": k.n_iters_sim,
                 "n_iters_full": k.n_iters_full}

    base = simulate_processor(k.instrs_per_iter, list(k.traces.values()),
                              k.n_iters_sim)
    t_base = base.scaled_runtime(k.n_iters_full)
    out["baseline_s"] = t_base

    for name, mk in mems.items():
        mem = mk()
        mem.max_outstanding = 16     # the paper's "multiple outstanding
        df = simulate_dataflow(df_stages, mem, k.n_iters_sim,
                               fifo_depth=32)  # FIFO covers lat×throughput
        cv = simulate_conventional(conv_stages, mk(), k.n_iters_sim)
        t_df = df.scaled_runtime(k.n_iters_full)
        t_cv = cv.scaled_runtime(k.n_iters_full)
        out[name] = {
            "dataflow_s": t_df,
            "conventional_s": t_cv,
            "dataflow_vs_baseline": t_base / t_df,
            "conventional_vs_baseline": t_base / t_cv,
            "dataflow_vs_conventional": t_cv / t_df,
        }
    return out


def run_all(scale: float = 0.125) -> dict:
    results = {}
    for name, mk in ALL_KERNELS.items():
        k = mk() if name != "spmv" else mk(scale)
        results[name] = run_kernel(k)
    return results


def summarize(results: dict) -> dict:
    """Aggregate the paper's headline numbers from the per-kernel table."""
    pipelineable = [r for n, r in results.items() if n != "dfs"]

    def best_vs_best(r):
        """Paper §V-A: best dataflow config vs best conventional config."""
        cfgs = ("ACP", "ACP+64KB", "HP", "HP+64KB")
        best_df = min(r[m]["dataflow_s"] for m in cfgs)
        best_cv = min(r[m]["conventional_s"] for m in cfgs)
        return best_cv / best_df
    conv_cache_cut = np.mean(
        [1 - r["ACP+64KB"]["conventional_s"] / r["ACP"]["conventional_s"]
         for r in pipelineable])
    df_cache_cut = np.mean(
        [1 - r["ACP+64KB"]["dataflow_s"] / r["ACP"]["dataflow_s"]
         for r in pipelineable])
    return {
        "dataflow_vs_conventional_best": {
            n: best_vs_best(r) for n, r in results.items()},
        "avg_best_gain_pipelineable": float(np.mean(
            [best_vs_best(r) for r in pipelineable])),
        "avg_dataflow_vs_baseline_acp_pipelineable": float(np.mean(
            [r["ACP"]["dataflow_vs_baseline"] for r in pipelineable])),
        "conv_runtime_cut_by_cache": float(conv_cache_cut),
        "df_runtime_cut_by_cache": float(df_cache_cut),
        "conv_hp_vs_acp_slowdown": float(np.mean(
            [r["HP"]["conventional_s"] / r["ACP"]["conventional_s"]
             for r in pipelineable])),
        "dfs_best_gain": float(best_vs_best(results["dfs"])),
    }


def main(out_path: str | None = "experiments/paper_fig5.json") -> dict:
    results = run_all()
    summary = summarize(results)
    print(f"{'kernel':<16}{'mem':<10}{'conv/base':>10}{'df/base':>10}"
          f"{'df/conv':>10}")
    for name, r in results.items():
        for m in ("ACP", "ACP+64KB", "HP", "HP+64KB"):
            print(f"{name:<16}{m:<10}"
                  f"{r[m]['conventional_vs_baseline']:>10.2f}"
                  f"{r[m]['dataflow_vs_baseline']:>10.2f}"
                  f"{r[m]['dataflow_vs_conventional']:>10.2f}")
    print("\nsummary:", json.dumps(summary, indent=1))
    if out_path:
        import os
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({"results": results, "summary": summary}, f,
                      indent=1, default=float)
    return {"results": results, "summary": summary}


if __name__ == "__main__":
    main()

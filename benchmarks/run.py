"""Benchmark driver: one section per paper table/figure + the roofline.

  fig5    — Fig. 5 reproduction, fully simulated at Table-I sizes
            (conventional vs dataflow vs ARM baseline; writes
            experiments/paper_fig5.json + BENCH_sim.json)
  sweep   — Fig. 5 design-space sweep (kernels × memory models × FIFO
            depths × SCC modes × port knobs; ``--smoke`` after the
            section name for the reduced CI grid, e.g.
            ``run.py sweep --smoke``)

Both fig5 and sweep memoize resolved traces under
``experiments/.rescache`` (chunk-granular records in an in-process LRU
+ on-disk store, shared across grid cells, chunk sizes, iteration
counts — an N-iteration artifact prefix-serves any shorter run, so
``fig5 --quick`` after a full run resolves nothing — and worker
processes; interrupted runs resume from their last completed chunk).
Pass ``--no-rescache`` after the section name to force cold
resolution — e.g. ``run.py fig5 --no-rescache`` — for timing runs or
when a trace generator changed without changing its fingerprinted
sample; ``--workers N`` shards each dataflow task's resolution over
the chunk-graph process pool (bit-identical; pays off from ~4 cores
up), and ``--server auto`` (or an address) delegates resolution to
the persistent resolution daemon — shared worker pool, cross-client
in-flight dedup, bit-identical results (see ``docs/serving.md``).
  serving — serving smoke: one daemon, two racing ``sweep --smoke``
            clients; asserts bit-identity with library mode and
            exactly-once resolution (``benchmarks.serving_smoke``)
  chaos   — fault-injection drills (worker SIGKILL, corrupt record,
            daemon SIGKILL + journal restart); asserts every scenario
            ends bit-identical to a clean library run with exactly one
            committed record per chunk (``benchmarks.chaos_smoke``)
  engine  — resolution-engine A/B smoke: the same full-scale
            resolution once per backend (numpy / jax), asserts
            bit-identical cycle counts, times the ported kernels head
            to head, and writes an ``engine`` section to
            ``BENCH_sim.json`` (``benchmarks.engine_smoke``; backend
            contract in ``docs/engine.md``)
  lint    — IR lint: compile every shipped kernel (paper kernels +
            example kernels) with the static dataflow verifier and
            report every diagnostic; exits nonzero on error-severity
            findings (``benchmarks.lint``, rule catalog in
            ``docs/verify.md``)
  gc      — garbage-collect the rescache store (``run.py gc
            [--max-bytes N]``: drop pre-v3 orphans, then enforce the
            byte cap — the flag overrides ``$REPRO_RESCACHE_MAX_BYTES``)
  table2  — Table II analogue (stage/channel/duplication accounting)
  kernels — Pallas-kernel micro-bench CSV (name,us_per_call,derived)
  roofline— the (arch × shape) table from dry-run artifacts (if present)
"""

from __future__ import annotations

import sys


def main() -> None:
    # sections are the leading non-flag arguments; everything from the
    # first "-" on belongs to the section's own argparse (run.py fig5
    # --quick, run.py sweep --smoke)
    sections = []
    for a in sys.argv[1:]:
        if a.startswith("-"):
            break
        sections.append(a)
    sections = sections or ["fig2", "fig5", "table2", "kernels",
                            "roofline"]

    if "fig2" in sections:
        print("=" * 72)
        print("Fig. 2 reproduction — execution schedule (Gantt)")
        print("=" * 72)
        from . import fig2_schedule
        fig2_schedule.main()
        print()

    if "fig5" in sections:
        print("=" * 72)
        print("Fig. 5 reproduction — conventional vs dataflow vs baseline")
        print("=" * 72)
        from . import paper_fig5
        paper_fig5.cli()  # parse_known_args: run.py fig5 --quick works

    if "sweep" in sections:
        print("\n" + "=" * 72)
        print("Fig. 5 design-space sweep — mems × FIFO depths × SCC modes")
        print("=" * 72)
        from . import sweep
        sweep.main()

    if "serving" in sections:
        print("\n" + "=" * 72)
        print("Serving smoke — daemon + two racing sweep clients")
        print("=" * 72)
        from . import serving_smoke
        serving_smoke.main()

    if "chaos" in sections:
        print("\n" + "=" * 72)
        print("Chaos smoke — fault-injection drills against the "
              "serving stack")
        print("=" * 72)
        from . import chaos_smoke
        chaos_smoke.main()

    if "engine" in sections:
        print("\n" + "=" * 72)
        print("Resolution-engine A/B smoke — numpy vs jax, bit-identity "
              "+ kernel walls")
        print("=" * 72)
        from . import engine_smoke
        engine_smoke.main()

    if "gc" in sections:
        import argparse
        import json
        from repro.core import rescache
        ap = argparse.ArgumentParser(prog="run.py gc")
        ap.add_argument("--max-bytes", type=int, default=None,
                        help="store byte cap for this collection "
                             "(overrides $REPRO_RESCACHE_MAX_BYTES)")
        a, _ = ap.parse_known_args()
        print("=" * 72)
        print("rescache gc — drop orphans, enforce the byte cap")
        print("=" * 72)
        print(json.dumps(rescache.gc(a.max_bytes), indent=1))

    if "lint" in sections:
        print("\n" + "=" * 72)
        print("IR lint — static dataflow verifier over every shipped "
              "kernel")
        print("=" * 72)
        from . import lint
        lint.main([])  # section names are run.py's, not lint targets

    if "table2" in sections:
        print("\n" + "=" * 72)
        print("Table II analogue — stages / channels / duplication")
        print("=" * 72)
        from . import paper_table2
        paper_table2.main()

    if "kernels" in sections:
        print("\n" + "=" * 72)
        print("Kernel micro-benchmarks (CSV)")
        print("=" * 72)
        from . import kernel_bench
        kernel_bench.main()

    if "roofline" in sections:
        print("\n" + "=" * 72)
        print("Roofline (from dry-run artifacts)")
        print("=" * 72)
        from . import roofline
        roofline.main()


if __name__ == "__main__":
    main()

"""Perf-trajectory trend gate: compare BENCH_sim.json against the
previous CI run's artifact and FAIL on regressions, instead of merely
uploading the file and hoping someone looks.

Gates (tolerances chosen so container noise passes but real regressions
do not):

* **cycle counts** — any sweep grid point or fig5 cell whose
  dataflow/conventional cycle count *increased* by more than 10 % vs the
  previous run fails (cycle counts are deterministic given the seed, so
  a drift means the model changed; deliberate modeling changes ship with
  a regenerated baseline artifact in the same PR, which resets the
  comparison).  Decreases are reported as improvements.
* **wall clock** — the sweep's wall time and the vectorized engine's
  iters/s throughput may regress at most 2× (generous: CI containers
  are noisy, a real algorithmic regression is way past 2×).

Rows are matched on their full grid coordinates; points present on only
one side (grid grew or shrank) are skipped with a note.  A missing
previous artifact passes — the first run has nothing to compare against.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

CYCLE_TOL = 1.10     # >10% cycle-count growth fails
WALL_TOL = 2.0       # >2x wall-clock growth fails
WALL_FLOOR_S = 30.0  # don't gate walls this short: runner noise 2x's them


def _sweep_key(row: dict) -> tuple:
    # "transform" defaults to "none" so rows written by pre-catalog
    # artifacts keep matching the untransformed lanes of new runs
    return (row.get("kernel"), row.get("mem"), row.get("fifo_depth"),
            row.get("mem_in_scc"), row.get("words_per_cycle"),
            row.get("max_outstanding"), row.get("n_iters"),
            row.get("trace_set"), row.get("transform") or "none")


def compare(prev: dict, cur: dict) -> tuple[list[str], list[str]]:
    """(failures, notes) between two BENCH_sim.json payloads."""
    failures: list[str] = []
    notes: list[str] = []

    # --- sweep cycle counts -------------------------------------------------
    ps, cs = prev.get("sweep"), cur.get("sweep")
    if ps and cs and ps.get("smoke") == cs.get("smoke"):
        prows = {_sweep_key(r): r for r in ps.get("rows", [])}
        matched = 0
        for r in cs.get("rows", []):
            p = prows.get(_sweep_key(r))
            if p is None:
                continue
            matched += 1
            for field in ("dataflow_cycles", "conventional_cycles"):
                if field in p and p[field] and field in r:
                    ratio = r[field] / p[field]
                    if ratio > CYCLE_TOL:
                        failures.append(
                            f"sweep {_sweep_key(r)} {field}: "
                            f"{p[field]} -> {r[field]} (+{ratio - 1:.1%})")
                    elif ratio < 1 / CYCLE_TOL:
                        notes.append(
                            f"sweep {_sweep_key(r)} {field} improved "
                            f"{1 - ratio:.1%}")
        notes.append(f"sweep: {matched} matched grid points")
        pw, cw = ps.get("wall_s"), cs.get("wall_s")
        if pw and cw and pw >= WALL_FLOOR_S and cw / pw > WALL_TOL:
            failures.append(f"sweep wall_s: {pw:.1f} -> {cw:.1f} "
                            f"({cw / pw:.1f}x)")
    elif ps and cs:
        notes.append("sweep: smoke/full mismatch, skipped")

    # --- fig5 cycle counts --------------------------------------------------
    pf, cf = prev.get("fig5"), cur.get("fig5")
    if pf and cf:
        for kn, cr in cf.get("results", {}).items():
            pr = pf.get("results", {}).get(kn)
            if not pr or pr.get("n_iters_simulated") != \
                    cr.get("n_iters_simulated"):
                continue
            for mem, cell in cr.items():
                if not isinstance(cell, dict) or mem not in pr:
                    continue
                for field in ("dataflow_cycles", "conventional_cycles"):
                    pv, cv = pr[mem].get(field), cell.get(field)
                    if pv and cv and cv / pv > CYCLE_TOL:
                        failures.append(
                            f"fig5 {kn}/{mem} {field}: {pv} -> {cv} "
                            f"(+{cv / pv - 1:.1%})")

    # --- partition-space DSE ------------------------------------------------
    pd, cd = prev.get("dse"), cur.get("dse")
    if pd and cd and pd.get("trace_set") != cd.get("trace_set"):
        notes.append("dse: trace-set change (smoke now prefixes the "
                     "full-scale traces), comparison reset")
        pd = None
    if pd and cd and pd.get("smoke") == cd.get("smoke"):
        for kn, cr in cd.get("kernels", {}).items():
            pr = pd.get("kernels", {}).get(kn)
            if not pr or any(pr.get(f) != cr.get(f) for f in
                             ("n_iters", "fifo_depth")) or \
                    pd.get("max_candidates") != cd.get("max_candidates"):
                continue
            for field in ("baseline", "best"):
                pv = (pr.get(field) or {}).get("cycles")
                cv = (cr.get(field) or {}).get("cycles")
                if pv and cv and cv / pv > CYCLE_TOL:
                    failures.append(
                        f"dse {kn} {field} cycles: {pv} -> {cv} "
                        f"(+{cv / pv - 1:.1%})")
            if pr.get("dominates_baseline") and \
                    not cr.get("dominates_baseline"):
                failures.append(
                    f"dse {kn}: previously dominated Algorithm 1, "
                    f"no longer does")
            if pr.get("transformed_dominates") and \
                    not cr.get("transformed_dominates"):
                failures.append(
                    f"dse {kn}: the transformed-widened front "
                    f"previously dominated the untransformed "
                    f"(stage-regrouping-only) front, no longer does")
    elif pd and cd:
        notes.append("dse: smoke/full mismatch, skipped")
    # hard gate (current run alone, no previous needed): once a DSE
    # entry explores the transformation catalog, a transformed candidate
    # must strictly dominate the best untransformed point — losing that
    # means the catalog stopped widening the front
    if cd:
        for kn, cr in cd.get("kernels", {}).items():
            if cr.get("transforms") and \
                    cr.get("transformed_dominates") is False:
                failures.append(
                    f"dse {kn}: transform axis explored "
                    f"({'/'.join(cr['transforms'])}) but no transformed "
                    f"candidate dominates the untransformed front")
    # hard gate: verifier-prune soundness.  The static deadlock/race
    # pruning (repro.dataflow.verify) must only discard candidates that
    # could never be Pareto-optimal — a recorded front point that is
    # itself pruned, or that sits below its own static deadlock bound,
    # means the analysis rejected a point the search wanted to keep
    if cd:
        for kn, cr in cd.get("kernels", {}).items():
            for p in cr.get("front", []):
                if p.get("pruned"):
                    failures.append(
                        f"dse {kn}: front point (depth {p.get('fifo_depth')},"
                        f" {p.get('fifo_bits')} bits) is statically pruned "
                        f"({p['pruned']}) — verifier pruning is unsound")
                bound = p.get("deadlock_min_depth")
                if bound is not None and p.get("fifo_depth", bound) < bound:
                    failures.append(
                        f"dse {kn}: front point at fifo depth "
                        f"{p['fifo_depth']} sits below its static deadlock "
                        f"bound {bound} — the bound over-approximates")

    # --- chunk-graph worker scaling ----------------------------------------
    pw, cw = prev.get("worker_scaling"), cur.get("worker_scaling")
    if cw:
        if cw.get("identical") is False:
            failures.append(
                "worker_scaling: sharded and streaming runs disagree "
                "on cycle counts — the chunk-graph executor must be "
                "bit-identical")
        # hard floor on the current run alone: the fused effect+replay
        # executor must hold its scaling.  ≥2 cores overlap the master's
        # fold/solve with the workers' replay, so break-even (0.9x) is
        # the bar; a 1-cpu container serializes master + workers + IPC
        # and 0.25x is the calibrated floor (measured 0.30-0.40x across
        # runs — the unfused executor's double replay scored 0.16x; see
        # docs/engine.md for the profile)
        cs = cw.get("speedup")
        floor = 0.9 if (cw.get("cpus") or 1) >= 2 else 0.25
        if cs and cs < floor:
            failures.append(
                f"worker_scaling: speedup {cs:.2f}x on "
                f"{cw.get('cpus')} cpu(s) fell below the {floor:.1f}x "
                f"floor — the fused effect+replay path regressed")
        if pw and pw.get("n_iters") == cw.get("n_iters"):
            p1, c1 = pw.get("workers1_s"), cw.get("workers1_s")
            # same short-wall floor as every other gate here: runner
            # noise routinely doubles second-scale timings
            if p1 and c1 and p1 >= WALL_FLOOR_S and c1 / p1 > WALL_TOL:
                failures.append(
                    f"worker_scaling workers1_s: {p1:.1f} -> {c1:.1f} "
                    f"({c1 / p1:.1f}x) — the streaming path regressed")
            ps, cs = pw.get("speedup"), cw.get("speedup")
            if ps and cs and pw.get("cpus") == cw.get("cpus"):
                notes.append(f"worker scaling on {cw.get('cpus')} cpus: "
                             f"{ps:.2f}x -> {cs:.2f}x")

    # --- resolution-engine A/B ---------------------------------------------
    # jax-vs-numpy cycle identity is a correctness property of the
    # engine abstraction (hard fail on the current run alone); the
    # per-backend walls and phase walls are trend-compared with the
    # usual noise tolerances
    pe, ce = prev.get("engine"), cur.get("engine")
    if ce:
        if ce.get("identical") is False:
            cyc = {b: ce.get(b, {}).get("cycles")
                   for b in ("numpy", "jax") if ce.get(b)}
            failures.append(
                f"engine: backends disagree on cycle counts ({cyc}) — "
                "the resolution engine must be bit-identical across "
                "numpy and jax")
        if pe and pe.get("n_iters") == ce.get("n_iters"):
            for b in ("numpy", "jax"):
                pv = pe.get(b, {}).get("wall_s")
                cv = ce.get(b, {}).get("wall_s")
                if pv and cv and pv >= WALL_FLOOR_S \
                        and cv / pv > WALL_TOL:
                    failures.append(
                        f"engine {b} wall_s: {pv:.1f} -> {cv:.1f} "
                        f"({cv / pv:.1f}x)")
            pj = pe.get("nway_replay", {}).get("jax_speedup")
            cj = ce.get("nway_replay", {}).get("jax_speedup")
            if pj and cj:
                notes.append(f"engine nway replay jax-vs-numpy: "
                             f"{pj:.2f}x -> {cj:.2f}x")

    # --- serving smoke ------------------------------------------------------
    # same posture as worker_scaling: the daemon is scheduling-only, so
    # identity and exactly-once are correctness gates (hard fail on the
    # current run alone), only the wall is trend-compared
    psv, csv = prev.get("serving"), cur.get("serving")
    if csv:
        if csv.get("identical") is False:
            failures.append(
                "serving: served sweep rows disagree with library mode "
                "— the resolution daemon must be bit-identical")
        if csv.get("exactly_once") is False:
            failures.append(
                "serving: racing clients did not resolve the shared "
                "keyset exactly once (in-flight dedup broke: "
                f"cold={csv.get('cold_chunks')} store="
                f"{csv.get('store_chunks')} "
                f"inflight={csv.get('inflight_dedup')})")
        if csv.get("clean_teardown") is False:
            failures.append("serving: daemon did not shut down cleanly")
        if psv and psv.get("smoke") == csv.get("smoke"):
            pv, cv = psv.get("wall_s"), csv.get("wall_s")
            if pv and cv and pv >= WALL_FLOOR_S and cv / pv > WALL_TOL:
                failures.append(f"serving wall_s: {pv:.1f} -> {cv:.1f} "
                                f"({cv / pv:.1f}x)")
            notes.append(
                f"serving: inflight dedup "
                f"{psv.get('inflight_dedup')} -> "
                f"{csv.get('inflight_dedup')} chunks, wall "
                f"{pv:.1f}s -> {cv:.1f}s" if pv and cv else
                "serving: compared")

    # --- chaos smoke --------------------------------------------------------
    # resilience is a correctness property: every fault drill must end
    # bit-identical to a clean library run with exactly one committed
    # record per chunk — both hard-fail on the current run alone; only
    # the wall is trend-compared
    pch, cch = prev.get("chaos"), cur.get("chaos")
    if cch:
        if cch.get("identical") is False:
            bad = [k for k in ("worker_kill", "corrupt_record",
                               "daemon_restart")
                   if cch.get(k, {}).get("identical") is False]
            failures.append(
                "chaos: fault drill diverged from the clean library "
                f"run ({', '.join(bad) or 'unknown scenario'}) — "
                "recovery must be bit-identical")
        if cch.get("exactly_once") is False:
            counts = {k: (cch.get(k, {}).get("records"),
                          cch.get(k, {}).get("expect_records"))
                      for k in ("worker_kill", "corrupt_record",
                                "daemon_restart")}
            failures.append(
                "chaos: store accounting broke — expected exactly one "
                f"committed record per chunk, got {counts}")
        if pch and pch.get("smoke") == cch.get("smoke"):
            pv, cv = pch.get("wall_s"), cch.get("wall_s")
            if pv and cv and pv >= WALL_FLOOR_S and cv / pv > WALL_TOL:
                failures.append(f"chaos wall_s: {pv:.1f} -> {cv:.1f} "
                                f"({cv / pv:.1f}x)")
            notes.append(
                f"chaos: quarantined "
                f"{pch.get('corrupt_record', {}).get('quarantined')}"
                f" -> "
                f"{cch.get('corrupt_record', {}).get('quarantined')}, "
                f"resumed jobs "
                f"{pch.get('daemon_restart', {}).get('resumed_jobs')}"
                f" -> "
                f"{cch.get('daemon_restart', {}).get('resumed_jobs')}"
                + (f", wall {pv:.1f}s -> {cv:.1f}s"
                   if pv and cv else ""))

    # --- vectorized-engine throughput --------------------------------------
    # gate on the reference-vs-vectorized *speedup ratio* rather than raw
    # iters/s: both numerator and denominator see the same runner noise,
    # so the ratio is stable where a 40 ms absolute timing is not
    pp, cp = prev.get("perf"), cur.get("perf")
    if pp and cp and pp.get("n_iters") == cp.get("n_iters"):
        for mem in ("ACP",):
            pv = pp.get(mem, {}).get("dataflow_speedup")
            cv = cp.get(mem, {}).get("dataflow_speedup")
            if pv and cv and pv / cv > WALL_TOL:
                failures.append(
                    f"perf {mem} dataflow vectorized-vs-reference "
                    f"speedup: {pv:.0f}x -> {cv:.0f}x "
                    f"({pv / cv:.1f}x worse)")

    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--previous", default="prev/BENCH_sim.json")
    ap.add_argument("--current", default="BENCH_sim.json")
    a = ap.parse_args()
    if not os.path.exists(a.current):
        print(f"trend gate: no current {a.current}; nothing to check")
        return 0
    if not os.path.exists(a.previous):
        print(f"trend gate: no previous artifact at {a.previous} "
              f"(first run?) — passing")
        return 0
    with open(a.previous) as f:
        prev = json.load(f)
    with open(a.current) as f:
        cur = json.load(f)
    failures, notes = compare(prev, cur)
    for n in notes:
        print(f"  note: {n}")
    if failures:
        print(f"trend gate: {len(failures)} regression(s) vs previous run:")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    print("trend gate: no regressions vs previous run")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Table II analogue: resource/area impact of the template.

FPGA LUT/FF/BRAM numbers have no TPU meaning; the honest analogues are:

  * per-variant op counts and channel (FIFO) count/width from the
    partitioner — the paper's "communication channels always add cost";
  * duplicated-op count (§III-B1 — compute traded for channels);
  * XLA program size: HLO ops of the fused vs decoupled executor for each
    kernel body (the "shallower per-stage pipeline" effect shows up as
    per-stage program size).
"""

from __future__ import annotations

import json

from repro.core import CDFG, decouple, partition_cdfg
from .paper_kernels import ALL_KERNELS


def analyze_kernel(name: str, mk) -> dict:
    k = mk()
    cdfg = CDFG.from_loop_body(
        k.loop_body, k.carry_example, *k.body_args,
        nonaliasing_carries=k.nonaliasing_carries)
    paper = partition_cdfg(cdfg, policy="paper")
    fused = partition_cdfg(cdfg, policy="fused")
    prog = decouple(paper)

    chan_bytes = sum(c.nbytes for c in paper.channels)
    return {
        "kernel": name,
        "nodes": len(cdfg.nodes),
        "stages_dataflow": paper.num_stages,
        "stages_conventional": fused.num_stages,
        "channels": len(paper.channels),
        "channel_bytes_per_token": chan_bytes,
        "duplicated_ops": len(paper.duplicated),
        "ops_per_stage": [sp.eqn_count for sp in prog.stages],
        # area analogue: total op instances = original + duplicated copies
        "op_instances_conventional": len(cdfg.nodes),
        "op_instances_dataflow": len(cdfg.nodes) + sum(
            len(v) for v in paper.duplicated.values()),
    }


def main(out_path: str | None = "experiments/paper_table2.json") -> dict:
    rows = [analyze_kernel(n, mk) for n, mk in ALL_KERNELS.items()]
    hdr = (f"{'kernel':<16}{'stages':>7}{'chans':>7}{'chanB':>7}"
           f"{'dup':>5}{'ops(conv)':>10}{'ops(df)':>9}")
    print(hdr)
    for r in rows:
        print(f"{r['kernel']:<16}{r['stages_dataflow']:>7}"
              f"{r['channels']:>7}{r['channel_bytes_per_token']:>7}"
              f"{r['duplicated_ops']:>5}"
              f"{r['op_instances_conventional']:>10}"
              f"{r['op_instances_dataflow']:>9}")
    if out_path:
        import os
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return {"rows": rows}


if __name__ == "__main__":
    main()

"""Serving smoke: the multi-tenant acceptance scenario as a benchmark.

Starts one resolution daemon on a **fresh** store, races two real
``benchmarks.sweep --smoke --server ADDR`` client processes through it
(same reduced grid the CI sweep job runs), and checks the serving
contract end to end:

- both clients' sweep rows are **bit-identical** to each other and to a
  library-mode (``--no-rescache``, streaming engine) baseline — the
  daemon is scheduling-only, never semantics;
- the shared keyset was resolved **exactly once**: the daemon's dedup
  counters satisfy ``cold == store + inflight`` with ``inflight > 0``
  (the second tenant attached to the first's in-flight resolution
  rather than re-resolving or waiting for the store);
- teardown is clean (``shutdown`` ack + daemon exit).

The daemon is throttled (``--throttle``) so resolution outlives the
clients' start-up skew — the race window is real on any machine, not
just a loaded CI runner.  Results land in the ``serving`` section of
``BENCH_sim.json`` so ``bench_trend.py`` gates serving regressions
(identity and exactly-once are hard failures, the wall is
tolerance-gated).  Run directly::

    python -m benchmarks.serving_smoke [--out BENCH_sim.json]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

BENCH_PATH = "BENCH_sim.json"
#: Small canonical chunks so the smoke grid spans many scheduling units.
CHUNK_ITERS = 2048
#: Per-chunk dispatch throttle: 10 chunks/group ⇒ ≥5 s of in-flight
#: window per resolution group, far above client start-up skew.
THROTTLE_S = 0.5


def _row_key(r: dict) -> tuple:
    return (r["kernel"], r["mem"], r["fifo_depth"], r["mem_in_scc"],
            r["words_per_cycle"], r["max_outstanding"])


def _row_val(r: dict) -> tuple:
    return (r["dataflow_cycles"], r["conventional_cycles"],
            r["dataflow_stalls"], r["cache_hits"], r["cache_misses"])


def _rows(path: str) -> dict:
    with open(path) as f:
        return {_row_key(r): _row_val(r)
                for r in json.load(f)["sweep"]["rows"]}


def run_smoke(out_path: str = BENCH_PATH,
              kernels: tuple[str, ...] = ("spmv",)) -> dict:
    from repro.serve.client import get_stats, ping, shutdown

    t0 = time.perf_counter()
    work = tempfile.mkdtemp(prefix="serving-smoke-")
    store = os.path.join(work, "store")
    sock = os.path.join(tempfile.mkdtemp(prefix="serve-"), "d.sock")
    env = dict(os.environ,
               REPRO_RESCACHE_DIR=store,
               REPRO_CHUNK_ITERS=str(CHUNK_ITERS))
    daemon = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "daemon",
         "--socket", sock, "--store-dir", store,
         "--throttle", str(THROTTLE_S)],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    payload: dict = {"smoke": True, "clients": 2, "kernels": kernels,
                     "chunk_iters": CHUNK_ITERS,
                     "throttle_s": THROTTLE_S}
    try:
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline and not ping(sock):
            time.sleep(0.2)
        if not ping(sock):
            raise RuntimeError("resolution daemon never came up")

        base = [sys.executable, "-m", "benchmarks.sweep", "--smoke",
                "--kernels", *kernels]
        clients = [subprocess.Popen(
            base + ["--server", sock,
                    "--out", os.path.join(work, f"bench{i}.json")],
            env=env, stdout=subprocess.DEVNULL,
            stderr=subprocess.STDOUT) for i in range(2)]
        for i, p in enumerate(clients):
            if p.wait(timeout=600):
                raise RuntimeError(f"served client {i} failed "
                                   f"(exit {p.returncode})")
        st = get_stats(sock)

        # library-mode baseline: cold streaming engine, no store, no
        # daemon — the ground truth the served rows must match
        lib = subprocess.run(
            base + ["--no-rescache",
                    "--out", os.path.join(work, "bench_lib.json")],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.STDOUT)
        if lib.returncode:
            raise RuntimeError("library baseline failed "
                               f"(exit {lib.returncode})")

        served0, served1 = (_rows(os.path.join(work, f"bench{i}.json"))
                            for i in range(2))
        library = _rows(os.path.join(work, "bench_lib.json"))
        ded = st["dedup"]
        payload.update({
            "identical": served0 == served1 == library,
            "exactly_once": (ded["inflight_chunks"] > 0
                             and ded["cold_chunks"]
                             == ded["store_chunks"]
                             + ded["inflight_chunks"]),
            "inflight_dedup": ded["inflight_chunks"],
            "store_chunks": ded["store_chunks"],
            "cold_chunks": ded["cold_chunks"],
            "dedup_hit_rate": ded["hit_rate"],
            "requests": len(st["requests"]),
            "jobs_completed": st["jobs_completed"],
            "worker_restarts": st["failures"]["worker_restarts"],
            "rows_compared": len(library),
        })
    finally:
        clean = shutdown(sock)
        try:
            daemon.wait(timeout=30)
        except subprocess.TimeoutExpired:
            daemon.kill()
            clean = False
        payload["clean_teardown"] = clean
        shutil.rmtree(work, ignore_errors=True)
    payload["wall_s"] = time.perf_counter() - t0

    from .sweep import update_bench
    update_bench("serving", payload, out_path)
    print(f"serving smoke: identical={payload.get('identical')} "
          f"exactly_once={payload.get('exactly_once')} "
          f"inflight={payload.get('inflight_dedup')} "
          f"cold={payload.get('cold_chunks')} "
          f"teardown={payload['clean_teardown']} "
          f"({payload['wall_s']:.1f}s); wrote {out_path}")
    if not (payload.get("identical") and payload.get("exactly_once")
            and payload["clean_teardown"]):
        raise SystemExit("serving smoke FAILED: " + json.dumps(payload))
    return payload


def main() -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=BENCH_PATH)
    ap.add_argument("--kernels", nargs="*", default=None)
    a, _ = ap.parse_known_args()
    return run_smoke(out_path=a.out,
                     kernels=tuple(a.kernels or ("spmv",)))


if __name__ == "__main__":
    main()

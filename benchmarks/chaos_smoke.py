"""Chaos smoke: the fault-injection acceptance scenarios as a benchmark.

Runs the three headline failure drills from the chaos harness
(``repro.serve.faults``) against fresh stores and checks the resilience
contract end to end:

- **worker_kill** — a pool worker SIGKILLs itself mid-chunk; the daemon
  respawns the slot and replays its in-flight chunks.  The served
  result must be bit-identical to a clean library run and the store
  must hold exactly one record per chunk.
- **corrupt_record** — a store record is damaged at publish time; the
  next run detects the bad checksum, quarantines the record,
  re-resolves the gap, and re-commits it — after which a third run
  serves fully warm with zero cold chunks.
- **daemon_restart** — the daemon SIGKILLs itself mid-stream; the
  client fails over to library mode from the committed prefix
  (bit-identically), and a *restarted* daemon replays its journal and
  finishes the orphaned job into the store with no client attached.

Every scenario's identity check and the exactly-once store accounting
are **hard failures**; results land in the ``chaos`` section of
``BENCH_sim.json`` so ``bench_trend.py`` gates resilience regressions
(the wall is tolerance-gated, identity/exactly-once fail on the
current run alone).  Run directly::

    python -m benchmarks.chaos_smoke [--out BENCH_sim.json]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

BENCH_PATH = "BENCH_sim.json"
#: Small canonical chunks so every drill spans many scheduling units.
CHUNK_ITERS = 512


def _pipeline(n: int, seed: int = 5):
    from repro.core.simulator import MemAccess, SimStage
    rng = np.random.default_rng(seed)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("i", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=3,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 19, n) * 4),
                           MemAccess("y", np.arange(n) * 4 + (1 << 22),
                                     is_store=True)]),
        SimStage("fma", ii=4, latency=6),
    ]


def _row(v) -> tuple:
    return (v.cycles, v.cache_hits, v.cache_misses,
            v.stage_stall_cycles)


def _run(n: int, **kw) -> dict:
    from repro.core.simulator import acp_cache, simulate_dataflow_many
    out = simulate_dataflow_many(_pipeline(n), {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), **kw)
    return {k: _row(v) for k, v in out.items()}


def _records(store: str) -> int:
    try:
        return len([f for f in os.listdir(store) if f.endswith(".npz")])
    except OSError:
        return 0


def _fresh_store(rc, work: str, name: str) -> str:
    d = os.path.join(work, name)
    rc.clear()
    rc.configure(enabled=True, directory=d)
    return d


def _spawn_daemon(sock: str, store: str, extra_env=None):
    from repro.serve.client import ping
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""),
               REPRO_CHUNK_ITERS=str(CHUNK_ITERS))
    env.update(extra_env or {})
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "daemon",
         "--socket", sock, "--workers", "2", "--store-dir", store,
         "--speculate-after", "0"],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline and not ping(sock):
        if proc.poll() is not None:
            raise RuntimeError("chaos daemon died during start-up")
        time.sleep(0.2)
    if not ping(sock):
        proc.kill()
        raise RuntimeError("chaos daemon never came up")
    return proc


def _drill_worker_kill(rc, work: str, n: int, ref: dict) -> dict:
    """SIGKILL one pool worker mid-chunk; serve through the daemon."""
    from repro.serve import faults
    from repro.serve.client import (get_stats, shutdown,
                                    simulate_dataflow_served)
    store = _fresh_store(rc, work, "store_wk")
    sock = os.path.join(work, "wk.sock")
    log = os.path.join(work, "wk.log")
    plan = json.dumps({"faults": [{"kind": "worker_kill", "chunk": 3}],
                       "log": log})
    proc = _spawn_daemon(sock, store, extra_env={faults.ENV: plan})
    try:
        from repro.core.simulator import acp_cache
        out = simulate_dataflow_served(
            _pipeline(n), {"ACPC": acp_cache()}, n, fifo_depths=(8,),
            address=sock)
        st = get_stats(sock)
        shutdown(sock)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
    got = {k: _row(v) for k, v in out.items()}
    return {"identical": got == ref,
            "worker_restarts": st["failures"]["worker_restarts"],
            "fault_fired": faults.log_counts(log).get("worker_kill", 0),
            "records": _records(store),
            "expect_records": -(-n // CHUNK_ITERS)}


def _drill_corrupt_record(rc, work: str, n: int, ref: dict) -> dict:
    """Damage one record at publish; quarantine + re-resolve heals it."""
    from repro.serve import faults
    store = _fresh_store(rc, work, "store_cr")
    log = os.path.join(work, "cr.log")
    faults.install(faults.FaultPlan(
        [{"kind": "corrupt_chunk", "chunk": 2}], log=log))
    try:
        first = _run(n)
    finally:
        faults.install(None)
    rc.clear()  # drop the memory tier: force the damaged disk read
    rc.configure(enabled=True, directory=store)
    healed = _run(n)
    quarantined = rc.stats()["quarantined"]
    rc.clear()
    rc.configure(enabled=True, directory=store)
    warm = _run(n)
    return {"identical": first == ref and healed == ref and warm == ref,
            "quarantined": quarantined,
            "warm_cold_chunks": rc.stats()["cold_chunks"],
            "records": _records(store),
            "expect_records": -(-n // CHUNK_ITERS)}


def _drill_daemon_restart(rc, work: str, n: int, ref: dict) -> dict:
    """SIGKILL the daemon mid-stream; fail over, then journal-resume."""
    from repro.serve import faults
    from repro.serve.client import (ServeUnavailable, get_stats,
                                    shutdown, simulate_dataflow_served)
    from repro.core.simulator import acp_cache
    store = _fresh_store(rc, work, "store_dr")
    sock = os.path.join(work, "dr.sock")
    log = os.path.join(work, "dr.log")
    plan = json.dumps({"faults": [{"kind": "daemon_kill", "chunk": 4}],
                       "log": log})
    expect = -(-n // CHUNK_ITERS)
    proc = _spawn_daemon(sock, store, extra_env={faults.ENV: plan})
    died_mid_stream = False
    try:
        try:
            simulate_dataflow_served(_pipeline(n),
                                     {"ACPC": acp_cache()}, n,
                                     fifo_depths=(8,), address=sock)
        except ServeUnavailable:
            died_mid_stream = True
        committed = _records(store)
        # failover path: the committed prefix serves, the rest resolves
        # locally — this is what simulate_dataflow_many does on its own
        got = _run(n)
        proc.wait(timeout=30)  # reap: a zombie would trip the pidfile
    finally:
        if proc.poll() is None:
            proc.kill()
    # wipe the failover's local completions so the restarted daemon has
    # a journaled remainder to finish with no client attached
    recs = sorted(f for f in os.listdir(store) if f.endswith(".npz"))
    for f in recs[committed:]:
        os.unlink(os.path.join(store, f))
    rc.clear()
    rc.configure(enabled=True, directory=store)
    proc2 = _spawn_daemon(sock, store)
    try:
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline and _records(store) < expect:
            time.sleep(0.5)
        st = get_stats(sock)
        shutdown(sock)
        proc2.wait(timeout=30)
    finally:
        if proc2.poll() is None:
            proc2.kill()
    rc.clear()
    rc.configure(enabled=True, directory=store)
    warm = _run(n)
    return {"identical": got == ref and warm == ref,
            "died_mid_stream": died_mid_stream,
            "committed_prefix": committed,
            "journal_restarts": st["journal"]["restarts"],
            "resumed_jobs": st["journal"]["resumed_jobs"],
            "warm_cold_chunks": rc.stats()["cold_chunks"],
            "records": _records(store),
            "expect_records": expect}


def run_smoke(out_path: str = BENCH_PATH, n: int = 5000) -> dict:
    from repro.core import rescache as rc

    t0 = time.perf_counter()
    work = tempfile.mkdtemp(prefix="chaos-smoke-")
    old_ci = rc.CHUNK_ITERS
    rc.CHUNK_ITERS = CHUNK_ITERS
    os.environ["REPRO_CHUNK_ITERS"] = str(CHUNK_ITERS)
    payload: dict = {"smoke": True, "n_iters": n,
                     "chunk_iters": CHUNK_ITERS}
    try:
        # ground truth: clean library run, no store, no daemon
        rc.clear()
        rc.configure(enabled=False)
        ref = _run(n)

        wk = _drill_worker_kill(rc, work, n, ref)
        rc.clear()
        rc.configure(enabled=False)
        ref_half = _run(n // 2)  # the store-damage drill runs shorter
        cr = _drill_corrupt_record(rc, work, n // 2, ref_half)
        dr = _drill_daemon_restart(rc, work, n, ref)
        payload.update({
            "worker_kill": wk, "corrupt_record": cr,
            "daemon_restart": dr,
            "identical": (wk["identical"] and cr["identical"]
                          and dr["identical"]),
            "exactly_once": all(
                d["records"] == d["expect_records"]
                for d in (wk, cr, dr)),
        })
    finally:
        rc.clear()
        rc.configure(enabled=False)
        rc.CHUNK_ITERS = old_ci
        os.environ.pop("REPRO_CHUNK_ITERS", None)
        shutil.rmtree(work, ignore_errors=True)
    payload["wall_s"] = time.perf_counter() - t0

    from .sweep import update_bench
    update_bench("chaos", payload, out_path)
    print(f"chaos smoke: identical={payload.get('identical')} "
          f"exactly_once={payload.get('exactly_once')} "
          f"worker_restarts="
          f"{payload.get('worker_kill', {}).get('worker_restarts')} "
          f"quarantined="
          f"{payload.get('corrupt_record', {}).get('quarantined')} "
          f"resumed_jobs="
          f"{payload.get('daemon_restart', {}).get('resumed_jobs')} "
          f"({payload['wall_s']:.1f}s); wrote {out_path}")
    if not (payload.get("identical") and payload.get("exactly_once")):
        raise SystemExit("chaos smoke FAILED: " + json.dumps(payload))
    return payload


def main() -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=BENCH_PATH)
    ap.add_argument("--n-iters", type=int, default=5000)
    a, _ = ap.parse_known_args()
    return run_smoke(out_path=a.out, n=a.n_iters)


if __name__ == "__main__":
    main()

"""Design-space sweep over the paper's kernels (Fig. 5, §V).

Grid: kernels × memory models (ACP / HP, ±64 KB System Cache) × FIFO
depths × ``mem_in_scc`` modes, each point **fully simulated** at the
Table-I iteration counts (no steady-state extrapolation — the vectorized
simulator streams even Floyd–Warshall's 1024^3 iterations).  This is the
sweep-style evaluation of de Fine Licht et al. / HIDA applied to the
dataflow template: how much FIFO depth the latency tolerance needs, what
the DFS pathology costs, and which memory port wins per kernel.

Also measures the simulator's own perf trajectory (vectorized vs the
scalar reference at 65536 iterations — the PR's ≥20× acceptance bar) and
writes everything to ``BENCH_sim.json`` (CI uploads it as an artifact).

``--smoke`` runs a reduced grid at small iteration counts (seconds) for
CI; the full sweep is a multi-hour batch job — ``--jobs``/-``--kernels``
split it.  ``--dse`` additionally explores the *partition space* per
kernel (``Compiled.explore``: merge/split/duplicate re-partitionings
under resource constraints, fully simulated, resolution shared through
the per-op rescache) and records each kernel's cycles-vs-FIFO-bits
Pareto front in the ``dse`` section of ``BENCH_sim.json``;
``--dse-only`` skips the grid.
"""

from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import time

import numpy as np

from repro.core.simulator import (MemAccess, SimStage, acp,
                                  simulate_conventional, simulate_dataflow,
                                  standard_memory_models)
from repro.dataflow import compile as dataflow_compile

from .paper_fig5 import MAX_OUTSTANDING, _make_kernel

BENCH_PATH = "BENCH_sim.json"
SMOKE_ITERS = 20_000
#: Full-scale sweep depths: both sized past the DRAM-spike threshold
#: (see benchmarks.paper_fig5.FIFO_DEPTH) so billion-iteration runs stay
#: on the solver's fast path; the smoke grid exercises a shallow FIFO.
FIFO_DEPTHS = (128, 256)
SCC_MODES = ("auto",)


def update_bench(section: str, payload: dict,
                 path: str = BENCH_PATH) -> None:
    """Merge one section into the BENCH_sim.json perf-trajectory file."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)


def _perf_pipeline(n: int) -> list[SimStage]:
    rng = np.random.default_rng(0)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("idx", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", rng.integers(0, 4 << 20, n) * 4),
                           MemAccess("w", rng.integers(0, 4 << 20, n) * 4)]),
        SimStage("fma", ii=6, latency=8),
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", np.arange(n) * 4,
                                     is_store=True)]),
    ]


def measure_perf(n: int = 65536) -> dict:
    """Vectorized-vs-reference timing at ``n`` iterations (identical
    cycle counts asserted) — the perf trajectory tracked across PRs."""
    stages = _perf_pipeline(n)
    out: dict = {"n_iters": n}
    for label, mk in (("ACP", acp),):
        t0 = time.perf_counter()
        ref = simulate_dataflow(stages, mk(), n, fifo_depth=32,
                                reference=True)
        t1 = time.perf_counter()
        vec = simulate_dataflow(stages, mk(), n, fifo_depth=32)
        t2 = time.perf_counter()
        assert ref.cycles == vec.cycles, (ref.cycles, vec.cycles)
        cr0 = time.perf_counter()
        cref = simulate_conventional(stages, mk(), n, reference=True)
        cr1 = time.perf_counter()
        cvec = simulate_conventional(stages, mk(), n)
        cr2 = time.perf_counter()
        assert cref.cycles == cvec.cycles, (cref.cycles, cvec.cycles)
        out[label] = {
            "dataflow_reference_s": t1 - t0,
            "dataflow_vectorized_s": t2 - t1,
            "dataflow_speedup": (t1 - t0) / max(1e-9, t2 - t1),
            "conventional_reference_s": cr1 - cr0,
            "conventional_vectorized_s": cr2 - cr1,
            "conventional_speedup": (cr1 - cr0) / max(1e-9, cr2 - cr1),
            "vectorized_iters_per_s": n / max(1e-9, t2 - t1),
        }
    return out


def _sweep_task(task: tuple) -> list[dict]:
    """Sweep one kernel over one memory model (top-level for spawn).

    Within the task the planner in ``sweep_schedule`` shares all trace
    resolution across FIFO depths / SCC modes / port-knob variants (one
    streaming pass per SCC mode), and the resolved traces are memoized
    on disk so tasks in sibling processes — and later ``paper_fig5``
    runs — share with this one.  Reduced (``--smoke``) runs use the
    *full-scale* traces at a truncated iteration count, so the v3
    rescache prefix-serves them from any full-scale run's artifacts —
    and every row records ``n_iters_requested`` (the Table-I count) vs
    ``n_iters_simulated`` so trend comparisons never silently mix
    scales."""
    (kname, mem_name, fifo_depths, scc_modes, n_iters,
     wpcs, mos, workers, server, transform) = task
    k = _make_kernel(kname)
    n = n_iters or k.n_iters_full
    traces = k.full_traces
    compiled = dataflow_compile(
        k.loop_body, k.carry_example, *k.body_args, loop=True,
        nonaliasing_carries=getattr(k, "nonaliasing_carries", ()),
        transforms=transform)
    mems = {mem_name: standard_memory_models()[mem_name]}
    res = compiled.sweep(n_iters=n, mems=mems,
                         fifo_depths=fifo_depths, scc_modes=scc_modes,
                         traces=list(traces.values()),
                         max_outstanding=MAX_OUTSTANDING,
                         words_per_cycle=wpcs, max_outstandings=mos,
                         workers=workers, server=server)
    for row in res.rows:
        row["kernel"] = kname
        row["n_iters"] = n
        row["n_iters_requested"] = k.n_iters_full
        row["n_iters_simulated"] = n
        row["fully_simulated"] = n == k.n_iters_full
        row["trace_set"] = "full"
    return res.rows


def measure_worker_scaling(n: int | None = None) -> dict:
    """The chunk-graph worker-scaling probe: one fixed cached-model
    pipeline resolved cold by the streaming engine (``--workers 1``)
    and by the sharded executor at all cores, identical cycles
    asserted.  Recorded in ``BENCH_sim.json`` (``worker_scaling``) and
    trend-gated: the workers=1 wall must never regress, and the two
    modes must agree bit-for-bit — the speedup column documents what
    the fused effect+replay executor buys on this machine.  Each arm
    records its per-phase walls (effect / replay / fold / solve, see
    ``repro.core.engine.walls``) and the resolution-engine backend, so
    a trend regression is attributable to a phase instead of one
    opaque wall number."""
    from repro.core import engine as _eng
    from repro.core import rescache as _rc
    from repro.core.simulator import simulate_dataflow_many
    if n is None:
        # enough chunks for the pool to engage, and enough work that
        # the two spawn-context worker startups (~seconds) don't
        # dominate what the probe is actually measuring
        n = 8 * _rc.CHUNK_ITERS
    stages = _perf_pipeline(n)
    cpus = multiprocessing.cpu_count()
    out = {"n_iters": n, "cpus": cpus, "engine": _eng.current()}
    mems = standard_memory_models()
    _eng.reset_walls()
    t0 = time.perf_counter()
    r1 = simulate_dataflow_many(
        stages, {"ACP+64KB": mems["ACP+64KB"]()}, n, fifo_depths=(64,),
        collect_stalls=False, use_rescache=False)
    out["workers1_s"] = time.perf_counter() - t0
    out["phases_workers1"] = _eng.walls()
    w = max(2, cpus)
    _eng.reset_walls()
    t0 = time.perf_counter()
    rw = simulate_dataflow_many(
        stages, {"ACP+64KB": mems["ACP+64KB"]()}, n, fifo_depths=(64,),
        collect_stalls=False, use_rescache=False, workers=w)
    out["workers_all_s"] = time.perf_counter() - t0
    out["phases_workers_all"] = _eng.walls()
    _eng.reset_walls()
    out["workers_all"] = w
    out["identical"] = all(rw[key].cycles == r1[key].cycles
                           for key in r1)
    out["speedup"] = out["workers1_s"] / max(1e-9, out["workers_all_s"])
    return out


def run_dse(*, smoke: bool = False,
            kernels: tuple[str, ...] | None = None,
            out_path: str = BENCH_PATH,
            max_candidates: int = 16,
            rescache: bool = True,
            server: str | None = None) -> dict:
    """Partition-space DSE over the paper kernels (``--dse``).

    Per kernel: explore merge/split/duplicate re-partitionings of the
    Algorithm 1 plan with ``Compiled.explore`` (every candidate fully
    simulated; the per-op rescache shares trace resolution across
    candidates, so the whole exploration costs little more than one cold
    simulation) and record the cycles-vs-FIFO-bits Pareto front, the
    baseline, and whether some candidate strictly dominates Algorithm 1.
    The exploration is *widened* with the transformation catalog
    (unroll=2 ± coalescing as per-candidate lanes, joint with a halved
    FIFO depth so transformed points can win at equal bits) and spans
    two memory models (``ACP`` / ``ACP+64KB``) in one call; the entry
    records ``transformed_dominates`` — whether some transformed
    candidate strictly dominates the best untransformed point — which
    bench_trend hard-gates.
    ``--smoke`` explores the first two kernels at SMOKE_ITERS for CI;
    the full mode explores at the Table-I iteration counts (defaults to
    spmv — Floyd–Warshall's 10⁹-iteration traces exceed the artifact
    cap, so its candidates would each resolve cold).
    """
    from .paper_fig5 import FIFO_DEPTH
    if not rescache:
        os.environ["REPRO_RESCACHE"] = "0"
        from repro.core import rescache as _rc
        _rc.configure(enabled=False)
    if smoke:
        from .paper_kernels import ALL_KERNELS
        kernels = tuple(kernels or ALL_KERNELS)[:2]
        n_iters, fifo_depth = SMOKE_ITERS, 8
    else:
        kernels = tuple(kernels or ("spmv",))
        n_iters, fifo_depth = None, FIFO_DEPTH
    payload: dict = {"smoke": smoke, "fifo_depth": fifo_depth,
                     "max_candidates": max_candidates,
                     "trace_set": "full", "kernels": {}}
    t0 = time.perf_counter()
    for kn in kernels:
        k = _make_kernel(kn)
        n = n_iters or k.n_iters_full
        traces = k.full_traces
        compiled = dataflow_compile(
            k.loop_body, k.carry_example, *k.body_args, loop=True,
            nonaliasing_carries=getattr(k, "nonaliasing_carries", ()))
        mem = acp()
        mem.max_outstanding = MAX_OUTSTANDING
        # acceptance meter: one cold simulation of the Algorithm 1
        # partition under the repo's default regime (rescache enabled —
        # a cold run resolves *and stores*, exactly what the first fig5
        # or sweep cell pays).  Run at seed+1 so it neither serves from
        # nor pre-warms the DSE's own artifacts.
        from repro.core.simulator import simulate_dataflow
        from repro.dataflow.dse import (sim_stages_for_partition,
                                        traces_by_node)
        from repro.dataflow.schedule import _cyclic_nodes
        nt = traces_by_node(compiled.cdfg, compiled.partition,
                            list(traces.values()), n_iters=n)
        cyc = {x for x in _cyclic_nodes(compiled.cdfg)
               if compiled.cdfg.node(x).is_memory}
        base_stages = sim_stages_for_partition(compiled.partition, nt,
                                               cyc)
        from repro.core import rescache as _rc
        colds = []
        for probe_seed in (1, 2, 3):  # median of three: the artifact
            tc = time.perf_counter()  # store makes single timings noisy
            simulate_dataflow(base_stages, mem, n, fifo_depth=fifo_depth,
                              seed=probe_seed)
            colds.append(time.perf_counter() - tc)
            # evict the probe's artifact so re-runs stay cold (a warm
            # serve would fake the meter) and the store keeps only
            # artifacts real sweeps reuse
            _rc.evict(_rc.resolution_key("dataflow", base_stages, mem,
                                         probe_seed))
        cold_s = sorted(colds)[1]
        from repro.dataflow import TransformConfig
        mem64 = standard_memory_models()["ACP+64KB"]()
        mem64.max_outstanding = MAX_OUTSTANDING
        te = time.perf_counter()
        res = compiled.explore(
            n_iters=n, traces=list(traces.values()), mem=mem,
            mems=[mem, mem64],
            fifo_depth=fifo_depth,
            fifo_depths=[fifo_depth, max(1, fifo_depth // 2)],
            transforms=[TransformConfig(unroll=2),
                        TransformConfig(unroll=2, coalesce=True)],
            max_candidates=max_candidates,
            server=server)
        explore_s = time.perf_counter() - te  # incl. front Compiled
        entry = res.to_json()                 # artifact materialization
        entry["single_cold_s"] = cold_s
        entry["explore_wall_s"] = explore_s
        entry["cost_ratio_vs_cold"] = explore_s / max(1e-9, cold_s)
        payload["kernels"][kn] = entry
        print(f"  [{kn}] {res.summary()}", flush=True)
        print(f"  [{kn}] single cold sim {cold_s:.2f}s, DSE wall "
              f"{explore_s:.2f}s over {len(res.evaluated())} simulated "
              f"candidates ({entry['cost_ratio_vs_cold']:.2f}x; "
              f"{res.eval_stats.get('cold_groups', 0)} cold resolution "
              f"group(s))", flush=True)
    payload["wall_s"] = time.perf_counter() - t0
    update_bench("dse", payload, out_path)
    print(f"\nwrote dse section to {out_path} "
          f"({payload['wall_s']:.1f}s)")
    return payload


def run_sweep(*, smoke: bool = False, jobs: int | None = None,
              kernels: tuple[str, ...] | None = None,
              out_path: str = BENCH_PATH,
              words_per_cycle: tuple[float, ...] | None = None,
              max_outstandings: tuple[int, ...] | None = None,
              rescache: bool = True,
              workers: int | None = None,
              server: str | None = None) -> dict:
    from .paper_kernels import ALL_KERNELS
    if not rescache:
        os.environ["REPRO_RESCACHE"] = "0"  # spawn workers inherit env
        from repro.core import rescache as _rc
        _rc.configure(enabled=False)
    if server == "auto":
        # spawn (or find) the daemon for this store up front, then hand
        # every task the concrete address — job subprocesses must not
        # race to spawn their own
        from repro.serve import ensure_daemon
        server = ensure_daemon()
    kernels = tuple(kernels or ALL_KERNELS)
    if smoke:
        kernels = kernels[:2]
        mems = ("ACP", "ACP+64KB")
        fifo_depths, scc_modes, n_iters = (8,), ("auto",), SMOKE_ITERS
        if words_per_cycle is None:
            # exercise the port-knob axes + Pareto view in CI
            words_per_cycle = (0.5, 1.0)
    else:
        mems = tuple(standard_memory_models())
        fifo_depths, scc_modes, n_iters = FIFO_DEPTHS, SCC_MODES, None
    tasks = [(kn, mn, fifo_depths, scc_modes, n_iters,
              words_per_cycle, max_outstandings, workers, server, None)
             for kn in kernels for mn in mems]
    # the transformation-catalog axis: spmv re-swept under
    # unroll=2 (+coalescing) — the rows land with a distinct
    # ``transform`` signature so bench_trend keys them separately
    if "spmv" in kernels:
        from repro.dataflow import TransformConfig
        tf_mems = mems if smoke else ("ACP",)
        tasks += [("spmv", mn, fifo_depths, scc_modes, n_iters,
                   words_per_cycle, max_outstandings, workers, server,
                   TransformConfig(unroll=2, coalesce=True))
                  for mn in tf_mems]
    if jobs is None:
        jobs = 1 if smoke else min(2, multiprocessing.cpu_count())
    rows: list[dict] = []
    t0 = time.perf_counter()
    pool = (multiprocessing.get_context("spawn").Pool(jobs)
            if jobs > 1 else None)
    try:
        parts = (pool.imap_unordered(_sweep_task, tasks) if pool
                 else map(_sweep_task, tasks))
        for part in parts:
            rows.extend(part)
            r = part[0]
            print(f"  [{r['kernel']}] {r['mem']:<9} done "
                  f"({len(part)} points)", flush=True)
    finally:
        if pool is not None:
            pool.close()
            pool.join()
    rows.sort(key=lambda r: (r["kernel"], r["mem"],
                             r.get("transform") or "none",
                             r["fifo_depth"], r["mem_in_scc"],
                             r["words_per_cycle"], r["max_outstanding"]))
    # per-kernel cycles-vs-FIFO-bits Pareto fronts (HIDA-style DSE view,
    # the same dominance rule as Compiled.sweep via SweepResult.pareto)
    from repro.dataflow.schedule import SweepResult
    fronts: dict[str, list] = {}
    for kn in kernels:
        krows = [r for r in rows if r["kernel"] == kn]
        front = SweepResult(krows, krows[0]["n_iters"]).pareto()
        fronts[kn] = [
            {"mem": r["mem"], "fifo_depth": r["fifo_depth"],
             "fifo_bits": r["fifo_bits"],
             "words_per_cycle": r["words_per_cycle"],
             "max_outstanding": r["max_outstanding"],
             "transform": r.get("transform") or "none",
             "dataflow_cycles": r["dataflow_cycles"]}
            for r in front]
    perf = measure_perf()
    scaling = measure_worker_scaling()
    payload = {"smoke": smoke, "wall_s": time.perf_counter() - t0,
               "workers": workers, "server": server, "rows": rows,
               "pareto": fronts}
    update_bench("sweep", payload, out_path)
    update_bench("perf", perf, out_path)
    update_bench("worker_scaling", scaling, out_path)
    if server:
        # the daemon's own telemetry (dedup rates, utilization, queue
        # wall) rides along so bench_trend can gate the serving path
        from repro.serve import ServeUnavailable, get_stats
        try:
            update_bench("serving_stats", get_stats(server), out_path)
        except ServeUnavailable:
            pass
    print(f"worker scaling: workers=1 {scaling['workers1_s']:.1f}s, "
          f"workers={scaling['workers_all']} "
          f"{scaling['workers_all_s']:.1f}s "
          f"({scaling['speedup']:.2f}x, identical="
          f"{scaling['identical']}) on {scaling['cpus']} cpus")
    print(f"\n{'kernel':<16}{'mem':<10}{'fifo':>5}{'wpc':>5}{'mo':>4}"
          f"{'df cyc/it':>11}{'conv cyc/it':>13}{'speedup':>9}")
    for r in rows:
        print(f"{r['kernel']:<16}{r['mem']:<10}{r['fifo_depth']:>5}"
              f"{r['words_per_cycle']:>5.2g}{r['max_outstanding']:>4}"
              f"{r['dataflow_cpi']:>11.2f}{r['conventional_cpi']:>13.2f}"
              f"{r['speedup']:>9.2f}")
    print(f"\nsimulator perf: dataflow {perf['ACP']['dataflow_speedup']:.0f}x"
          f" / conventional {perf['ACP']['conventional_speedup']:.0f}x"
          f" vectorized-vs-reference at {perf['n_iters']} iters; "
          f"wrote {out_path}")
    return payload


def main() -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid at small iteration counts (CI)")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--kernels", nargs="*", default=None)
    ap.add_argument("--out", default=BENCH_PATH)
    ap.add_argument("--words-per-cycle", nargs="*", type=float,
                    default=None, help="port bandwidth axis values")
    ap.add_argument("--max-outstandings", nargs="*", type=int,
                    default=None, help="in-flight request cap axis values")
    ap.add_argument("--no-rescache", action="store_true",
                    help="bypass the resolved-trace cache (cold timings)")
    ap.add_argument("--workers", type=int, default=None,
                    help="shard trace resolution over N processes per "
                         "sweep task (the chunk-graph executor; "
                         "bit-identical results)")
    ap.add_argument("--server", default=None, metavar="auto|ADDR",
                    help="delegate trace resolution to the resolution "
                         "daemon ('auto' spawns one for this store; "
                         "else an AF_UNIX path or host:port) — shared "
                         "pool, cross-client in-flight dedup, "
                         "bit-identical results")
    ap.add_argument("--dse", action="store_true",
                    help="also run the partition-space DSE and record "
                         "the Pareto fronts in BENCH_sim.json")
    ap.add_argument("--dse-only", action="store_true",
                    help="run only the DSE section (skip the sweep grid)")
    ap.add_argument("--dse-candidates", type=int, default=16)
    a, _ = ap.parse_known_args()
    kernels = tuple(a.kernels) if a.kernels else None
    out: dict = {}
    server = a.server
    if server == "auto":
        from repro.serve import ensure_daemon
        server = ensure_daemon()
    if not a.dse_only:
        out = run_sweep(smoke=a.smoke, jobs=a.jobs,
                        kernels=kernels,
                        out_path=a.out,
                        words_per_cycle=(tuple(a.words_per_cycle)
                                         if a.words_per_cycle else None),
                        max_outstandings=(tuple(a.max_outstandings)
                                          if a.max_outstandings else None),
                        rescache=not a.no_rescache,
                        workers=a.workers, server=server)
    if a.dse or a.dse_only:
        out["dse"] = run_dse(smoke=a.smoke, kernels=kernels,
                             out_path=a.out,
                             max_candidates=a.dse_candidates,
                             rescache=not a.no_rescache,
                             server=server)
    return out


if __name__ == "__main__":
    main()

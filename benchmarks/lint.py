"""IR lint: the static dataflow verifier over every shipped kernel.

``run.py lint`` (or ``python -m benchmarks.lint``) compiles each paper
kernel and each example kernel through the full pass pipeline — with the
inter-pass verifier on, so a pass that breaks an IR invariant fails the
compile outright — then runs :meth:`Compiled.verify` for the whole-
artifact families (channel balance, FIFO deadlock bounds at the
configuration's depth, decoupled-access races, decouple wiring) and
prints every finding.  Exit status is nonzero iff any *error*-severity
diagnostic survives; warnings are printed but don't fail the sweep
(``docs/verify.md`` has the rule catalog).
"""

from __future__ import annotations

import sys
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.dataflow import compile as dataflow_compile


def _example_quickstart():
    """The quickstart example's kernel: data-dependent gather feeding
    long-latency fp compute (examples/quickstart.py)."""
    def kernel(table, idx, w):
        g = table[idx]
        h = g * w
        return jnp.tanh(h) + 1.0
    table = jnp.arange(1024, dtype=jnp.float32)
    idx = jnp.asarray([3, 997, 41, 512, 7, 800, 64, 2])
    w = jnp.float32(1.5)
    return dataflow_compile(kernel, table, idx, w,
                            stream_argnums=(1,)), (8,)


def _example_spmv():
    """The SpMV example's CSR inner loop in loop mode
    (examples/spmv_dataflow.py, HLS view; simulated at depth 32)."""
    rng = np.random.default_rng(0)
    dim = 64
    dense = ((rng.random((dim, dim)) < 0.25)
             * rng.normal(size=(dim, dim))).astype(np.float32)
    vals = jnp.asarray(dense[dense != 0])
    cols = jnp.asarray(np.nonzero(dense)[1].astype(np.int32))
    x = jnp.asarray(rng.normal(size=dim).astype(np.float32))

    def inner_loop(acc, j):
        return acc + vals[j] * x[cols[j]]

    return dataflow_compile(inner_loop, jnp.float32(0), jnp.int32(0),
                            loop=True), (32,)


def _paper(kname: str) -> Callable:
    def make():
        from .paper_fig5 import FIFO_DEPTH, _make_kernel
        k = _make_kernel(kname)
        c = dataflow_compile(
            k.loop_body, k.carry_example, *k.body_args, loop=True,
            nonaliasing_carries=getattr(k, "nonaliasing_carries", ()))
        return c, (FIFO_DEPTH,)
    return make


def targets() -> dict[str, Callable]:
    """name -> () -> (Compiled, fifo_depths): every shipped kernel."""
    from .paper_kernels import ALL_KERNELS
    out: dict[str, Callable] = {
        f"kernel:{kn}": _paper(kn) for kn in ALL_KERNELS}
    out["example:quickstart"] = _example_quickstart
    out["example:spmv_dataflow"] = _example_spmv
    return out


def lint_all(names: tuple[str, ...] = ()) -> int:
    """Lint every (or the named) target; returns the error count."""
    from repro.dataflow.verify import VerifyError
    errors = 0
    for name, make in sorted(targets().items()):
        if names and name not in names:
            continue
        try:
            compiled, depths = make()
        except VerifyError as e:
            # the inter-pass hook caught a broken invariant mid-compile
            errors += len(e.diagnostics)
            print(f"{name}: COMPILE FAILED at pass {e.where!r}")
            for d in e.diagnostics:
                print(f"  {d}")
            continue
        diags = compiled.verify(fifo_depths=depths)
        errs = [d for d in diags if d.severity == "error"]
        warns = [d for d in diags if d.severity == "warning"]
        errors += len(errs)
        status = "clean" if not errs else f"{len(errs)} error(s)"
        if warns:
            status += f", {len(warns)} warning(s)"
        print(f"{name}: {status}")
        for d in errs + warns:
            print(f"  {d}")
    return errors


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    names = tuple(a for a in argv if not a.startswith("-"))
    errors = lint_all(names)
    if errors:
        print(f"\nlint: {errors} error(s)")
        sys.exit(1)
    print("\nlint: all targets clean")


if __name__ == "__main__":
    main()

"""Fig. 2 reproduction: the execution schedule, rendered.

The paper's Fig. 2 contrasts the conventional engine (every miss stalls
everything) with the dataflow engine (stalls localized to the fetch stage,
shadowed by the long-latency compute stage).  This renders the same
comparison as an ASCII Gantt chart from the actual simulator state —
per-stage start/finish times for the first iterations of an SpMV-like
pipeline.
"""

from __future__ import annotations

import numpy as np

from repro.core.simulator import (MemAccess, SimStage, acp,
                                  simulate_conventional, simulate_dataflow)


def _gantt(starts: np.ndarray, finishes: np.ndarray, names: list[str],
           n_iters: int, width: int = 100) -> str:
    t_max = finishes.max()
    scale = width / max(1, t_max)
    lines = []
    for s, name in enumerate(names):
        row = [" "] * (width + 1)
        for i in range(n_iters):
            a = int(starts[s, i] * scale)
            b = max(a + 1, int(finishes[s, i] * scale))
            ch = chr(ord("0") + i % 10)
            for x in range(a, min(b, width)):
                row[x] = ch
        lines.append(f"{name:>8} |{''.join(row)}")
    lines.append(f"{'':>8} +{'-' * width}> cycles (0..{int(t_max)})")
    return "\n".join(lines)


def main() -> None:
    rng = np.random.default_rng(0)
    n = 12
    # an SpMV-like pipeline: sequential index fetch → random x fetch →
    # long-latency FMA → sequential store
    stages = [
        SimStage("idx", ii=1, latency=2,
                 accesses=[MemAccess("cols", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x",
                                     rng.integers(0, 4 << 20, n) * 4)]),
        SimStage("fma", ii=6, latency=8),
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", np.arange(n) * 4,
                                     is_store=True)]),
    ]
    mem = acp()

    # the real simulator, capturing the per-stage schedule matrices
    df, start, finish = simulate_dataflow(stages, mem, n,
                                          return_schedule=True)

    print("Dataflow engine (Fig. 2 bottom): stalls stay inside 'fetch';")
    print("'fma' streams at its II once the FIFO fills.\n")
    print(_gantt(start, finish, [st.name for st in stages], n))

    cv = simulate_conventional(
        [SimStage("fused", ii=max(s.ii for s in stages),
                  latency=sum(s.latency for s in stages),
                  accesses=[a for s in stages for a in s.accesses])],
        acp(), n)
    df_cycles = df.cycles
    print(f"\nConventional engine (Fig. 2 top): {cv.cycles} cycles for the "
          f"same {n} iterations — {cv.cycles / max(1, df_cycles):.1f}x "
          f"slower (every access serializes into the single schedule).")


if __name__ == "__main__":
    main()

"""The paper's four benchmark kernels (§V, Table I), as JAX loop bodies +
reference implementations + memory-address-trace generators.

Each kernel provides:
  * ``loop_body``    — one inner-loop iteration, traced by the CDFG front
                       end (the HLS view Algorithm 1 partitions);
  * ``reference``    — a vectorized JAX implementation (correctness oracle);
  * ``traces``       — per-region word-address streams of the *actual*
                       execution, fed to the cycle simulator's cache model;
  * ``meta``         — iteration counts, baseline instruction estimates,
                       and which regions sit inside a memory SCC (DFS).

Datasets follow Table I, scaled by ``scale`` (1.0 = the paper's sizes;
benchmarks default to a reduced scale and extrapolate via steady-state
cycles/iteration, which the pipeline reaches within a few hundred
iterations).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import MemAccess


@dataclasses.dataclass
class PaperKernel:
    name: str
    loop_body: Callable          # (carry, args...) -> carry
    carry_example: tuple
    body_args: tuple             # example args for tracing
    regions: dict[int, str]      # invar index -> region name (annotation)
    traces: dict[str, MemAccess]
    n_iters_full: int            # Table-I-scale iteration count
    n_iters_sim: int             # simulated window
    instrs_per_iter: float       # ARM baseline estimate
    mem_in_scc_regions: tuple = ()
    nonaliasing_carries: tuple = ()
    reference: Callable | None = None
    reference_args: tuple = ()
    expected: np.ndarray | None = None


# ---------------------------------------------------------------------------
# 1. SpMV (CSR): dim 4096, density 0.25 (≈16 MB)
# ---------------------------------------------------------------------------

def make_spmv(scale: float = 0.125, seed: int = 0) -> PaperKernel:
    dim = max(64, int(4096 * scale))
    rng = np.random.default_rng(seed)
    density = 0.25
    # build a random CSR matrix
    nnz_per_row = np.maximum(1, rng.binomial(dim, density, size=dim))
    indptr = np.zeros(dim + 1, np.int64)
    indptr[1:] = np.cumsum(nnz_per_row)
    nnz = int(indptr[-1])
    indices = np.concatenate([
        np.sort(rng.choice(dim, size=n, replace=False))
        for n in nnz_per_row]).astype(np.int32)
    data = rng.normal(size=nnz).astype(np.float32)
    x = rng.normal(size=dim).astype(np.float32)

    vals_j = jnp.asarray(data)
    cols_j = jnp.asarray(indices)
    x_j = jnp.asarray(x)

    def loop_body(acc, j, vals=vals_j, cols=cols_j, xv=x_j):
        c = cols[j]          # sequential index load
        v = vals[j]          # sequential value load
        xx = xv[c]           # data-dependent gather (the pathology)
        return acc + v * xx  # fp multiply feeding the accumulation SCC

    n_sim = 40_000
    # traces are FULL-scale (Table I: dim 4096, 16 MB) so the cache models
    # see the real working set; `scale` only shrinks the correctness data.
    full_dim = 4096
    trng = np.random.default_rng(seed + 100)
    traces = {
        "cols": MemAccess("cols", np.arange(n_sim) * 4),
        "vals": MemAccess("vals", np.arange(n_sim) * 4 + (1 << 24)),
        "x": MemAccess("x", trng.integers(0, full_dim, n_sim).astype(
            np.int64) * 4 + (1 << 25)),
    }

    def reference(vals, cols, indptr, xv):
        contrib = vals * xv[cols]
        row_id = np.repeat(np.arange(dim), np.diff(indptr))
        return jnp.asarray(np.add.reduceat(
            np.asarray(contrib), indptr[:-1].astype(np.int64)))

    expected = (np.add.reduceat(data * x[indices],
                                indptr[:-1].astype(np.int64))
                if nnz else np.zeros(dim))

    return PaperKernel(
        name="spmv",
        loop_body=loop_body,
        carry_example=jnp.float32(0.0),
        body_args=(jnp.int32(0),),
        regions={},
        traces=traces,
        n_iters_full=int(4096 * 4096 * 0.25),
        n_iters_sim=n_sim,
        instrs_per_iter=9.0,
        expected=expected.astype(np.float32),
    )


# ---------------------------------------------------------------------------
# 2. Knapsack DP: W=3200, N=200 (≈5 MB)
# ---------------------------------------------------------------------------

def make_knapsack(scale: float = 0.25, seed: int = 1) -> PaperKernel:
    W = max(64, int(3200 * scale))
    N = max(8, int(200 * scale))
    rng = np.random.default_rng(seed)
    weights = rng.integers(1, 64, size=N).astype(np.int32)
    values = rng.integers(1, 100, size=N).astype(np.int32)

    dp_j = jnp.zeros(W + 1, jnp.int32)
    w_j = jnp.asarray(weights)
    v_j = jnp.asarray(values)

    def loop_body(dp, ij, w=w_j, v=v_j):
        # one (i, j) inner iteration, j descending
        i, j = ij
        cur = dp[j]                       # load dp[j]
        take = dp[j - w[i]] + v[i]        # load dp[j-w]; the DP recurrence
        new = jnp.maximum(cur, take)
        return dp.at[j].set(jnp.where(j >= w[i], new, cur))  # store dp[j]

    # FULL-scale 2-D DP table traces (W=3200, N=200 => ~5 MB, Table I):
    # row i reads row i-1 (two streams) and writes row i.
    n_sim = 40_000
    Wf = 3200
    t = np.arange(n_sim)
    ti = t // Wf
    tj = Wf - (t % Wf)
    wt = np.asarray(weights)[(ti % len(weights))].astype(np.int64)
    traces = {
        "dp_load": MemAccess("dp_load", ((ti - 1).clip(0) * Wf + tj) * 4),
        "dp_load2": MemAccess("dp_load2",
                              ((ti - 1).clip(0) * Wf
                               + np.maximum(0, tj - wt)) * 4),
        "dp_store": MemAccess("dp_store", (ti * Wf + tj) * 4,
                              is_store=True),
    }
    cnt = n_sim

    # reference: classic vectorized DP
    dp = np.zeros(W + 1, np.int64)
    for i in range(N):
        w, v = int(weights[i]), int(values[i])
        dp[w:] = np.maximum(dp[w:], dp[:-w] + v if w else dp[w:])
    return PaperKernel(
        name="knapsack",
        loop_body=loop_body,
        carry_example=dp_j,
        body_args=((jnp.int32(0), jnp.int32(1)),),
        regions={},
        traces=traces,
        n_iters_full=3200 * 200,
        n_iters_sim=cnt,
        instrs_per_iter=11.0,
        nonaliasing_carries=(0,),  # §III-A annotation: row i-1 -> row i
        expected=dp.astype(np.int64),
    )


# ---------------------------------------------------------------------------
# 3. Floyd–Warshall: 1024 nodes (≈8 MB) — regular but data-derived addresses
# ---------------------------------------------------------------------------

def make_floyd_warshall(scale: float = 0.125, seed: int = 2) -> PaperKernel:
    n = max(32, int(1024 * scale))
    rng = np.random.default_rng(seed)
    dist0 = rng.integers(1, 100, size=(n, n)).astype(np.float32)
    np.fill_diagonal(dist0, 0)

    dist_j = jnp.asarray(dist0.reshape(-1))

    def loop_body(dist, kij, n=n):
        k, i, j = kij
        d_ij = dist[i * n + j]            # load
        d_ik = dist[i * n + k]            # load
        d_kj = dist[k * n + j]            # load
        new = jnp.minimum(d_ij, d_ik + d_kj)
        return dist.at[i * n + j].set(new)  # store

    n_sim = 40_000
    nf = 1024  # full Table-I scale for the memory model
    ks = np.zeros(n_sim, np.int64)
    iis = (np.arange(n_sim) // nf) % nf
    jjs = np.arange(n_sim) % nf
    traces = {
        "d_ij": MemAccess("d_ij", (iis * nf + jjs) * 4),
        "d_ik": MemAccess("d_ik", (iis * nf + ks) * 4),
        "d_kj": MemAccess("d_kj", (ks * nf + jjs) * 4),
        "d_store": MemAccess("d_store", (iis * nf + jjs) * 4,
                             is_store=True),
    }

    d = dist0.copy()
    for k in range(n):
        d = np.minimum(d, d[:, k:k + 1] + d[k:k + 1, :])
    return PaperKernel(
        name="floyd_warshall",
        loop_body=loop_body,
        carry_example=dist_j,
        body_args=((jnp.int32(0), jnp.int32(0), jnp.int32(1)),),
        regions={},
        traces=traces,
        n_iters_full=1024 ** 3,
        n_iters_sim=n_sim,
        instrs_per_iter=12.0,
        nonaliasing_carries=(0,),  # §III-A annotation: k-pass writes don't
                                   # feed row/col-k reads within the pass
        expected=d.astype(np.float32),
    )


# ---------------------------------------------------------------------------
# 4. DFS: 4000 nodes × 200 neighbors (≈3 MB) — stack = memory SCC
# ---------------------------------------------------------------------------

def make_dfs(scale: float = 0.25, seed: int = 3) -> PaperKernel:
    n_nodes = max(64, int(4000 * scale))
    n_nbrs = max(8, int(200 * scale))
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n_nodes, size=(n_nodes, n_nbrs)).astype(np.int32)

    stack_j = jnp.zeros(n_nodes * 4, jnp.int32)
    visited_j = jnp.zeros(n_nodes, jnp.int32)
    adj_j = jnp.asarray(adj.reshape(-1))

    def loop_body(carry, _, n_nbrs=n_nbrs):
        # one DFS step: pop, mark, push first unvisited neighbor.
        stack, visited, sp = carry
        node = stack[sp - 1]                       # load through the stack
        visited = visited.at[node].set(1)          # store visited
        nb = adj_j[node * n_nbrs]                  # load adjacency
        seen = visited[nb]                         # load visited[nb]
        push = 1 - seen
        stack = stack.at[sp].set(nb)               # store through the stack
        sp = sp - 1 + push
        return (stack, visited, sp)

    # FULL-scale trace (4000 nodes x 200 nbrs ~ 3 MB adjacency)
    nf_nodes, nf_nbrs = 4000, 200
    trng = np.random.default_rng(seed + 100)
    m = 40_000
    nodes = trng.integers(0, nf_nodes, m).astype(np.int64)
    traces = {
        "stack": MemAccess("stack",
                           (trng.integers(0, 64, m) * 4).astype(np.int64)),
        "adj": MemAccess("adj", (nodes * nf_nbrs * 4) + (1 << 24)),
        "visited": MemAccess("visited", nodes * 4 + (1 << 23)),
    }

    return PaperKernel(
        name="dfs",
        loop_body=loop_body,
        carry_example=(stack_j, visited_j, jnp.int32(1)),
        body_args=(jnp.int32(0),),
        regions={},
        traces=traces,
        n_iters_full=4000 * 200,
        n_iters_sim=m,
        instrs_per_iter=14.0,
        mem_in_scc_regions=("arg0", "stack"),
        expected=None,
    )


ALL_KERNELS = {
    "spmv": make_spmv,
    "knapsack": make_knapsack,
    "floyd_warshall": make_floyd_warshall,
    "dfs": make_dfs,
}

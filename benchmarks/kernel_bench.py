"""Per-kernel micro-benchmarks (CSV: name,us_per_call,derived).

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock measures the *reference* (pure-jnp) path; the kernel-specific
derived column reports the structural quantities that determine TPU
performance: VMEM working set of the chosen BlockSpecs and arithmetic
intensity (FLOPs/HBM byte), which positions each kernel on the v5e
roofline (ridge at 197e12/819e9 ≈ 241 FLOP/B).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_matmul() -> list[tuple]:
    rows = []
    for M, K, N, bm, bk, bn in [(512, 512, 512, 128, 512, 128),
                                (1024, 1024, 512, 128, 512, 128)]:
        x = jnp.ones((M, K), jnp.bfloat16)
        w = jnp.ones((K, N), jnp.bfloat16)
        us = _time(jax.jit(lambda a, b: ref.matmul_ref(a, b)), x, w)
        flops = 2 * M * K * N
        bytes_ = 2 * (M * K + K * N + M * N)
        vmem = 2 * (bm * bk + bk * bn) + 4 * bm * bn
        rows.append((f"matmul_{M}x{K}x{N}", us,
                     f"AI={flops / bytes_:.0f}flop/B;vmem={vmem >> 10}KB"))
    return rows


def bench_attention() -> list[tuple]:
    rows = []
    for B, H, S, d in [(1, 8, 1024, 64), (1, 8, 4096, 64)]:
        q = jnp.ones((B, H, S, d), jnp.bfloat16)
        us = _time(jax.jit(lambda q: ref.flash_attention_ref(q, q, q)), q)
        flops = 4 * B * H * S * S * d
        bytes_ = 2 * 4 * B * H * S * d
        rows.append((f"attn_{B}x{H}x{S}x{d}", us,
                     f"AI={flops / bytes_:.0f}flop/B"))
    return rows


def bench_decode() -> list[tuple]:
    rows = []
    for B, H, S, d in [(8, 8, 4096, 64)]:
        q = jnp.ones((B, H, d), jnp.bfloat16)
        kc = jnp.ones((B, H, S, d), jnp.bfloat16)
        lens = jnp.full((B,), S, jnp.int32)
        us = _time(jax.jit(
            lambda q, k, l: ref.decode_attention_ref(q, k, k, l)),
            q, kc, lens)
        flops = 4 * B * H * S * d
        bytes_ = 2 * 2 * B * H * S * d
        rows.append((f"decode_{B}x{H}x{S}x{d}", us,
                     f"AI={flops / bytes_:.1f}flop/B(mem-bound)"))
    return rows


def bench_spmv() -> list[tuple]:
    rng = np.random.default_rng(0)
    nbr, nnz, bm, bk = 16, 8, 8, 128
    vals = jnp.asarray(rng.normal(size=(nbr, nnz, bm, bk)), jnp.float32)
    cols = jnp.asarray(rng.integers(0, nnz, (nbr, nnz)), jnp.int32)
    x = jnp.ones((nnz * bk,), jnp.float32)
    us = _time(jax.jit(
        lambda v, c, x: ref.spmv_bsr_ref(v, c, x, nbr * bm)),
        vals, cols, x)
    flops = 2 * nbr * nnz * bm * bk
    bytes_ = 4 * (vals.size + x.size)
    return [(f"spmv_bsr_{nbr}x{nnz}x{bm}x{bk}", us,
             f"AI={flops / bytes_:.2f}flop/B(mem-bound)")]


def bench_dataflow_driver() -> list[tuple]:
    """Backend overhead of the compiler driver on the quickstart kernel:
    ``xla`` is the fused baseline, ``sequential`` replays N staged XLA
    calls (per-stage dispatch overhead), ``emulated`` adds the tick-exact
    schedule.  The derived column reports the compiled pipeline shape."""
    from repro.dataflow import compile as dataflow_compile

    def kernel(table, idx, w):
        return jnp.tanh(table[idx] * w) + 1.0

    table = jnp.arange(4096, dtype=jnp.float32)
    idx = jnp.arange(0, 4096, 16, dtype=jnp.int32)
    w = jnp.float32(1.5)
    compiled = dataflow_compile(kernel, table, idx, w, stream_argnums=(1,))
    shape = (f"stages={compiled.num_stages};"
             f"chans={compiled.schedule.num_channels}")
    rows = []
    for backend in ("xla", "sequential", "emulated"):
        us = _time(lambda t, i, w: compiled(t, i, w, backend=backend),
                   table, idx, w)
        rows.append((f"dataflow_{backend}", us, shape))
    return rows


def all_rows() -> list[tuple]:
    return (bench_matmul() + bench_attention() + bench_decode()
            + bench_spmv() + bench_dataflow_driver())


def main() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in all_rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

"""Resolution-engine A/B smoke (``run.py engine``).

Runs the same full-Table-I-scale resolution twice — once per engine
backend (``numpy`` / ``jax``) — asserts the cycle counts are
bit-identical, and times the two hot resolution kernels the engine
ports (the wavefront solver's running max and the N-way LRU cache
replay) head to head at the same scale.  The result lands in
``BENCH_sim.json`` under ``engine``:

* ``identical`` — jax-vs-numpy cycle identity (``bench_trend.py``
  hard-fails on ``False``);
* per-backend wall and per-phase walls (effect / replay / fold /
  solve) for the end-to-end run;
* ``running_max`` — scalar ``np.maximum.accumulate`` vs the blocked
  dominated-bound form vs jitted ``lax.cummax`` on the solver's
  trending-down finish-time shape;
* ``nway_replay`` — the numpy segmented-scan replay vs the jitted JAX
  scan on one cached-geometry trace.

On a machine with an accelerator backend the jax columns are the
headline; on the CPU-only container the blocked numpy form is the one
that moves (see ``docs/engine.md`` for why XLA:CPU loses the dispatch
race at this arithmetic intensity).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import engine as _eng
from repro.core.simulator import (MemAccess, SimStage,
                                  simulate_dataflow_many,
                                  standard_memory_models)

from .sweep import BENCH_PATH, update_bench

#: Table-I spmv iteration count — the full-scale reference workload.
N_FULL = 4_194_304


def _pipeline(n: int) -> list[SimStage]:
    rng = np.random.default_rng(0)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("idx", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", rng.integers(0, 4 << 20, n) * 4),
                           MemAccess("w", rng.integers(0, 4 << 20, n) * 4)]),
        SimStage("fma", ii=6, latency=8),
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", np.arange(n) * 4,
                                     is_store=True)]),
    ]


def _bench_running_max(captured: list[np.ndarray]) -> dict:
    """The wavefront solver's running-max sweep on the *actual* arrays
    the full-scale solve produced (captured during the numpy backend
    run), three ways: the pre-engine scalar accumulate, the blocked
    dominated-bound form the numpy backend now uses, and the jitted
    ``lax.cummax``.  Best-of-3 per variant."""
    out: dict = {"arrays": len(captured),
                 "elems": int(sum(a.shape[0] for a in captured))}
    if not captured:
        return out
    B = _eng._RMAX_BLOCK
    blocks = needed = 0
    for a in captured:
        nb = a.shape[0] // B
        if nb < 2:
            continue
        M = a[: nb * B].reshape(nb, B).max(axis=1)
        C = np.maximum.accumulate(M)
        blocks += nb
        needed += 1 + int(np.count_nonzero(M[1:] > C[:-1]))
    out["dominated_frac"] = 1 - needed / max(1, blocks)
    want = [np.maximum.accumulate(a) for a in captured]

    def best_of(f, reps: int = 3) -> float:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            got = [f(a.copy()) for a in captured]
            best = min(best, time.perf_counter() - t0)
        for g, w in zip(got, want):
            assert np.array_equal(np.asarray(g), w)
        return best

    def scalar(a):
        np.maximum.accumulate(a, out=a)
        return a

    out["scalar_s"] = best_of(scalar)
    out["blocked_s"] = best_of(_eng._running_max_np)
    out["blocked_speedup"] = out["scalar_s"] / max(1e-9, out["blocked_s"])
    if _eng.jax_modules():
        with _eng.use("jax"):
            _eng.running_max(captured[0].copy())  # pay the jit compile
            out["jax_s"] = best_of(_eng.running_max)
        out["jax_speedup_vs_scalar"] = \
            out["scalar_s"] / max(1e-9, out["jax_s"])
    return out


def _bench_nway_replay(n: int, ways: int = 8) -> dict:
    """One cached geometry's chunk replay (the ``_lookup_nway``
    segmented scan) numpy vs jax, identical hit flags asserted.
    Benchmarked at ``ways > 2``: the 2-way geometries take the
    closed-form ``_lookup2`` path that never reaches the scan."""
    from repro.core.simulator import BatchedCacheSim, CacheConfig
    cfg = CacheConfig(size_bytes=64 << 10, line_bytes=32, ways=ways)
    rng = np.random.default_rng(2)
    addrs = rng.integers(0, 4 << 20, n) * 4
    out: dict = {"n": n, "ways": ways}
    sim = BatchedCacheSim(cfg)
    t0 = time.perf_counter()
    h_np = sim.lookup(addrs)
    out["numpy_s"] = time.perf_counter() - t0
    if _eng.jax_modules():
        with _eng.use("jax"):
            sim2 = BatchedCacheSim(cfg)
            h0 = sim2.lookup(addrs[: 1 << 16])  # pay the jit compile
            sim3 = BatchedCacheSim(cfg)
            t0 = time.perf_counter()
            h_jx = sim3.lookup(addrs)
            out["jax_s"] = time.perf_counter() - t0
        assert np.array_equal(np.asarray(h_jx), h_np)
        assert np.array_equal(np.asarray(h0), h_np[: 1 << 16])
        out["jax_speedup"] = out["numpy_s"] / max(1e-9, out["jax_s"])
    return out


def measure_engine(n: int = N_FULL) -> dict:
    out: dict = {"n_iters": n, "auto_engine": _eng.current(),
                 "jax_available": bool(_eng.jax_modules())}
    stages = _pipeline(n)
    mems = standard_memory_models()
    cycles: dict[str, int] = {}
    backends = ["numpy"] + (["jax"] if _eng.jax_modules() else [])
    # capture the solver's real running-max inputs during the numpy
    # run so the kernel A/B below runs on the workload's actual shape
    captured: list[np.ndarray] = []
    orig_rmax = _eng._running_max_np

    def capture(a):
        if len(captured) < 8 and a.shape[0] >= 2 * _eng._RMAX_BLOCK:
            captured.append(a.copy())
        return orig_rmax(a)

    for eng in backends:
        _eng.reset_walls()
        _eng._running_max_np = capture if eng == "numpy" else orig_rmax
        try:
            t0 = time.perf_counter()
            r = simulate_dataflow_many(
                stages, {"ACP+64KB": mems["ACP+64KB"]()}, n,
                fifo_depths=(64,), collect_stalls=False,
                use_rescache=False, engine=eng)
            wall = time.perf_counter() - t0
        finally:
            _eng._running_max_np = orig_rmax
        key = next(iter(r))
        cycles[eng] = r[key].cycles
        out[eng] = {"wall_s": wall, "phases": _eng.walls(),
                    "cycles": r[key].cycles}
        _eng.reset_walls()
    out["identical"] = len(set(cycles.values())) == 1
    out["running_max"] = _bench_running_max(captured)
    out["nway_replay"] = _bench_nway_replay(n)
    return out


def main(n: int = N_FULL, out_path: str = BENCH_PATH) -> dict:
    res = measure_engine(n)
    assert res["identical"], (
        "engine backends disagree on cycle counts: "
        + ", ".join(f"{k}={v['cycles']}" for k, v in res.items()
                    if isinstance(v, dict) and "cycles" in v))
    update_bench("engine", res, out_path)
    import json
    print(json.dumps(res, indent=1, default=float))
    return res


if __name__ == "__main__":
    main()

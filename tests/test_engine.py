"""Tests for the backend-switchable resolution engine (repro.core.engine):
kernel parity (numpy vs jax, bit-exact), the fused effect+replay pass,
cycle-exactness across engines × execution modes vs the scalar
reference, effect-record persistence, and the per-phase wall accounting.
"""

import os

import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import rescache as rc
from repro.core.simulator import (
    BatchedCacheSim, CacheConfig, MemAccess, SimStage, _resolve_fused,
    _SharedResolver, acp, acp_cache, compose_stacks, hp_cache,
    simulate_dataflow, simulate_dataflow_many,
)

HAVE_JAX = eng.jax_modules() is not None
needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not importable")


@pytest.fixture(autouse=True)
def _clean_engine():
    """Every test starts from the env-driven default and leaves no
    forced selection or wall residue behind."""
    eng.select(None)
    eng.reset_walls()
    yield
    eng.select(None)
    eng.reset_walls()


# ---------------------------------------------------------------------------
# Selection layer
# ---------------------------------------------------------------------------

def test_env_selection(monkeypatch):
    monkeypatch.setenv("REPRO_ENGINE", "numpy")
    assert eng.current() == "numpy"
    monkeypatch.setenv("REPRO_ENGINE", "nonsense")
    assert eng.current() in ("numpy", "jax")  # falls back to auto
    monkeypatch.delenv("REPRO_ENGINE")
    assert eng.current() in ("numpy", "jax")


def test_select_and_use_override(monkeypatch):
    monkeypatch.setenv("REPRO_ENGINE", "numpy")
    eng.select("numpy")
    assert eng.current() == "numpy"
    if HAVE_JAX:
        with eng.use("jax"):
            assert eng.current() == "jax"
            assert eng._explicit()
            with eng.use("numpy"):  # nesting restores the outer override
                assert eng.current() == "numpy"
            assert eng.current() == "jax"
    assert eng.current() == "numpy"
    with pytest.raises(ValueError):
        eng.select("cuda")
    with pytest.raises(ValueError):
        with eng.use("tpu"):
            pass


def test_jax_without_jax_degrades(monkeypatch):
    """An explicit jax selection on a host without jax must degrade to
    numpy, not crash."""
    monkeypatch.setattr(eng, "_jax_mods", False)
    eng.select("jax")
    assert eng.current() == "numpy"


# ---------------------------------------------------------------------------
# Per-phase wall accounting
# ---------------------------------------------------------------------------

def test_walls_accumulate_and_merge():
    with eng.phase("replay"):
        pass
    with eng.phase("replay"):
        pass
    with eng.phase("solve"):
        pass
    w = eng.walls()
    assert set(w) == {"replay", "solve"} and all(v >= 0 for v in w.values())
    eng.merge_walls({"replay": 1.5, "fold": 2.0})
    w2 = eng.walls()
    assert w2["replay"] >= 1.5 and w2["fold"] == 2.0
    eng.merge_walls(None)  # tolerated: workers may report no walls
    eng.reset_walls()
    assert eng.walls() == {}


# ---------------------------------------------------------------------------
# running_max parity
# ---------------------------------------------------------------------------

def _rmax_cases():
    rng = np.random.default_rng(0)
    B = eng._RMAX_BLOCK
    yield np.arange(10, dtype=np.int64)                    # tiny
    yield rng.integers(0, 1 << 40, B - 1)                  # below one block
    yield rng.integers(0, 1 << 40, 2 * B)                  # exact blocks
    yield rng.integers(0, 1 << 40, 5 * B + 137)            # ragged tail
    yield np.arange(4 * B, dtype=np.int64)                 # worst case: rising
    yield -np.arange(4 * B, dtype=np.int64)                # best case: falling
    yield np.full(3 * B + 7, 42, dtype=np.int64)           # constant
    a = rng.integers(0, 1 << 20, 3 * B).astype(np.int32)   # int32 input
    yield a
    big = rng.integers(1 << 33, 1 << 40, 2 * B + 11)       # tags > 2**31
    yield big


@pytest.mark.parametrize("i,a", list(enumerate(_rmax_cases())))
def test_running_max_np_parity(i, a):
    want = np.maximum.accumulate(a)
    got = eng._running_max_np(a.copy())
    assert got.dtype == a.dtype
    assert np.array_equal(got, want), f"case {i}"


def test_running_max_noncontiguous_falls_back():
    rng = np.random.default_rng(1)
    base = rng.integers(0, 1 << 30, 8 * eng._RMAX_BLOCK)
    view = base[::2]  # non-contiguous: must take the plain accumulate
    assert not view.flags.c_contiguous
    want = np.maximum.accumulate(view.copy())
    assert np.array_equal(eng._running_max_np(view), want)


@needs_jax
def test_running_max_jax_parity():
    rng = np.random.default_rng(2)
    for n in (eng.JIT_MIN_ELEMS, eng.JIT_MIN_ELEMS * 3 + 17):
        a = rng.integers(0, 1 << 40, n)  # > 2**31: x64 must hold
        want = np.maximum.accumulate(a)
        with eng.use("jax"):
            got = eng.running_max(a.copy())
        assert got.dtype == np.int64
        assert np.array_equal(got, want)


@needs_jax
def test_pallas_running_max_interpret():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 40, 5000)
    try:
        got = eng.pallas_running_max(a, block=512, interpret=True)
    except Exception as e:  # pragma: no cover - lowering gap on this host
        pytest.skip(f"pallas interpret unavailable: {e}")
    assert np.array_equal(got, np.maximum.accumulate(a))


# ---------------------------------------------------------------------------
# N-way replay core parity (numpy vs jax, adversarial geometries)
# ---------------------------------------------------------------------------

def _addr_patterns(sim: BatchedCacheSim, n: int, seed: int):
    """Adversarial address streams for one geometry: single-set
    thrashing, a cyclic ways+1 working set (classic LRU worst case),
    skewed reuse, segment-boundary runs, and uniform random."""
    rng = np.random.default_rng(seed)
    lb, ns, ways = sim.cfg.line_bytes, sim.n_sets, sim.cfg.ways
    stride = lb * ns  # same set, new tag
    yield "one_set", (rng.integers(0, 3 * ways, n) * stride)
    cyc = (np.arange(n) % (ways + 1)) * stride
    yield "cyclic", cyc
    zipf = np.minimum(rng.zipf(1.3, n), 4 * ways) * lb
    yield "skewed", zipf
    runs = np.repeat(rng.integers(0, 8 * ways, max(1, n // 7)), 7)[:n]
    yield "runs", runs * lb
    yield "uniform", rng.integers(0, 1 << 22, n) * lb


@needs_jax
@pytest.mark.parametrize("ways", [3, 4, 8, 16])
def test_nway_jax_parity(ways):
    cfg = CacheConfig(size_bytes=ways * 16 * 32, line_bytes=32, ways=ways)
    probe = BatchedCacheSim(cfg)
    for name, addrs in _addr_patterns(probe, 4000, seed=ways):
        s_np = BatchedCacheSim(cfg)
        eng.select("numpy")
        h_np = s_np.lookup(addrs)
        st_np = s_np.export_stacks()
        s_jx = BatchedCacheSim(cfg)
        eng.select("jax")  # explicit: bypasses the size threshold
        h_jx = s_jx.lookup(addrs)
        st_jx = s_jx.export_stacks()
        eng.select(None)
        assert np.array_equal(h_jx, h_np), (ways, name)
        assert np.array_equal(st_jx[0], st_np[0]), (ways, name)
        assert st_jx[1] == st_np[1]


@needs_jax
def test_nway_jax_parity_large_tags():
    """Carried tags past 2**31 survive the jax path (x64 regression)."""
    cfg = CacheConfig(size_bytes=4 * 4 * 32, line_bytes=32, ways=4)
    probe = BatchedCacheSim(cfg)
    stride = probe.cfg.line_bytes * probe.n_sets
    rng = np.random.default_rng(9)
    addrs = (rng.integers(1 << 33, 1 << 36, 2000)) * stride
    s_np, s_jx = BatchedCacheSim(cfg), BatchedCacheSim(cfg)
    eng.select("numpy")
    h_np = s_np.lookup(addrs)
    eng.select("jax")
    h_jx = s_jx.lookup(addrs)
    eng.select(None)
    assert s_np._max_tag > (1 << 31)
    assert np.array_equal(h_jx, h_np)
    assert np.array_equal(s_jx.export_stacks()[0], s_np.export_stacks()[0])


# ---------------------------------------------------------------------------
# Fused effect+replay correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ways", [2, 4, 8])
def test_fused_lookup_matches_warm_lookup(ways):
    """fused_lookup + _resolve_fused against ANY incoming state equals
    a plain warm lookup, and the composed outgoing state matches the
    sequential one — the theorem the single-pass executor rests on."""
    cfg = CacheConfig(size_bytes=ways * 8 * 32, line_bytes=32, ways=ways)
    rng = np.random.default_rng(ways)
    warm = rng.integers(0, 1 << 14, 3000) * 4
    chunk = rng.integers(0, 1 << 14, 2500) * 4

    ref = BatchedCacheSim(cfg)
    ref.lookup(warm)
    incoming = ref.export_stacks()
    want_h = ref.lookup(chunk)
    want_out = ref.export_stacks()

    fus = BatchedCacheSim(cfg)
    h, amb = fus.fused_lookup(chunk)
    own = fus.export_stacks()
    # empty-incoming flags are exact as-is
    fresh = BatchedCacheSim(cfg)
    assert np.array_equal(h, fresh.lookup(chunk))
    # patched against the warm incoming state
    h = h.copy()
    if len(amb.idx):
        h[amb.idx] = _resolve_fused(amb, incoming[0], ways)
    assert np.array_equal(h, want_h)
    out = compose_stacks(incoming[0], own[0])
    assert np.array_equal(out, want_out[0])
    assert max(incoming[1], own[1]) == want_out[1]


def _two_stage(n, seed, store_heavy=False):
    rng = np.random.default_rng(seed)
    acc = [MemAccess("x", rng.integers(0, 1 << 16, n) * 4)]
    if store_heavy:
        acc.append(MemAccess("y", rng.integers(0, 1 << 16, n) * 4,
                             is_store=True))
    return [SimStage("ld", ii=1, latency=2, accesses=acc),
            SimStage("fma", ii=2, latency=4)]


@pytest.mark.parametrize("store_heavy", [False, True])
def test_chunk_effects_fused_equals_replay(store_heavy):
    """The fused single-pass resolver chunk chain (effects →
    finalize_replay) reproduces the two-pass resolver's deltas, hit
    flags, and cache state, chunk by chunk — including write-around
    stores that bypass the cache."""
    n, c = 3000, 1000
    mems = {"A": acp_cache(), "H": hp_cache()}
    seq = _SharedResolver(_two_stage(n, 4, store_heavy), mems, seed=0)
    fus = _SharedResolver(_two_stage(n, 4, store_heavy),
                          {"A": acp_cache(), "H": hp_cache()}, seed=0)
    states = None
    for lo in range(0, n, c):
        hi = min(n, lo + c)
        d_seq = seq.replay(lo, hi)
        eff, na = fus.chunk_effects_fused(lo, hi)
        assert set(eff) == set(fus.caches)
        assert na == fus._n_addrs
        d_fus = fus.finalize_replay(states)
        assert d_fus == d_seq
        for key in seq.caches:
            assert np.array_equal(fus._hits_by_key[key],
                                  seq._hits_by_key[key]), (lo, key)
            assert np.array_equal(fus.caches[key].export_stacks()[0],
                                  seq.caches[key].export_stacks()[0])
        states = {key: fus.caches[key].export_stacks()
                  for key in fus.caches}


def test_chunk_effects_fused_matches_chunk_effects():
    """Phase A's output (the own-effect monoid) is unchanged by the
    fusion — the persisted effect records are the same either way."""
    n = 2000
    r1 = _SharedResolver(_two_stage(n, 5), {"A": acp_cache()}, seed=0)
    r2 = _SharedResolver(_two_stage(n, 5), {"A": acp_cache()}, seed=0)
    e1, na1 = r1.chunk_effects(0, n)
    e2, na2 = r2.chunk_effects_fused(0, n)
    assert na1 == na2 and set(e1) == set(e2)
    for k in e1:
        assert np.array_equal(e1[k][0], e2[k][0])
        assert e1[k][1] == e2[k][1]


# ---------------------------------------------------------------------------
# Cycle-exactness: engines × execution modes vs the scalar reference
# ---------------------------------------------------------------------------

def _paper_pipeline(n, seed=11):
    rng = np.random.default_rng(seed)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("i", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=3,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 18, n) * 4),
                           MemAccess("w", rng.integers(0, 1 << 12, n) * 4)]),
        SimStage("fma", ii=6, latency=8),
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", np.arange(n) * 4 + (1 << 22),
                                     is_store=True)]),
    ]


def _sig(r):
    return (r.cycles, r.cache_hits, r.cache_misses, r.stage_stall_cycles)


@pytest.fixture()
def small_chunks(tmp_path, monkeypatch):
    d = str(tmp_path / "rescache")
    rc.clear()
    rc.configure(enabled=True, directory=d)
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    yield d
    rc.clear()
    rc.configure(enabled=False)


@pytest.mark.parametrize("mem_mk", [acp, acp_cache, hp_cache])
def test_cycle_exact_engines_vs_reference(mem_mk):
    """numpy and jax streaming engines both equal the scalar reference
    simulator, cycle for cycle, on a paper-shaped pipeline."""
    n = 1500
    stages = _paper_pipeline(n)
    ref = simulate_dataflow(stages, mem_mk(), n, reference=True,
                            use_rescache=False)
    got_np = simulate_dataflow(stages, mem_mk(), n, use_rescache=False,
                               engine="numpy")
    assert _sig(got_np) == _sig(ref)
    if HAVE_JAX:
        got_jx = simulate_dataflow(stages, mem_mk(), n,
                                   use_rescache=False, engine="jax")
        assert _sig(got_jx) == _sig(ref)


@pytest.mark.parametrize(
    "engine",
    ["numpy"] + (["jax"] if HAVE_JAX else []))
def test_cycle_exact_sharded_vs_streaming(small_chunks, engine):
    """The chunk-graph executor (fused effect+replay, engine pinned via
    the job payload) stays bit-identical to streaming on both
    backends."""
    n = 4 * 512
    stages = _paper_pipeline(n)
    mems = {"ACPC": acp_cache(), "HPC": hp_cache()}
    ref = simulate_dataflow_many(
        _paper_pipeline(n), {"ACPC": acp_cache(), "HPC": hp_cache()}, n,
        fifo_depths=(8,), use_rescache=False, engine=engine)
    rc.clear()
    got = simulate_dataflow_many(stages, mems, n, fifo_depths=(8,),
                                 workers=2, engine=engine)
    assert set(got) == set(ref)
    for k in ref:
        assert _sig(got[k]) == _sig(ref[k]), k


def test_cycle_exact_served(small_chunks):
    """Daemon-served resolution equals the library engine under the
    session's default backend (the CI jax lane re-runs this with
    REPRO_ENGINE=jax in the daemon workers' environment)."""
    import contextlib
    import tempfile

    from repro.serve.client import simulate_dataflow_served
    from repro.serve.daemon import ResolutionDaemon

    n = 3 * 512
    stages = _paper_pipeline(n)
    mems = {"ACPC": acp_cache()}
    ref = simulate_dataflow_many(_paper_pipeline(n),
                                 {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), use_rescache=False)
    rc.clear()
    sdir = tempfile.mkdtemp(prefix="serve-")
    d = ResolutionDaemon(address=os.path.join(sdir, "d.sock"), workers=2)
    d.start()
    with contextlib.ExitStack() as st:
        st.callback(d.stop)
        got = simulate_dataflow_served(stages, mems, n, fifo_depths=(8,),
                                       address=d.address)
    for k in ref:
        assert _sig(got[k]) == _sig(ref[k]), k


# ---------------------------------------------------------------------------
# Effect-record persistence (satellite a)
# ---------------------------------------------------------------------------

@pytest.fixture()
def estore(tmp_path):
    d = str(tmp_path / "store")
    rc.clear()
    rc.configure(enabled=True, directory=d)
    yield d
    rc.clear()
    rc.configure(enabled=False)


def _an_effect(seed=0, big=False):
    rng = np.random.default_rng(seed)
    lo, hi = ((1 << 33), (1 << 35)) if big else (0, 1 << 12)
    stacks = rng.integers(lo, hi, (64, 4))
    stacks[rng.random(stacks.shape) < 0.2] = -1
    return np.sort(stacks, axis=1)[:, ::-1].copy(), int(stacks.max())


def test_effect_record_roundtrip(estore):
    key = "ab" * 16
    stacks, mt = _an_effect()
    rc.put_effect(key, 3, (stacks, mt), n_addrs=777)
    got = rc.get_effect(key, 3)
    assert got is not None
    gs, gmt, gna = got
    assert gs.dtype == np.int64 and np.array_equal(gs, stacks)
    assert (gmt, gna) == (mt, 777)
    assert rc.get_effect(key, 4) is None
    assert rc.get_effect("cd" * 16, 3) is None
    c = rc.census()
    assert c["effects"]["count"] == 1 and c["effects"]["bytes"] > 0
    assert c["effects"]["stores"] >= 1 and c["effects"]["hits"] >= 1


def test_effect_record_wide_tags(estore):
    """Tags past 2**31 skip the int32 narrowing and survive exactly."""
    key = "ef" * 16
    stacks, mt = _an_effect(1, big=True)
    rc.put_effect(key, 0, (stacks, mt), n_addrs=5)
    gs, gmt, _ = rc.get_effect(key, 0)
    assert np.array_equal(gs, stacks) and gmt == mt


def test_effect_record_idempotent_and_quarantine(estore):
    key = "12" * 16
    stacks, mt = _an_effect(2)
    rc.put_effect(key, 0, (stacks, mt), n_addrs=9)
    p = os.path.join(estore, f"{key}.e00000.npz")
    mtime = os.path.getmtime(p)
    rc.put_effect(key, 0, (stacks * 0, 0), n_addrs=1)  # same key+idx: kept
    assert os.path.getmtime(p) == mtime
    gs, _, _ = rc.get_effect(key, 0)
    assert np.array_equal(gs, stacks)
    # flip bytes: the checksum catches it, the record is quarantined
    with open(p, "r+b") as f:
        f.seek(60)
        f.write(b"\xff\xff\xff\xff")
    assert rc.get_effect(key, 0) is None
    assert not os.path.exists(p)


def test_gc_collects_orphaned_effects(estore):
    """Effects whose key has no chunk records are pre-v3-style orphans
    for gc; effects alongside live chunk records survive."""
    orphan, live = "aa" * 16, "bb" * 16
    stacks, mt = _an_effect(3)
    rc.put_effect(orphan, 0, (stacks, mt), n_addrs=2)
    rc.put_effect(live, 0, (stacks, mt), n_addrs=2)
    # a minimal chunk record under the live key
    np.savez(os.path.join(estore, f"{live}.c00000.npz"),
             marker=np.zeros(1))
    rep = rc.gc()
    assert not os.path.exists(os.path.join(estore,
                                           f"{orphan}.e00000.npz"))
    assert os.path.exists(os.path.join(estore, f"{live}.e00000.npz"))
    assert rep["orphans_removed"] >= 1


def test_reshard_composes_stored_effects(small_chunks):
    """The tentpole: a re-shard whose chunk records are gone but whose
    effect records survive preloads every chunk's incoming state from
    the store (effect hits observed) and stays bit-identical."""
    import glob

    n = 6 * 512
    stages = _paper_pipeline(n, seed=21)
    ref = simulate_dataflow_many(_paper_pipeline(n, seed=21),
                                 {"A": acp_cache()}, n,
                                 use_rescache=False)
    rc.clear()
    r1 = simulate_dataflow_many(stages, {"A": acp_cache()}, n, workers=2)
    c1 = rc.census()
    assert c1["effects"]["count"] > 0
    for p in glob.glob(os.path.join(small_chunks, "*.c*.npz")):
        os.unlink(p)
    rc.clear()
    rc.configure(enabled=True, directory=small_chunks)
    r2 = simulate_dataflow_many(_paper_pipeline(n, seed=21),
                                {"A": acp_cache()}, n, workers=2)
    c2 = rc.census()
    assert c2["effects"]["hits"] > 0, "master did not preload effects"
    k = ("A", 8)
    assert ref[k].cycles == r1[k].cycles == r2[k].cycles
    assert (ref[k].cache_hits, ref[k].cache_misses) == \
        (r2[k].cache_hits, r2[k].cache_misses)

"""Tests for channels (pack/unpack, FIFO) and the pipeline executors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # noqa: E402 — skips when hypothesis is missing

from repro.core import (CDFG, ChannelSpec, DeviceFIFO, HostFIFO,
                        SystolicPipeline, decouple, partition_cdfg,
                        pipeline_apply_emulated, gpipe_bubble_fraction)


# ---------------------------------------------------------------------------
# ChannelSpec: pack/unpack roundtrip across dtypes/shapes (property test)
# ---------------------------------------------------------------------------

_DTYPES = [jnp.float32, jnp.int32, jnp.uint32, jnp.float16, jnp.bfloat16,
           jnp.int8, jnp.uint8, jnp.int16]


@given(
    st.lists(
        st.tuples(
            st.sampled_from(list(range(len(_DTYPES)))),
            st.lists(st.integers(min_value=1, max_value=5), min_size=0,
                     max_size=3),
        ),
        min_size=1, max_size=4),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=40, deadline=None)
def test_channel_roundtrip(leaf_specs, seed):
    rng = np.random.default_rng(seed)
    leaves = []
    for di, shape in leaf_specs:
        dt = _DTYPES[di]
        x = rng.integers(0, 100, size=shape)
        leaves.append(jnp.asarray(x).astype(dt))
    payload = tuple(leaves)
    spec = ChannelSpec.from_example(payload)
    word = spec.pack(payload, pad_to=spec.width + 3)
    got = spec.unpack(word)
    for a, b in zip(jax.tree_util.tree_leaves(payload),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_channel_roundtrip_f64_under_x64():
    payload = (jnp.arange(3, dtype=jnp.float32),
               jnp.asarray([1, 2], dtype=jnp.int32))
    spec = ChannelSpec.from_example(payload)
    got = spec.unpack(spec.pack(payload))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(payload[0]))


# ---------------------------------------------------------------------------
# DeviceFIFO semantics (functional bounded queue)
# ---------------------------------------------------------------------------

def test_device_fifo_push_pop_order():
    f = DeviceFIFO(depth=3, width=2)
    s = f.init()
    for i in range(3):
        s = f.push(s, jnp.full((2,), i, jnp.uint32))
    assert int(s.count) == 3
    assert not bool(f.can_push(s))
    # push on full is a no-op
    s2 = f.push(s, jnp.full((2,), 99, jnp.uint32))
    assert int(s2.count) == 3
    outs = []
    for _ in range(3):
        w, s = f.pop(s)
        outs.append(int(w[0]))
    assert outs == [0, 1, 2]
    assert not bool(f.can_pop(s))
    # pop on empty is a no-op returning stale data but count stays 0
    _, s3 = f.pop(s)
    assert int(s3.count) == 0


def test_device_fifo_wraparound():
    f = DeviceFIFO(depth=2, width=1)
    s = f.init()
    s = f.push(s, jnp.asarray([1], jnp.uint32))
    s = f.push(s, jnp.asarray([2], jnp.uint32))
    w, s = f.pop(s)
    assert int(w[0]) == 1
    s = f.push(s, jnp.asarray([3], jnp.uint32))
    w, s = f.pop(s)
    assert int(w[0]) == 2
    w, s = f.pop(s)
    assert int(w[0]) == 3


def test_device_fifo_inside_scan():
    f = DeviceFIFO(depth=4, width=1)

    def step(s, x):
        s = f.push(s, x[None].astype(jnp.uint32))
        w, s = f.pop(s)
        return s, w[0]

    xs = jnp.arange(10, dtype=jnp.uint32)
    _, ys = jax.lax.scan(step, f.init(), xs)
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(xs))


# ---------------------------------------------------------------------------
# HostFIFO (input-pipeline decoupling)
# ---------------------------------------------------------------------------

def test_host_fifo_streams_everything():
    src = iter(range(100))
    out = list(HostFIFO(src, depth=8))
    assert out == list(range(100))


def test_host_fifo_propagates_errors():
    def bad():
        yield 1
        raise RuntimeError("producer died")

    f = HostFIFO(bad(), depth=2)
    assert next(f) == 1
    with pytest.raises(RuntimeError, match="producer died"):
        next(f)


# ---------------------------------------------------------------------------
# SystolicPipeline: stream semantics == per-microbatch direct calls
# ---------------------------------------------------------------------------

def _mk_pipe(fn, *example, stream_argnums=(1,)):
    cdfg = CDFG.from_function(fn, *example)
    part = partition_cdfg(cdfg)
    prog = decouple(part)
    return SystolicPipeline(prog, stream_argnums=stream_argnums)


def test_systolic_matches_direct():
    def kernel(x, idx, w):
        a = x[idx]
        b = a * w
        return jnp.tanh(b) + 1.0

    x = jnp.arange(64, dtype=jnp.float32)
    T = 7
    idxs = jnp.stack([(jnp.arange(8) * (t + 1)) % 64 for t in range(T)])
    w = jnp.float32(0.5)
    pipe = _mk_pipe(kernel, x, idxs[0], w)
    outs = pipe.run_emulated(x, idxs, w)
    ref = jnp.stack([kernel(x, idxs[t], w) for t in range(T)])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               rtol=1e-6)


def test_systolic_multi_stream_args():
    def kernel(table, idx, scale):
        return table[idx] * scale

    table = jnp.arange(32, dtype=jnp.float32)
    T = 4
    idxs = jnp.stack([jnp.arange(4) + t for t in range(T)])
    scales = jnp.arange(1., T + 1.)
    pipe = _mk_pipe(kernel, table, idxs[0], scales[0],
                    stream_argnums=(1, 2))
    outs = pipe.run_emulated(table, idxs, scales)
    ref = jnp.stack([kernel(table, idxs[t], scales[t]) for t in range(T)])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref))


# ---------------------------------------------------------------------------
# Homogeneous pipeline (classic PP) — emulated schedule
# ---------------------------------------------------------------------------

def test_pipeline_apply_emulated_matches_sequential():
    S, M, D = 4, 6, 8
    rng = np.random.default_rng(0)
    params = jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.1)
    mbs = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    got = pipeline_apply_emulated(stage_fn, params, mbs, num_stages=S)

    def full(x):
        for s in range(S):
            x = stage_fn(params[s], x)
        return x

    ref = jnp.stack([full(mbs[m]) for m in range(M)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_bubble_fraction():
    assert gpipe_bubble_fraction(1, 8) == 0.0
    assert gpipe_bubble_fraction(4, 4) == pytest.approx(3 / 7)
    # more microbatches -> smaller bubble (the template's throughput story)
    assert (gpipe_bubble_fraction(4, 64)
            < gpipe_bubble_fraction(4, 8)
            < gpipe_bubble_fraction(4, 4))


# ---------------------------------------------------------------------------
# Property: systolic streaming == per-microbatch direct calls, for random
# programs (random op chains, random stream lengths)
# ---------------------------------------------------------------------------

@given(
    st.lists(st.sampled_from(["gather", "mul", "tanh", "add", "exp"]),
             min_size=1, max_size=6),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=15, deadline=None)
def test_systolic_property_random_programs(ops, T, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))

    def fn(table, idx):
        v = table[idx].astype(jnp.float32)
        for op in ops:
            if op == "gather":
                j = jnp.clip(jnp.abs(v).astype(jnp.int32) % 32, 0, 31)
                v = table[j]
            elif op == "mul":
                v = v * 1.25
            elif op == "tanh":
                v = jnp.tanh(v)
            elif op == "add":
                v = v + 0.5
            elif op == "exp":
                v = jnp.exp(jnp.clip(v, -4, 4))
        return v

    idxs = jnp.asarray(rng.integers(0, 32, size=(T, 8)))
    from repro.core import CDFG, decouple, partition_cdfg
    cdfg = CDFG.from_function(fn, table, idxs[0])
    part = partition_cdfg(cdfg)
    prog = decouple(part)
    pipe = SystolicPipeline(prog, stream_argnums=(1,))
    outs = pipe.run_emulated(table, idxs)
    ref = jnp.stack([fn(table, idxs[t]) for t in range(T)])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)

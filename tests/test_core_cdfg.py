"""Unit + property tests for the CDFG front end and Algorithm 1."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # noqa: E402 — skips when hypothesis is missing

from repro.core import (CDFG, LatencyModel, partition_cdfg, decouple,
                        run_stages_sequential, decoupled_call)


def _fig1_kernel(x, idx, w):
    """The paper's Fig. 1 flavor: gather → fp multiply → elementwise."""
    a = x[idx]
    b = a * w
    c = jnp.tanh(b)
    return c + 1.0


def test_classification_memory_and_long():
    x = jnp.arange(64, dtype=jnp.float32)
    idx = jnp.arange(8)
    cdfg = CDFG.from_function(_fig1_kernel, x, idx, jnp.float32(2.0))
    mems = [n.prim for n in cdfg.memory_nodes]
    assert "gather" in mems
    longs = {n.prim for n in cdfg.long_nodes}
    assert {"mul", "tanh"} <= longs


def test_region_discovery_names_buffers():
    x = jnp.arange(64, dtype=jnp.float32)
    idx = jnp.arange(8)
    cdfg = CDFG.from_function(_fig1_kernel, x, idx, jnp.float32(2.0))
    (g,) = [n for n in cdfg.nodes if n.prim == "gather"]
    assert g.region == "arg0"


def test_algorithm1_cuts_after_mem_and_long():
    x = jnp.arange(64, dtype=jnp.float32)
    idx = jnp.arange(8)
    cdfg = CDFG.from_function(_fig1_kernel, x, idx, jnp.float32(2.0))
    part = partition_cdfg(cdfg)
    # Algorithm 1: stage boundary after the gather, after the mul, after tanh
    assert part.num_stages == 4
    # the gather's stage is cut exactly at the gather
    s_gather = part.stage_of_node[
        next(n.id for n in cdfg.nodes if n.prim == "gather")]
    last_node = max(part.stages[s_gather].node_ids)
    assert cdfg.node(last_node).prim == "gather"


def test_fused_policy_single_stage():
    x = jnp.arange(64, dtype=jnp.float32)
    cdfg = CDFG.from_function(_fig1_kernel, x, jnp.arange(8), jnp.float32(2.))
    part = partition_cdfg(cdfg, policy="fused")
    assert part.num_stages == 1
    assert not part.channels


def test_maximal_policy_one_node_per_stage():
    x = jnp.arange(64, dtype=jnp.float32)
    cdfg = CDFG.from_function(_fig1_kernel, x, jnp.arange(8), jnp.float32(2.))
    part = partition_cdfg(cdfg, policy="maximal", duplicate_cheap=False)
    assert part.num_stages == len(cdfg.nodes)


def test_scc_never_split_loop_view():
    """Loop-carried accumulation must stay in one stage (paper §III)."""

    def body(carry, x):
        acc = carry
        y = jnp.exp(x)        # long op NOT in the cycle
        acc = acc * 0.9 + y   # mul+add cycle through carry
        return acc

    cdfg = CDFG.from_loop_body(body, jnp.float32(0.0), jnp.float32(1.0))
    part = partition_cdfg(cdfg)
    # find the SCC members (mul & add on the carry path)
    import networkx as nx
    g = nx.DiGraph()
    g.add_nodes_from(n.id for n in cdfg.nodes)
    g.add_edges_from((e.src, e.dst) for e in cdfg.edges)
    sccs = [c for c in nx.strongly_connected_components(g) if len(c) > 1]
    assert sccs, "expected a loop-carried SCC"
    for comp in sccs:
        stages = {part.stage_of_node[n] for n in comp}
        assert len(stages) == 1, "SCC split across stages"


def test_memory_order_edges_serialize_stores():
    def k(buf, idx, v):
        buf = buf.at[idx].set(v)      # store
        a = buf[idx + 1]              # load after store: must be ordered
        return a

    buf = jnp.zeros(16)
    cdfg = CDFG.from_function(k, buf, jnp.int32(3), jnp.float32(1.0))
    mem_edges = [e for e in cdfg.edges if e.kind == "mem"]
    assert mem_edges, "store->load ordering edge missing"


def test_channels_only_cross_forward():
    x = jnp.arange(64, dtype=jnp.float32)
    cdfg = CDFG.from_function(_fig1_kernel, x, jnp.arange(8), jnp.float32(2.))
    part = partition_cdfg(cdfg)
    for c in part.channels:
        assert c.src_stage < c.dst_stage


def test_every_node_in_exactly_one_stage():
    x = jnp.arange(64, dtype=jnp.float32)
    cdfg = CDFG.from_function(_fig1_kernel, x, jnp.arange(8), jnp.float32(2.))
    part = partition_cdfg(cdfg)
    seen = [n for s in part.stages for n in s.node_ids]
    assert sorted(seen) == sorted(n.id for n in cdfg.nodes)
    assert len(seen) == len(set(seen))


def test_latency_model_override():
    lm = LatencyModel(table={"mul": 1}, long_threshold=1)
    assert not lm.is_long("mul")
    assert lm.is_long("dot_general")


# ---------------------------------------------------------------------------
# Property tests: decoupled program == direct execution on random programs
# ---------------------------------------------------------------------------

@st.composite
def _random_program(draw):
    """Build a random straight-line program mixing memory/long/cheap ops."""
    n_ops = draw(st.integers(min_value=1, max_value=8))
    ops = draw(st.lists(
        st.sampled_from(["gather", "mul", "tanh", "add", "exp", "sub"]),
        min_size=n_ops, max_size=n_ops))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return ops, seed


@given(_random_program())
@settings(max_examples=25, deadline=None)
def test_decoupled_equals_direct(prog_spec):
    ops, seed = prog_spec
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    idx0 = jnp.asarray(rng.integers(0, 32, size=(8,)))

    def fn(table, idx):
        v = table[idx].astype(jnp.float32)
        for op in ops:
            if op == "gather":
                j = jnp.clip(jnp.abs(v).astype(jnp.int32) % 32, 0, 31)
                v = table[j]
            elif op == "mul":
                v = v * 1.5
            elif op == "tanh":
                v = jnp.tanh(v)
            elif op == "add":
                v = v + 0.25
            elif op == "exp":
                v = jnp.exp(jnp.clip(v, -5, 5))
            elif op == "sub":
                v = v - 0.125
        return v

    ref = fn(table, idx0)
    for policy in ("paper", "fused", "maximal", "cost_aware"):
        staged = decoupled_call(fn, table, idx0, policy=policy)
        got = staged(table, idx0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref)), policy


@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=20, deadline=None)
def test_partition_invariants_random(n_extra, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))

    def fn(x, idx, w):
        v = x[idx]
        for i in range(n_extra):
            v = jnp.tanh(v @ w) if i % 2 == 0 else v * 1.1
        return v.sum()

    x = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, size=(8,)))
    cdfg = CDFG.from_function(fn, x, idx, w)
    part = partition_cdfg(cdfg)
    # invariant 1: stages partition the node set
    seen = sorted(n for s in part.stages for n in s.node_ids)
    assert seen == sorted(n.id for n in cdfg.nodes)
    # invariant 2: data flows forward only
    for c in part.channels:
        assert c.src_stage < c.dst_stage
    # invariant 3: every memory op's stage ends at a mem/long node boundary
    for s in part.stages[:-1]:
        last = cdfg.node(max(s.node_ids))
        assert last.is_memory or last.is_long or s.has_long or s.has_memory
    # invariant 4: decoupled execution matches
    prog = decouple(part)
    got = run_stages_sequential(prog, x, idx, w)
    np.testing.assert_array_equal(np.asarray(got[0]),
                                  np.asarray(fn(x, idx, w)))

"""Tests for the partition-space DSE pass (repro.dataflow.dse), the
partition-rewrite correctness fixes that ride with it, and the Fig. 2
schedule capture's move onto the resolution layer."""

import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rescache as rc
from repro.core.cdfg import CDFG, Node, Edge
from repro.core.partition import (derive_channels,
                                  duplicate_cheap_rewrite, fused_plan,
                                  materialize, maximal_plan,
                                  merge_costly_boundaries, merge_move,
                                  partition_cdfg,
                                  plan_is_legal, plan_signature, split_move,
                                  stage_groups)
from repro.core.simulator import (MemAccess, SimStage, acp, acp_cache,
                                  simulate_dataflow)
from repro.dataflow import (ResourceConstraints, compile as dcompile,
                            enumerate_plans, explore_plans)
from repro.dataflow.dse import (constraint_violation, partition_resources,
                                sim_stages_for_partition, traces_by_node)


@pytest.fixture()
def rescache_on():
    """The DSE sharing tests need the cache enabled (other test modules
    may have disabled it globally); conftest already isolates the
    directory."""
    rc.clear()
    rc.configure(enabled=True)
    yield
    rc.clear()
    rc.configure(enabled=False)


def _spmv_like():
    def body(acc, j, vals, cols, xv):
        return acc + vals[j] * xv[cols[j]]

    vals = jnp.arange(64, dtype=jnp.float32)
    cols = jnp.arange(64) % 16
    xv = jnp.arange(16, dtype=jnp.float32)
    args = (jnp.float32(0.0), jnp.int32(0), vals, cols, xv)
    return body, args


# ---------------------------------------------------------------------------
# Satellite bugfix 1: token edges count as feeders for §III-B1 duplication
# ---------------------------------------------------------------------------


def _fake_cdfg(nodes, edges):
    """A minimal CDFG stand-in for partition-level unit tests (the
    partitioner never touches eqns or jaxpr internals)."""
    cdfg = types.SimpleNamespace(nodes=nodes, edges=edges)
    by_id = {n.id: n for n in nodes}
    cdfg.node = lambda nid: by_id[nid]
    return cdfg


def _node(nid, prim, *, memory=False, latency=1, region=None):
    return Node(id=nid, prim=prim, eqn=None, is_memory=memory,
                latency=latency, region=region)


class _FakeVar:
    """Hashable jaxpr-var stand-in with just enough aval for channels."""

    def __init__(self):
        self.aval = types.SimpleNamespace(shape=(),
                                          dtype=np.dtype(np.float32))


def _var():
    return _FakeVar()


def test_token_edge_feeder_blocks_duplication():
    """A cheap node whose only input is an ordering token (the loop
    counter's carry self-edge) must NOT be duplicated: the replica in
    the consumer stage would silently drop the iteration ordering."""
    v01, v02 = _var(), _var()
    nodes = [_node(0, "add"), _node(1, "gather", memory=True, latency=2,
                                    region="t"), _node(2, "add")]
    edges = [
        Edge(0, 0, None, "carry"),   # the token feeder under test
        Edge(0, 2, v02, "data"),     # cross-stage consumer
        Edge(1, 2, v01, "data"),
    ]
    cdfg = _fake_cdfg(nodes, edges)
    plan = stage_groups(cdfg)
    part = materialize(cdfg, plan)
    assert part.stage_of_node[0] != part.stage_of_node[2]  # cross-stage
    duplicate_cheap_rewrite(part)
    assert 0 not in part.duplicated, \
        "token-fed cheap node was duplicated (ordering dropped)"
    # the identical graph minus the token edge IS duplicable (control)
    cdfg2 = _fake_cdfg(nodes, edges[1:])
    part2 = materialize(cdfg2, stage_groups(cdfg2))
    duplicate_cheap_rewrite(part2)
    assert 0 in part2.duplicated


# ---------------------------------------------------------------------------
# Satellite bugfix 2: duplicated producers' latencies fold into consumers
# ---------------------------------------------------------------------------


def test_duplicated_latency_folds_into_consumer_stage():
    def fn(table, idx, w):
        j = idx + 1                      # cheap, invar-fed: duplicable
        a = table[j]                     # gather -> stage cut
        b = a * w                        # long mul -> stage cut
        return b + j.astype(jnp.float32)

    table = jnp.arange(32, dtype=jnp.float32)
    cdfg = CDFG.from_function(fn, table, jnp.int32(3), jnp.float32(2.0))
    part = partition_cdfg(cdfg)
    assert part.duplicated, "expected the index add to be duplicated"
    (nid, consumers), = part.duplicated.items()
    dup_lat = cdfg.node(nid).latency
    for sid in consumers:
        st = part.stages[sid]
        base = sum(cdfg.node(n).latency for n in st.node_ids)
        assert st.latency == base + dup_lat, \
            "consumer stage latency must include the duplicated op"
    # idempotent: re-running the rewrite must not double-count
    duplicate_cheap_rewrite(part)
    st = part.stages[consumers[0]]
    base = sum(cdfg.node(n).latency for n in st.node_ids)
    assert st.latency == base + dup_lat


# ---------------------------------------------------------------------------
# Satellite: partition invariants under DSE moves
# ---------------------------------------------------------------------------


def _compiled_spmv():
    body, args = _spmv_like()
    return dcompile(body, *args, loop=True)


def test_moves_preserve_invariants():
    """Every enumerated candidate: SCCs intact, node set partitioned,
    channels re-derived and forward-only."""
    c = _compiled_spmv()
    cdfg, base = c.cdfg, c.context.plan
    plans = enumerate_plans(cdfg, base, 64)
    assert len(plans) > 4
    all_nodes = sorted(n.id for n in cdfg.nodes)
    for moves, plan in plans:
        assert plan_is_legal(cdfg, plan), moves
        # SCC membership is identical across plans (never split)
        for grp in plan.groups:
            for k in grp:
                assert plan.sccs[k] == base.sccs[k]
        part = materialize(cdfg, plan)
        seen = sorted(n for s in part.stages for n in s.node_ids)
        assert seen == all_nodes, moves
        assert part.channels == derive_channels(part)
        for ch in part.channels:
            assert ch.src_stage < ch.dst_stage, moves


def test_fused_and_maximal_reachable_as_degenerate_points():
    c = _compiled_spmv()
    cdfg, base = c.cdfg, c.context.plan
    sigs = {plan_signature(p) for _, p in enumerate_plans(cdfg, base, 256)}
    assert plan_signature(stage_groups(cdfg, policy="fused")) in sigs
    assert plan_signature(stage_groups(cdfg, policy="maximal")) in sigs
    # and the helpers agree with the policies
    assert plan_signature(fused_plan(base)) == \
        plan_signature(stage_groups(cdfg, policy="fused"))
    assert plan_signature(maximal_plan(base)) == \
        plan_signature(stage_groups(cdfg, policy="maximal"))


def test_split_then_merge_roundtrips():
    c = _compiled_spmv()
    base = c.context.plan
    wide = [b for b, g in enumerate(base.groups) if len(g) > 1]
    assert wide, "expected a multi-SCC stage in the Algorithm 1 plan"
    b = wide[0]
    split = split_move(base, b, 1)
    assert plan_signature(merge_move(split, b)) == plan_signature(base)


def test_cost_aware_merge_deterministic():
    c = _compiled_spmv()
    cdfg, base = c.cdfg, c.context.plan
    a = merge_costly_boundaries(cdfg, base, 0)
    b = merge_costly_boundaries(cdfg, base, 0)
    assert plan_signature(a) == plan_signature(b)
    assert plan_is_legal(cdfg, a)
    # the merged plan is inside the move closure too
    sigs = {plan_signature(p) for _, p in enumerate_plans(cdfg, base, 256)}
    assert plan_signature(a) in sigs


# ---------------------------------------------------------------------------
# The explorer
# ---------------------------------------------------------------------------


def test_explore_front_artifacts_and_shared_resolution(rescache_on):
    c = _compiled_spmv()
    res = c.explore(n_iters=1500, max_candidates=12)
    # baseline is the Algorithm 1 plan, always simulated
    assert res.baseline.cycles is not None
    assert res.baseline.groups == plan_signature(c.context.plan)
    # the front is a proper Pareto set: bits ascending, cycles descending
    bits = [f.fifo_bits for f in res.front]
    cyc = [f.cycles for f in res.front]
    assert bits == sorted(bits) and len(set(bits)) == len(bits)
    assert cyc == sorted(cyc, reverse=True)
    # every front point carries a full Compiled artifact that executes
    body, args = _spmv_like()
    expect = np.asarray(body(*args))
    for f in res.front:
        assert f.compiled is not None
        assert f.compiled.num_stages == f.resources["num_stages"]
        got = f.compiled(*args)
        np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-6)
    # candidate evaluations share resolution: all 12 candidates group
    # onto a handful of distinct op signatures, each resolved once
    evaluated = len(res.evaluated())
    assert evaluated >= 8
    assert res.eval_stats["resolution_groups"] <= 3
    assert res.eval_stats["cold_groups"] <= res.eval_stats[
        "resolution_groups"]
    # a second exploration serves every group from the rescache
    res2 = c.explore(n_iters=1500, max_candidates=12)
    assert res2.eval_stats["cold_groups"] == 0
    assert res2.rescache_hits >= res2.eval_stats["resolution_groups"]
    assert [f.cycles for f in res2.front] == [f.cycles for f in res.front]


def test_explore_cycles_bit_identical_to_fresh_simulation(rescache_on):
    c = _compiled_spmv()
    res = c.explore(n_iters=1200, max_candidates=10)
    nt = traces_by_node(c.cdfg, c.partition, None, n_iters=1200, seed=0)
    from repro.dataflow.schedule import _cyclic_nodes
    cyc_mem = {n for n in _cyclic_nodes(c.cdfg)
               if c.cdfg.node(n).is_memory}
    for cand in res.front:
        stages = sim_stages_for_partition(cand.compiled.partition, nt,
                                          cyc_mem)
        fresh = simulate_dataflow(stages, acp(), 1200, fifo_depth=8,
                                  collect_stalls=False,
                                  use_rescache=False)
        assert fresh.cycles == cand.cycles


def test_joint_partition_depth_front(rescache_on):
    """``explore(fifo_depths=[...])``: the joint partition×depth search.
    Every (plan, duplicate) pair is costed and simulated at every depth,
    the front is non-dominated across both axes, and every front point
    is bit-identical to a fresh cold simulation at its depth."""
    c = _compiled_spmv()
    depths = (4, 8, 32)
    res = c.explore(n_iters=1200, max_candidates=8, fifo_depths=depths)
    assert tuple(res.fifo_depths) == depths
    assert {x.fifo_depth for x in res.candidates} == set(depths)
    assert len(res.candidates) % len(depths) == 0  # pairs × depths
    bits = [f.fifo_bits for f in res.front]
    cyc = [f.cycles for f in res.front]
    assert bits == sorted(bits)
    assert cyc == sorted(cyc, reverse=True)
    nt = traces_by_node(c.cdfg, c.partition, None, n_iters=1200, seed=0)
    from repro.dataflow.schedule import _cyclic_nodes
    cyc_mem = {n for n in _cyclic_nodes(c.cdfg)
               if c.cdfg.node(n).is_memory}
    for cand in res.front:
        assert cand.compiled is not None
        stages = sim_stages_for_partition(cand.compiled.partition, nt,
                                          cyc_mem)
        fresh = simulate_dataflow(stages, acp(), 1200,
                                  fifo_depth=cand.fifo_depth,
                                  collect_stalls=False,
                                  use_rescache=False)
        assert fresh.cycles == cand.cycles, cand.fifo_depth
    # depth grids ride in ResourceConstraints (frozen, hashable) too
    rcon = ResourceConstraints(fifo_depths=[4, 16], n_iters=600)
    assert hash(rcon) is not None
    res2 = c.explore(constraints=rcon, max_candidates=4)
    assert {x.fifo_depth for x in res2.candidates} == {4, 16}


def test_constraints_prune_before_simulation():
    c = _compiled_spmv()
    limit = 64
    res = explore_plans(
        c.cdfg, c.context.plan,
        constraints=ResourceConstraints(max_fifo_bits=limit, n_iters=800,
                                        max_candidates=12))
    for cand in res.candidates:
        if cand is res.baseline:
            continue  # baseline is simulated even when infeasible
        if cand.pruned is not None:
            assert cand.cycles is None
    for cand in res.front:
        assert cand.fifo_bits <= limit
    assert res.best().fifo_bits <= limit or res.best() is res.baseline
    # stage-count constraint prunes by a different axis
    res2 = explore_plans(
        c.cdfg, c.context.plan,
        constraints=ResourceConstraints(max_stages=2, n_iters=800,
                                        max_candidates=12))
    for cand in res2.front:
        assert cand.resources["num_stages"] <= 2
    viol = constraint_violation({"fifo_bits": 10, "max_mem_ports": 3,
                                 "duplicated_nodes": 0, "num_stages": 4},
                                ResourceConstraints(
                                    max_mem_ports_per_stage=2))
    assert viol == "max_mem_ports 3 > 2"


def test_dse_pass_compiles_constrained_winner():
    body, args = _spmv_like()
    rcon = ResourceConstraints(max_fifo_bits=2048, n_iters=1000,
                               max_candidates=10)
    c = dcompile(body, *args, loop=True, dse=rcon)
    assert c.dse_result is not None
    best = c.dse_result.best()
    assert partition_resources(
        c.partition, rcon.fifo_depth)["fifo_bits"] <= 2048 \
        or best is c.dse_result.baseline
    # re-partitioned program still computes the right thing
    got = c(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(body(*args)),
                               rtol=1e-6)
    # no-op without options.dse
    c2 = dcompile(body, *args, loop=True)
    assert c2.dse_result is None


def test_duplication_budget_is_a_move():
    """max_duplicated_nodes=0 forbids the §III-B1 rewrite: every
    feasible candidate must be duplication-free."""
    def fn(table, idx, w):
        j = idx + 1
        a = table[j]
        b = a * w
        return b + j.astype(jnp.float32)

    table = jnp.arange(32, dtype=jnp.float32)
    c = dcompile(fn, table, jnp.int32(3), jnp.float32(2.0))
    assert c.partition.duplicated  # the default plan duplicates
    res = explore_plans(
        c.cdfg, c.context.plan,
        constraints=ResourceConstraints(max_duplicated_nodes=0,
                                        n_iters=500, max_candidates=12))
    feasible = [cand for cand in res.candidates if cand.pruned is None]
    assert feasible
    assert all(cand.resources["duplicated_nodes"] == 0
               for cand in feasible)
    assert any(not cand.duplicate for cand in feasible)
    # ...and the toggle works in the other direction too: a base compile
    # without the rewrite still explores duplicated candidates
    c2 = dcompile(fn, table, jnp.int32(3), jnp.float32(2.0),
                  duplicate_cheap=False)
    res2 = explore_plans(
        c2.cdfg, c2.context.plan,
        constraints=ResourceConstraints(n_iters=500, max_candidates=12),
        duplicate_base=False)
    assert any(cand.duplicate and "duplicate" in cand.moves
               for cand in res2.candidates)


def test_traces_by_node_conventions():
    c = _compiled_spmv()
    mem_nodes = [nid for st in c.partition.stages for nid in st.node_ids
                 if c.cdfg.node(nid).is_memory]
    # positional sequence: one trace per memory node, pipeline order
    seq = [MemAccess(f"t{i}", np.arange(100) * 4)
           for i in range(len(mem_nodes))]
    nt = traces_by_node(c.cdfg, c.partition, seq, n_iters=100)
    assert [nt[nid][0].region for nid in mem_nodes] == \
        [f"t{i}" for i in range(len(mem_nodes))]
    # region mapping: the region's ops share the trace
    regions = {c.cdfg.node(nid).region for nid in mem_nodes}
    mapping = {r: MemAccess(r, np.arange(64) * 4) for r in regions}
    nt2 = traces_by_node(c.cdfg, c.partition, mapping, n_iters=64)
    for nid in mem_nodes:
        assert nt2[nid][0].region == c.cdfg.node(nid).region
    # None: synthetic per-region traces, deterministic in the seed
    nt3 = traces_by_node(c.cdfg, c.partition, None, n_iters=64, seed=7)
    nt4 = traces_by_node(c.cdfg, c.partition, None, n_iters=64, seed=7)
    for nid in mem_nodes:
        np.testing.assert_array_equal(nt3[nid][0].addrs,
                                      nt4[nid][0].addrs)


# ---------------------------------------------------------------------------
# Satellite: Fig. 2 schedule capture on the resolution layer
# ---------------------------------------------------------------------------


def test_return_schedule_matches_scalar_path_and_hits_rescache(
        rescache_on):
    rng = np.random.default_rng(0)
    n = 400
    stages = [
        SimStage("idx", ii=1, latency=2,
                 accesses=[MemAccess("cols", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x",
                                     rng.integers(0, 4 << 20, n) * 4)]),
        SimStage("fma", ii=6, latency=8),
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", np.arange(n) * 4,
                                     is_store=True)]),
    ]
    for mk in (acp, acp_cache):
        ref, s_ref, f_ref = simulate_dataflow(
            stages, mk(), n, reference=True, return_schedule=True)
        new, s_new, f_new = simulate_dataflow(
            stages, mk(), n, return_schedule=True)
        np.testing.assert_array_equal(s_ref, s_new)
        np.testing.assert_array_equal(f_ref, f_new)
        assert ref.cycles == new.cycles
        assert ref.stage_stall_cycles == new.stage_stall_cycles
        assert (ref.cache_hits, ref.cache_misses) == \
            (new.cache_hits, new.cache_misses)
    # the schedule path stored artifacts; a rerun serves from the cache
    before = rc.stats()["mem_hits"]
    again, s2, _ = simulate_dataflow(stages, acp_cache(), n,
                                     return_schedule=True)
    assert rc.stats()["mem_hits"] > before
    np.testing.assert_array_equal(s2, s_new)
    assert again.cycles == new.cycles

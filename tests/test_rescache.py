"""Tests for the memoized trace-resolution layer (repro.core.rescache)
and the multi-lane resolution engine built on it."""

import multiprocessing
import os

import numpy as np
import pytest

from repro.core import rescache as rc
from repro.core.simulator import (
    CacheConfig, MemAccess, MemoryModel, SimStage, acp, acp_cache, hp,
    hp_cache, simulate_conventional, simulate_conventional_many,
    simulate_dataflow, simulate_dataflow_many, simulate_processor,
    standard_memory_models,
)


@pytest.fixture()
def cache_dir(tmp_path):
    """A fresh, isolated cache for every test."""
    d = str(tmp_path / "rescache")
    rc.clear()
    rc.configure(enabled=True, directory=d, memory_mb=64,
                 artifact_mb=64, disk_mb=256)
    yield d
    rc.clear()
    rc.configure(enabled=False)


def _pipeline(n=3000, seed=5):
    rng = np.random.default_rng(seed)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("i", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=3,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 19, n) * 4),
                           MemAccess("y", np.arange(n) * 4 + (1 << 22),
                                     is_store=True)]),
        SimStage("fma", ii=4, latency=6),
    ]


def test_cached_results_bit_identical(cache_dir):
    """Cold vs warm (memory LRU) vs disk-served runs must agree exactly:
    cycles, stall buckets, cache statistics."""
    stages = _pipeline()
    cold = simulate_dataflow(stages, acp_cache(), 3000, fifo_depth=16)
    assert rc.stats()["stores"] >= 1
    warm = simulate_dataflow(stages, acp_cache(), 3000, fifo_depth=16)
    assert rc.stats()["mem_hits"] >= 1
    assert cold.cycles == warm.cycles
    assert cold.stage_stall_cycles == warm.stage_stall_cycles
    assert (cold.cache_hits, cold.cache_misses) == \
        (warm.cache_hits, warm.cache_misses)
    # chunking of the serving run is irrelevant: views of one artifact
    ch = simulate_dataflow(stages, acp_cache(), 3000, fifo_depth=16,
                           chunk_iters=311)
    assert ch.cycles == cold.cycles
    assert ch.stage_stall_cycles == cold.stage_stall_cycles
    # drop the in-process LRU: the next run is served from disk
    rc._mem.clear()
    rc._mem_bytes = 0
    disk = simulate_dataflow(stages, acp_cache(), 3000, fifo_depth=16)
    assert rc.stats()["disk_hits"] >= 1
    assert disk.cycles == cold.cycles
    assert disk.stage_stall_cycles == cold.stage_stall_cycles


def test_cached_vs_uncached_identical(cache_dir):
    """A cache-served run must match a run with the cache disabled."""
    stages = _pipeline(seed=6)
    for mk in (acp, hp, acp_cache, hp_cache):
        warm0 = simulate_dataflow(stages, mk(), 2500, fifo_depth=8)
        warm1 = simulate_dataflow(stages, mk(), 2500, fifo_depth=8)
        off = simulate_dataflow(stages, mk(), 2500, fifo_depth=8,
                                use_rescache=False)
        assert warm0.cycles == warm1.cycles == off.cycles
        assert warm1.stage_stall_cycles == off.stage_stall_cycles
        assert (warm1.cache_hits, warm1.cache_misses) == \
            (off.cache_hits, off.cache_misses)


def test_key_invalidates_on_model_and_seed(cache_dir):
    """Every memory-model field that reaches the resolved per-access
    latencies must change the key (no false sharing); the model's *name*
    and the fold-only fields (bandwidth, outstanding cap, store-buffer
    depth, posted writes) must not — those variants legitimately share
    one artifact.  Since v3 the iteration count is not part of the key
    either: chunk records serve any prefix."""
    stages = _pipeline(seed=7)
    base = acp()
    key0 = rc.resolution_key("dataflow", stages, base, 0)
    renamed = acp()
    renamed.name = "something-else"
    assert rc.resolution_key("dataflow", stages, renamed, 0) == key0
    assert rc.resolution_key("dataflow", stages, base, 1) != key0
    for field, value in [("port_latency", 26), ("dram_latency", 66),
                         ("backing_hit_rate", 0.5)]:
        m = acp()
        setattr(m, field, value)
        assert rc.resolution_key("dataflow", stages, m, 0) != key0, \
            field
    # fold-only fields share the artifact (per-op keying)
    for field, value in [("words_per_cycle", 0.5), ("max_outstanding", 4),
                         ("posted_writes", False),
                         ("store_buffer_depth", 2)]:
        m = acp()
        setattr(m, field, value)
        assert rc.resolution_key("dataflow", stages, m, 0) == key0, \
            field
    # since v3 the conventional artifact stores raw latencies, so
    # posted_writes is fold-only there too — the variants share
    m = acp()
    m.posted_writes = False
    assert rc.resolution_key("conventional", stages, m, 0) == \
        rc.resolution_key("conventional", stages, acp(), 0)
    m = acp_cache()
    k1 = rc.resolution_key("dataflow", stages, m, 0)
    assert k1 != key0
    m2 = acp_cache()
    m2.cache.write_allocate = False
    assert rc.resolution_key("dataflow", stages, m2, 0) != k1
    # trace content is part of the key
    other = _pipeline(seed=8)
    assert rc.resolution_key("dataflow", other, base, 0) != key0
    # stage latency and II are NOT: they never reach the resolved arrays,
    # and neither is the stage *grouping* — regrouping the same ops in the
    # same stream order (a DSE merge) shares the artifact
    relat = _pipeline(seed=7)
    for st in relat:
        st.latency += 3
        st.ii += 2
    assert rc.resolution_key("dataflow", relat, base, 0) == key0
    merged = [SimStage("m", ii=1, latency=5,
                       accesses=[a for st in _pipeline(seed=7)
                                 for a in st.accesses])]
    assert rc.resolution_key("dataflow", merged, base, 0) == key0
    # a serialized (mem-in-SCC) op resolves differently: key must differ
    ser = _pipeline(seed=7)
    ser[0] = SimStage(ser[0].name, ii=ser[0].ii, latency=ser[0].latency,
                      accesses=ser[0].accesses, mem_in_scc=True)
    assert rc.resolution_key("dataflow", ser, base, 0) != key0


def test_trace_fingerprint_generated_vs_materialized():
    """A generated trace and its materialized twin fingerprint equal when
    small enough for full hashing to... differ is fine — but the same
    generator with the same content must be stable, and content changes
    must change it."""
    g1 = MemAccess("g", gen=lambda lo, hi: np.arange(lo, hi) * 4,
                   length=1 << 23)
    g2 = MemAccess("g", gen=lambda lo, hi: np.arange(lo, hi) * 4,
                   length=1 << 23)
    g3 = MemAccess("g", gen=lambda lo, hi: np.arange(lo, hi) * 8,
                   length=1 << 23)
    assert rc.trace_fingerprint(g1) == rc.trace_fingerprint(g2)
    assert rc.trace_fingerprint(g1) != rc.trace_fingerprint(g3)
    # materialized arrays hash full content below the threshold
    a = MemAccess("a", np.arange(1000) * 4)
    b = MemAccess("b", np.arange(1000) * 4)
    c = MemAccess("c", np.arange(1000) * 4 + 4)
    assert rc.trace_fingerprint(a) == rc.trace_fingerprint(b)
    assert rc.trace_fingerprint(a) != rc.trace_fingerprint(c)


def test_disk_store_survives_spawn_pool(cache_dir):
    """The on-disk store must be shared across a spawn-based process
    pool: workers in fresh interpreters see artifacts the first worker
    wrote (atomic writes; corrupt reads degrade to a miss)."""
    import _rescache_worker
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(1) as pool:
        c0, s0 = pool.map(_rescache_worker.run_cell, [(cache_dir, 0)])[0]
    assert s0["stores"] >= 1
    assert os.path.isdir(cache_dir) and any(
        f.endswith(".npz") for f in os.listdir(cache_dir))
    with ctx.Pool(1) as pool:  # a brand-new interpreter
        c1, s1 = pool.map(_rescache_worker.run_cell, [(cache_dir, 0)])[0]
    assert c1 == c0
    assert s1["disk_hits"] >= 1, s1
    with ctx.Pool(1) as pool:  # different seed: no false sharing
        c2, s2 = pool.map(_rescache_worker.run_cell, [(cache_dir, 3)])[0]
    assert s2["disk_hits"] == 0 or c2 == c0  # key must differ -> resolve
    assert s2["stores"] >= 1


def test_artifact_size_gate(cache_dir):
    """Oversized artifacts are never stored; the run still succeeds."""
    rc.configure(artifact_mb=0)
    stages = _pipeline(seed=9)
    r0 = simulate_dataflow(stages, acp(), 2000, fifo_depth=8)
    assert rc.stats()["too_large"] >= 1
    assert rc.stats()["stores"] == 0
    r1 = simulate_dataflow(stages, acp(), 2000, fifo_depth=8)
    assert r0.cycles == r1.cycles


def test_summaries_conventional_and_processor(cache_dir):
    """Conventional/processor runs memoize chunk records (per-access
    latencies / hit levels); warm results are bit-identical and the
    processor cycle count is rebuilt for different instrs_per_iter."""
    stages = _pipeline(seed=10)
    c0 = simulate_conventional(stages, acp_cache(), 3000)
    c1 = simulate_conventional(stages, acp_cache(), 3000)
    assert c0.cycles == c1.cycles
    assert (c0.cache_hits, c0.cache_misses) == (c1.cache_hits,
                                                c1.cache_misses)
    accs = [a for st in stages for a in st.accesses]
    p0 = simulate_processor(10.0, accs, 3000)
    p1 = simulate_processor(10.0, accs, 3000)
    assert p0.cycles == p1.cycles
    # the hierarchy summary is instrs-independent; cycles are rebuilt
    p2 = simulate_processor(20.0, accs, 3000)
    assert p2.cycles > p0.cycles
    assert (p2.cache_hits, p2.cache_misses) == (p0.cache_hits,
                                                p0.cache_misses)


# ---------------------------------------------------------------------------
# The multi-lane engine: grid == per-cell, axes, Pareto
# ---------------------------------------------------------------------------

def test_many_engine_equals_per_cell_runs():
    """simulate_dataflow_many / simulate_conventional_many must be
    bit-identical to stand-alone per-cell simulations (same seeds, same
    draw streams) across the standard memory models and FIFO depths."""
    rc.configure(enabled=False)
    stages = _pipeline(seed=12)
    n = 2000
    mems = {mn: mk() for mn, mk in standard_memory_models().items()}
    grid = simulate_dataflow_many(stages, mems, n, fifo_depths=(4, 32),
                                  chunk_iters=701)
    conv = simulate_conventional_many(
        stages, {mn: mk() for mn, mk in standard_memory_models().items()},
        n)
    for mn, mk in standard_memory_models().items():
        cv = simulate_conventional(stages, mk(), n, reference=True)
        assert conv[mn].cycles == cv.cycles
        for d in (4, 32):
            ref = simulate_dataflow(stages, mk(), n, fifo_depth=d,
                                    reference=True)
            got = grid[(mn, d)]
            assert got.cycles == ref.cycles, (mn, d)
            assert got.stage_stall_cycles == ref.stage_stall_cycles
            assert (got.cache_hits, got.cache_misses) == \
                (ref.cache_hits, ref.cache_misses)


def test_collect_stalls_off_same_cycles():
    rc.configure(enabled=False)
    stages = _pipeline(seed=13)
    a = simulate_dataflow(stages, acp(), 1500, fifo_depth=8)
    b = simulate_dataflow(stages, acp(), 1500, fifo_depth=8,
                          collect_stalls=False)
    assert a.cycles == b.cycles
    assert all(v == 0 for bk in b.stage_stall_cycles.values()
               for v in bk.values())


def test_posted_writes_and_write_allocate_toggles():
    """Posted stores shorten the data path but not below the load-bound
    schedule; write-around stores bypass the cache (loads keep hitting).
    Both toggles agree with the scalar reference."""
    rc.configure(enabled=False)
    n = 3000
    rng = np.random.default_rng(14)
    store_heavy = [
        SimStage("w", ii=1, latency=2,
                 accesses=[MemAccess("out", rng.integers(0, 1 << 20, n) * 4,
                                     is_store=True)]),
        SimStage("c", ii=2, latency=4),
    ]
    posted = MemoryModel(name="p", posted_writes=True)
    blocking = MemoryModel(name="b", posted_writes=False)
    rp = simulate_dataflow(store_heavy, posted, n)
    rb = simulate_dataflow(store_heavy, blocking, n)
    assert rp.cycles <= rb.cycles
    for mem in (posted, blocking):
        ref = simulate_dataflow(store_heavy, mem, n, reference=True)
        vec = simulate_dataflow(store_heavy, mem, n)
        assert ref.cycles == vec.cycles
        cref = simulate_conventional(store_heavy, mem, n, reference=True)
        cvec = simulate_conventional(store_heavy, mem, n)
        assert cref.cycles == cvec.cycles
    # posted stores do not stall the conventional engine; blocking do
    cp = simulate_conventional(store_heavy, posted, n)
    cb = simulate_conventional(store_heavy, blocking, n)
    assert cp.cycles < cb.cycles
    # write-around: stores bypass the cache -> fewer store hits, and the
    # vectorized path still matches the scalar reference exactly
    wa = MemoryModel(name="wa", cache=CacheConfig(write_allocate=False))
    alloc = MemoryModel(name="al", cache=CacheConfig(write_allocate=True))
    mixed = [
        SimStage("ld", ii=1, latency=2,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 14, n) * 4)]),
        SimStage("st", ii=1, latency=2,
                 accesses=[MemAccess("y",
                                     rng.integers(0, 1 << 14, n) * 4,
                                     is_store=True)]),
    ]
    for mem in (wa, alloc):
        ref = simulate_dataflow(mixed, mem, n, reference=True)
        vec = simulate_dataflow(mixed, mem, n)
        assert ref.cycles == vec.cycles
        assert (ref.cache_hits, ref.cache_misses) == \
            (vec.cache_hits, vec.cache_misses)
    r_wa = simulate_dataflow(mixed, wa, n)
    r_al = simulate_dataflow(mixed, alloc, n)
    assert r_wa.cache_hits != r_al.cache_hits


def test_sweep_axes_and_pareto():
    """The extended sweep axes (words_per_cycle / max_outstanding) and
    the cycles-vs-FIFO-bits Pareto front."""
    import jax.numpy as jnp
    from repro.dataflow import compile as dataflow_compile
    rc.configure(enabled=False)

    def body(acc, x):
        return acc + x * 2.0

    c = dataflow_compile(body, jnp.float32(0.0), jnp.float32(1.0),
                         loop=True)
    res = c.sweep(n_iters=1200, fifo_depths=(2, 8, 32),
                  mems={"ACP": acp, "HP": hp},
                  words_per_cycle=(0.5, 1.0), max_outstandings=(2, 16))
    assert len(res.rows) == 2 * 3 * 2 * 2
    for r in res.rows:
        assert {"fifo_bits", "words_per_cycle", "max_outstanding",
                "pareto"} <= set(r)
    front = res.pareto()
    assert front, "front must be non-empty"
    bits = [r["fifo_bits"] for r in front]
    cyc = [r["dataflow_cycles"] for r in front]
    assert bits == sorted(bits)
    assert cyc == sorted(cyc, reverse=True)
    # a wider port / deeper outstanding queue can never be slower
    by_cfg = {(r["mem"], r["fifo_depth"], r["words_per_cycle"],
               r["max_outstanding"]): r["dataflow_cycles"]
              for r in res.rows}
    for mem in ("ACP", "HP"):
        for d in (2, 8, 32):
            assert by_cfg[(mem, d, 1.0, 16)] <= by_cfg[(mem, d, 0.5, 2)]

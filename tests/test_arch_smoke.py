"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus decode-step and prefill↔decode
consistency.  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, load_config, reduced

_HEAVY_ARCHS = {"deepseek-v3-671b", "jamba-1.5-large-398b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
               if a in _HEAVY_ARCHS else a for a in ARCH_IDS]
from repro.models import (decode_step, forward, init_cache, init_params,
                          input_specs, loss_fn, prefill)

_B, _S = 2, 16


def _batch(cfg, rng):
    if cfg.frontend_stub:
        return {
            "embeds": jax.random.normal(rng, (_B, _S, cfg.d_model),
                                        jnp.float32),
            "labels": jax.random.randint(rng, (_B, _S), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(rng, (_B, _S + 1), 0,
                                         cfg.vocab_size)}


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(load_config(arch))
            rng = jax.random.PRNGKey(hash(arch) % 2**31)
            params = init_params(rng, cfg)
            cache[arch] = (cfg, params, rng)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_and_finite(arch, arch_setup):
    cfg, params, rng = arch_setup(arch)
    batch = _batch(cfg, rng)
    inputs = batch.get("tokens", batch.get("embeds"))
    if "tokens" in batch:
        inputs = batch["tokens"][:, :-1]
    logits, aux = forward(params, inputs, cfg)
    S = inputs.shape[1]
    assert logits.shape == (_B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step_loss_finite_and_grads(arch, arch_setup):
    cfg, params, rng = arch_setup(arch)
    batch = _batch(cfg, rng)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg)
    assert bool(jnp.isfinite(loss)), arch
    # at least one grad leaf is nonzero and all are finite
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in leaves), arch
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
               for g in leaves), arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_step_runs(arch, arch_setup):
    cfg, params, rng = arch_setup(arch)
    cache = init_cache(cfg, _B, max_len=_S + 8)
    token = jnp.zeros((_B,), jnp.int32)
    logits, new_cache = decode_step(params, token, cache,
                                    jnp.asarray(0, jnp.int32), cfg)
    assert logits.shape == (_B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(new_cache))


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-1.6b",
                                  "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_prefill_then_decode_matches_forward(arch, arch_setup):
    """prefill(t_0..t_{n-1}) + decode(t_n) must equal forward on the full
    prefix — the serving path is consistent with training semantics."""
    cfg, params, rng = arch_setup(arch)
    if cfg.moe is not None:
        # token-dropping MoE is batch-order dependent; relax via high cap
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    n = 8
    tokens = jax.random.randint(rng, (_B, n + 1), 0, cfg.vocab_size)
    # ground truth: forward over n+1 tokens, logits at position n
    logits_full, _ = forward(params, tokens, cfg)
    want = logits_full[:, -1]
    # serving: prefill n tokens, then decode token n
    _, cache = prefill(params, tokens[:, :n], cfg, max_len=n + 4)
    got, _ = decode_step(params, tokens[:, n], cache,
                         jnp.asarray(n, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_input_specs_all_shapes(arch):
    from repro.configs import SHAPES, cell_is_applicable
    cfg = load_config(arch)
    for shape in SHAPES.values():
        if not cell_is_applicable(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        assert leaves, (arch, shape.name)
        for l in leaves:
            assert isinstance(l, jax.ShapeDtypeStruct)


def test_param_counts_match_published():
    expected = {
        "jamba-1.5-large-398b": (398, 30),
        "qwen2.5-14b": (14.8, 1),
        "olmo-1b": (1.3, 0.2),
        "smollm-135m": (0.135, 0.03),
        "command-r-plus-104b": (104, 5),
        "rwkv6-1.6b": (1.6, 0.3),
        "deepseek-v3-671b": (671, 10),
        "llama4-scout-17b-a16e": (109, 10),
        "chameleon-34b": (34, 2),
    }
    for arch, (want_b, tol_b) in expected.items():
        got = load_config(arch).param_count() / 1e9
        assert abs(got - want_b) < tol_b, (arch, got, want_b)
    # active params for the MoE flagships
    assert abs(load_config("deepseek-v3-671b").active_param_count() / 1e9
               - 37) < 3
    assert abs(load_config("llama4-scout-17b-a16e").active_param_count()
               / 1e9 - 17) < 2

"""Spawn-side client for the resolution-daemon tests (top-level module
so a spawn context can import it)."""

import numpy as np


def pipeline(n=5000, seed=5):
    from repro.core.simulator import MemAccess, SimStage
    rng = np.random.default_rng(seed)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("i", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=3,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 19, n) * 4),
                           MemAccess("y", np.arange(n) * 4 + (1 << 22),
                                     is_store=True)]),
        SimStage("fma", ii=4, latency=6),
    ]


def race_client(i, store, sock, barrier, q, n):
    """One racing tenant: build the request, rendezvous at the barrier
    (so both clients submit while the other's resolution is in flight),
    resolve through the daemon, report results + the local cold count."""
    from repro.core import rescache as rc
    from repro.core.simulator import acp_cache
    from repro.serve.client import simulate_dataflow_served
    rc.configure(enabled=True, directory=store)
    stages = pipeline(n)
    mems = {"ACPC": acp_cache()}
    barrier.wait()
    try:
        out = simulate_dataflow_served(stages, mems, n,
                                       fifo_depths=(8,), address=sock)
        q.put((i, {k: (v.cycles, v.cache_hits, v.cache_misses)
                   for k, v in out.items()},
               rc.stats()["cold_chunks"]))
    except Exception as e:  # noqa: BLE001 — surfaced by the test
        q.put((i, f"ERROR: {type(e).__name__}: {e}", -1))

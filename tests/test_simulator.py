"""Tests for the Fig. 2 / Fig. 5 fidelity simulator."""

import numpy as np
import pytest

from repro.core.simulator import (
    BatchedCacheSim, CacheConfig, CacheSim, MemAccess, MemoryModel,
    SimStage, acp, acp_cache, hp, hp_cache,
    simulate_conventional, simulate_dataflow, simulate_processor,
)


def _seq_trace(n, stride=4, base=0):
    return np.arange(n) * stride + base


def _rand_trace(n, span_bytes, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, span_bytes // 4, size=n) * 4


def test_cache_lru_and_hit_rate():
    c = CacheSim(CacheConfig(size_bytes=1024, line_bytes=32, ways=2))
    # sequential pass over 2 KB: first touch of each line misses,
    # subsequent words in the line hit.
    for a in range(0, 2048, 4):
        c.access(a)
    assert c.misses == 2048 // 32
    assert c.hits == 2048 // 4 - c.misses
    # second pass over the SAME first 512 bytes (fits) now hits
    h0 = c.hits
    for a in range(1024, 2048, 4):
        c.access(a)
    assert c.hits > h0


def test_dataflow_hides_latency_conventional_does_not():
    """The paper's central claim (Fig. 2): with a long-latency compute stage
    downstream, random-access misses are shadowed in the dataflow engine but
    stall the conventional engine."""
    n = 4000
    stages = [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("idx", _seq_trace(n))]),
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", _rand_trace(n, 16 << 20))]),
        SimStage("fma", ii=6, latency=8),   # long-latency fp pipeline
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", _seq_trace(n), is_store=True)]),
    ]
    mem = acp()
    df = simulate_dataflow(stages, mem, n)
    cv = simulate_conventional(stages, mem, n)
    assert df.cycles < cv.cycles, (df.cycles, cv.cycles)
    speedup = cv.cycles / df.cycles
    assert speedup > 2.0, f"expected substantial speedup, got {speedup:.2f}"
    # dataflow throughput should approach the compute II bound (6 cyc/iter)
    assert df.cycles_per_iter < 2.5 * 6


def test_cache_helps_conventional_more_than_dataflow():
    """Fig. 5: adding the 64KB cache cut conventional runtime by ~45% but
    dataflow only by ~19% — dataflow already tolerates latency."""
    n = 4000
    # reuse-heavy random trace so a cache actually captures something
    rng = np.random.default_rng(1)
    hot = rng.integers(0, 48 << 10, size=n) & ~3
    stages = [
        SimStage("fetch", ii=1, latency=2, accesses=[MemAccess("x", hot)]),
        SimStage("fma", ii=6, latency=8),
    ]
    cv_nc = simulate_conventional(stages, acp(), n).cycles
    cv_c = simulate_conventional(stages, acp_cache(64), n).cycles
    df_nc = simulate_dataflow(stages, acp(), n).cycles
    df_c = simulate_dataflow(stages, acp_cache(64), n).cycles
    conv_gain = 1 - cv_c / cv_nc
    df_gain = 1 - df_c / df_nc
    assert conv_gain > df_gain, (conv_gain, df_gain)


def test_hp_port_hurts_conventional():
    """Fig. 5: conventional degrades ~40% on the uncached HP port vs ACP."""
    n = 3000
    stages = [
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", _rand_trace(n, 8 << 20))]),
        SimStage("fma", ii=6, latency=8),
    ]
    cv_acp = simulate_conventional(stages, acp(), n).cycles
    cv_hp = simulate_conventional(stages, hp(), n).cycles
    assert cv_hp > cv_acp * 1.2


def test_mem_in_scc_gives_no_benefit():
    """The DFS negative result (§V-A): a dependence cycle through memory
    serializes access latency; dataflow ≈ conventional."""
    n = 2000
    trace = _rand_trace(n, 3 << 20, seed=2)
    # DFS: the adjacency load feeds the stack push — the whole loop body is
    # one SCC *through memory*, so Algorithm 1 yields a single stage with
    # the accesses inside the dependence cycle.
    stages = [
        SimStage("dfs_scc", ii=3, latency=3, mem_in_scc=True,
                 accesses=[MemAccess("stk", trace),
                           MemAccess("adj", _rand_trace(n, 3 << 20, 3))]),
    ]
    mem = acp()
    df = simulate_dataflow(stages, mem, n)
    cv = simulate_conventional(stages, mem, n)
    ratio = cv.cycles / df.cycles
    assert ratio < 1.8, f"DFS-like kernel should not benefit much: {ratio}"


def test_backpressure_bounds_runahead():
    """A bounded FIFO must prevent the producer from running unboundedly
    ahead of a slow consumer."""
    n = 1000
    fast = SimStage("prod", ii=1, latency=1)
    slow = SimStage("cons", ii=20, latency=4)
    r = simulate_dataflow([fast, slow], acp(), n, fifo_depth=4)
    # producer start times can lead consumer's by at most depth iterations
    # → total time governed by the slow stage, not hidden
    assert r.cycles >= 20 * (n - 1)


def test_processor_baseline_reasonable():
    n = 4000
    accesses = [MemAccess("x", _rand_trace(n, 16 << 20))]
    r = simulate_processor(instrs_per_iter=12, accesses=accesses, n_iters=n)
    assert r.cycles > 0
    assert r.freq_mhz == 667.0
    # scaled runtime extrapolation is monotone in iterations
    assert r.scaled_runtime(10 * n) > r.scaled_runtime(n)


# ---------------------------------------------------------------------------
# The vectorized core: batched cache, wavefront solver, stall accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ways", [1, 2, 4, 8])
@pytest.mark.parametrize("pattern", ["random", "sequential", "dup_runs"])
def test_batched_cache_matches_scalar(ways, pattern):
    """BatchedCacheSim must reproduce CacheSim access-for-access —
    including the 2-way closed form, the segmented N-way scan, and state
    carried across chunked lookups."""
    cfg = CacheConfig(size_bytes=4096, line_bytes=32, ways=ways)
    rng = np.random.default_rng(ways)
    n = 4000
    if pattern == "random":
        addrs = rng.integers(0, 1 << 15, n) * 4
    elif pattern == "sequential":
        addrs = np.arange(n) * 4
    else:  # runs of the same line, the collapse fast path
        addrs = np.repeat(rng.integers(0, 1 << 10, n // 4) * 32, 4)[:n]
    sc, bc = CacheSim(cfg), BatchedCacheSim(cfg)
    ref = np.array([sc.access(int(a)) for a in addrs])
    got = np.concatenate([bc.lookup(addrs[i:i + 701])
                          for i in range(0, n, 701)])
    np.testing.assert_array_equal(ref, got)
    assert (sc.hits, sc.misses) == (bc.hits, bc.misses)


@pytest.mark.parametrize("ways", [3, 4, 8, 16])
@pytest.mark.parametrize("pattern", ["one_set", "skewed", "cyclic",
                                     "seg_edge"])
def test_nway_scan_adversarial(ways, pattern):
    """The segmented distinct-distance scan vs the scalar LRU on the
    patterns that killed the old rounds replay (extreme per-set skew) or
    probe the scan's edges: single-set floods, cyclic reuse exactly at
    the associativity boundary, and runs crossing segment boundaries."""
    cfg = CacheConfig(size_bytes=ways * 8 * 32, line_bytes=32, ways=ways)
    bc = BatchedCacheSim(cfg)
    n_sets = bc.n_sets
    rng = np.random.default_rng(ways * 100 + len(pattern))
    n = 3000
    if pattern == "one_set":
        # everything lands in set 0: maximum skew
        addrs = rng.integers(0, ways + 3, n) * n_sets * 32
    elif pattern == "skewed":
        # zipf-ish: a few sets get almost all traffic
        s = rng.zipf(1.3, n) % n_sets
        line = s + n_sets * rng.integers(0, ways + 2, n)
        addrs = line * 32
    elif pattern == "cyclic":
        # round-robin over exactly ways+1 lines of one set: every access
        # misses under LRU (the classic worst case), all stack distances
        # sit at the associativity boundary
        addrs = (np.arange(n) % (ways + 1)) * n_sets * 32
    else:  # seg_edge: duplicate runs straddling the segment width
        base = np.repeat(rng.integers(0, ways + 2, n // 7 + 1), 7)[:n]
        addrs = base * n_sets * 32
    addrs = addrs.astype(np.int64)
    sc = CacheSim(cfg)
    ref = np.array([sc.access(int(a)) for a in addrs])
    # chunk at awkward boundaries so carried stacks are exercised
    got = np.concatenate([bc.lookup(addrs[i:i + 613])
                          for i in range(0, n, 613)])
    np.testing.assert_array_equal(ref, got)
    assert (sc.hits, sc.misses) == (bc.hits, bc.misses)
    if pattern == "cyclic":
        assert bc.hits == 0  # LRU's worst case: every access misses


def test_nway_carried_tags_beyond_int32():
    """Regression: the narrow-dtype decision must account for tags
    carried from *earlier* lookups — a first chunk touching addresses
    past 2^31·lines must not wrap when a later small-address chunk
    arrives (wrapped carried tags aliased fresh ones as spurious hits)."""
    cfg = CacheConfig(size_bytes=4 * 8 * 32, line_bytes=32, ways=4)
    sc, bc = CacheSim(cfg), BatchedCacheSim(cfg)
    n_sets = bc.n_sets
    huge = (np.arange(3, dtype=np.int64) + (1 << 32)) * n_sets * 32
    small = np.arange(3, dtype=np.int64) * n_sets * 32
    for chunk in (huge, small, huge):
        ref = np.array([sc.access(int(a)) for a in chunk])
        got = bc.lookup(chunk)
        np.testing.assert_array_equal(ref, got)
    assert (sc.hits, sc.misses) == (bc.hits, bc.misses)


def _random_pipeline(trial: int, n: int):
    """A seeded random pipeline + memory model (shared by the equivalence
    and stall-accounting tests)."""
    r = np.random.default_rng(1000 + trial)
    S = int(r.integers(1, 5))
    stages = []
    for s in range(S):
        accs = []
        for k in range(int(r.integers(0, 3))):
            kind = int(r.integers(0, 4))
            ln = int(r.integers(1, n + 50))
            if kind == 0:
                a = np.arange(ln) * 4 + int(r.integers(0, 1 << 20))
            elif kind == 1:
                a = (1 << 20) - np.arange(ln) * 4
            elif kind == 2:
                a = r.integers(0, 1 << 18, ln) * 4
            else:
                a = r.integers(0, 1 << 18, ln) * 4
                a[r.random(ln) < 0.3] = -1
            accs.append(MemAccess(f"r{s}_{k}", a,
                                  is_store=bool(r.integers(0, 2))))
        stages.append(SimStage(f"s{s}", ii=int(r.integers(1, 8)),
                               latency=int(r.integers(1, 10)),
                               accesses=accs,
                               mem_in_scc=bool(r.random() < 0.2 and accs)))
    mo = int(r.integers(1, 17))
    wpc = float(r.choice([0.25, 0.5, 1.0, 2.0]))
    mk0 = [acp, hp, acp_cache, hp_cache][trial % 4]

    def mkmem():
        m = mk0()
        m.max_outstanding = mo
        m.words_per_cycle = wpc
        return m

    return stages, mkmem, int(r.integers(1, 12))


@pytest.mark.parametrize("trial", range(12))
def test_vectorized_matches_reference(trial):
    """Cycle-exact agreement between the wavefront solver and the scalar
    reference on seeded random pipelines: cycles, per-stage stall buckets,
    and cache statistics, for dataflow and conventional."""
    n = 300
    stages, mkmem, fd = _random_pipeline(trial, n)
    ref = simulate_dataflow(stages, mkmem(), n, fifo_depth=fd,
                            reference=True, seed=trial)
    vec = simulate_dataflow(stages, mkmem(), n, fifo_depth=fd, seed=trial)
    assert ref.cycles == vec.cycles
    assert ref.stage_stall_cycles == vec.stage_stall_cycles
    assert (ref.cache_hits, ref.cache_misses) == \
        (vec.cache_hits, vec.cache_misses)
    cr = simulate_conventional(stages, mkmem(), n, reference=True,
                               seed=trial)
    cv = simulate_conventional(stages, mkmem(), n, seed=trial)
    assert cr.cycles == cv.cycles
    assert (cr.cache_hits, cr.cache_misses) == \
        (cv.cache_hits, cv.cache_misses)


@pytest.mark.parametrize("trial", [0, 3, 6])
def test_chunked_streaming_invariance(trial):
    """Chunk size must not change anything: cache state, RNG stream, and
    solver carry all stream across chunk boundaries."""
    n = 500
    stages, mkmem, fd = _random_pipeline(trial, n)
    whole = simulate_dataflow(stages, mkmem(), n, fifo_depth=fd, seed=9)
    tiny = simulate_dataflow(stages, mkmem(), n, fifo_depth=fd, seed=9,
                             chunk_iters=37)
    assert whole.cycles == tiny.cycles
    assert whole.stage_stall_cycles == tiny.stage_stall_cycles
    assert whole.cache_hits == tiny.cache_hits


@pytest.mark.parametrize("trial", range(8))
def test_stall_buckets_partition_idle_time(trial):
    """Satellite bugfix: stalls were double-counted (mem_in_scc) and
    producer waits were booked at every downstream stage, summing to a
    multiple of total cycles.  Now the buckets partition each stage's idle
    time, so per stage sum(buckets) <= cycles."""
    n = 400
    stages, mkmem, fd = _random_pipeline(trial, n)
    r = simulate_dataflow(stages, mkmem(), n, fifo_depth=fd)
    assert set(next(iter(r.stage_stall_cycles.values()))) == \
        {"ii", "upstream", "fifo", "memory"}
    for name, buckets in r.stage_stall_cycles.items():
        assert all(v >= 0 for v in buckets.values()), (name, buckets)
        assert sum(buckets.values()) <= r.cycles, (name, buckets, r.cycles)


def test_mem_in_scc_stall_not_double_counted():
    """The old mem_in_scc path added the serialized latency to the stall
    twice (once in the t2 branch, once in the generic check); now the
    memory bucket alone carries it and the stage's buckets stay under
    total cycles even for a pure-SCC stage."""
    n = 1500
    stages = [SimStage("scc", ii=3, latency=3, mem_in_scc=True,
                       accesses=[MemAccess("a", _rand_trace(n, 8 << 20)),
                                 MemAccess("b", _rand_trace(n, 8 << 20, 1))])]
    r = simulate_dataflow(stages, acp(), n)
    buckets = r.stage_stall_cycles["scc"]
    assert sum(buckets.values()) <= r.cycles
    # the serialized access latency lands in the memory bucket
    assert buckets["memory"] > n * 2 * 20  # two accesses, >=~25cyc each
    assert buckets["upstream"] == 0 and buckets["fifo"] == 0


def test_conventional_fast_backing_store_no_negative_stall():
    """Regression: a backing trip faster than the assumed (cache-hit)
    latency must stall nothing — not contribute a negative stall — and
    the vectorized path must agree with the reference."""
    n = 2000
    mem = MemoryModel(name="fastback", port_latency=2, dram_latency=3,
                      backing_hit_rate=0.9,
                      cache=CacheConfig(size_bytes=4096, hit_cycles=4))
    stages = [SimStage("f", ii=1, latency=1,
                       accesses=[MemAccess("x", _rand_trace(n, 8 << 20))])]
    ref = simulate_conventional(stages, mem, n, reference=True)
    vec = simulate_conventional(stages, mem, n)
    assert ref.cycles == vec.cycles
    assert vec.cycles >= n
    assert vec.stage_stall_cycles["engine"]["memory"] >= 0


def test_monotone_in_memory_latency():
    """More memory latency can never make the pipeline faster."""
    n = 3000
    stages = [
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", _rand_trace(n, 8 << 20))]),
        SimStage("fma", ii=4, latency=6),
    ]
    prev = None
    for port, dram in [(10, 30), (25, 65), (40, 100), (80, 200)]:
        mem = MemoryModel(name="m", port_latency=port, dram_latency=dram,
                          backing_hit_rate=0.35)
        cyc = simulate_dataflow(stages, mem, n).cycles
        if prev is not None:
            assert cyc >= prev, (port, dram, cyc, prev)
        prev = cyc
        cv = simulate_conventional(stages, mem, n).cycles
        assert cv >= cyc


def test_burst_trace_beats_random_trace():
    """§III-B2: sequential (burst) streams at port bandwidth; random
    gathers pay per-access latency — on the same model, same pipeline."""
    n = 5000
    def pipeline(trace):
        return [SimStage("fetch", ii=1, latency=2,
                         accesses=[MemAccess("x", trace)]),
                SimStage("fma", ii=2, latency=4)]
    for mk in (acp, hp, acp_cache):
        seq = simulate_dataflow(pipeline(_seq_trace(n)), mk(), n).cycles
        rand = simulate_dataflow(pipeline(_rand_trace(n, 32 << 20)),
                                 mk(), n).cycles
        assert seq < rand, (mk().name, seq, rand)


def test_burst_respects_bandwidth_and_outstanding_cap():
    """Satellite bugfix: the old burst branch hid the in-flight cap and
    its i==0 ternary was a no-op.  A narrow port (words_per_cycle < 1)
    must now throttle burst streams, and a tiny max_outstanding must
    throttle latency-paying streams."""
    n = 4000
    stages = [SimStage("fetch", ii=1, latency=2,
                       accesses=[MemAccess("x", _seq_trace(n))])]
    wide = MemoryModel(name="w", words_per_cycle=1.0, backing_hit_rate=0.0)
    narrow = MemoryModel(name="n", words_per_cycle=0.25,
                         backing_hit_rate=0.0)
    c_wide = simulate_dataflow(stages, wide, n).cycles
    c_narrow = simulate_dataflow(stages, narrow, n).cycles
    assert c_narrow >= 4 * (n - 1)            # 1 word / 4 cycles
    assert c_narrow > 3 * c_wide
    rng_stages = [SimStage("fetch", ii=1, latency=2,
                           accesses=[MemAccess("x",
                                               _rand_trace(n, 32 << 20))])]
    lots = MemoryModel(name="l", max_outstanding=16)
    few = MemoryModel(name="f", max_outstanding=1)
    assert (simulate_dataflow(rng_stages, few, n).cycles
            > simulate_dataflow(rng_stages, lots, n).cycles * 2)


def test_latency_bound_fused_vs_decoupled_regression():
    """Regression pin: for a latency-bound kernel (long-latency random
    gather feeding real compute) the decoupled template must beat the
    fused conventional schedule, and by a sane margin (Fig. 5 band)."""
    n = 8000
    stages = [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("idx", _seq_trace(n))]),
        SimStage("gather", ii=1, latency=2,
                 accesses=[MemAccess("x", _rand_trace(n, 32 << 20))]),
        SimStage("fma", ii=6, latency=8),
    ]
    mem = acp()
    df = simulate_dataflow(stages, mem, n, fifo_depth=32)
    from repro.dataflow import fused_stage
    cv = simulate_conventional([fused_stage(stages)], acp(), n)
    speedup = cv.cycles / df.cycles
    assert speedup > 2.0, speedup
    assert speedup < 40.0, speedup


def test_memaccess_canonicalizes_and_windows():
    """Satellite bugfix: the canonicalized int64 array is assigned back;
    windows pad with -1; generated traces match materialized ones."""
    a = MemAccess("r", [0, 4, 8, 100])
    assert isinstance(a.addrs, np.ndarray) and a.addrs.dtype == np.int64
    assert len(a) == 4
    w, seq = a.window(2, 6)
    np.testing.assert_array_equal(w, [8, 100, -1, -1])
    assert not seq[2] and not seq[3]
    g = MemAccess("g", gen=lambda lo, hi: np.arange(lo, hi) * 4, length=10)
    m = MemAccess("m", np.arange(10) * 4)
    for lo, hi in [(0, 10), (3, 7), (8, 15)]:
        wg, sg = g.window(lo, hi)
        wm, sm = m.window(lo, hi)
        np.testing.assert_array_equal(wg, wm)
        np.testing.assert_array_equal(sg, sm)


def test_burst_threshold_derived_from_line_bytes():
    """Satellite bugfix: the burst threshold follows the model's line
    size instead of a hard-coded 64."""
    a = MemAccess("r", np.arange(10) * 48)  # stride between 32 and 64
    assert not a.window(0, 10, line_bytes=32)[1][1:].any()
    assert a.window(0, 10, line_bytes=64)[1][1:].all()
    # and MemoryModel.line_bytes is the cache line when a cache is present
    assert acp().line_bytes == 32
    assert acp_cache().line_bytes == CacheConfig().line_bytes


@pytest.mark.slow
def test_vectorized_speedup_at_65536():
    """Acceptance bar: the vectorized engines are >= 20x faster than the
    scalar reference at n_iters = 65536 with identical cycle counts —
    the same pipeline the CI perf trajectory (benchmarks.sweep
    measure_perf -> BENCH_sim.json) tracks."""
    import os
    import sys
    import time
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.sweep import _perf_pipeline
    n = 65536
    stages = _perf_pipeline(n)
    def best_of(fn, repeat=2):
        best, out = float("inf"), None
        for _ in range(repeat):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_ref, ref = best_of(lambda: simulate_dataflow(
        stages, acp(), n, fifo_depth=32, reference=True))
    t_vec, vec = best_of(lambda: simulate_dataflow(
        stages, acp(), n, fifo_depth=32))
    assert ref.cycles == vec.cycles
    assert ref.stage_stall_cycles == vec.stage_stall_cycles
    assert t_ref / t_vec >= 20.0, (t_ref, t_vec)
    t_cr, cr = best_of(lambda: simulate_conventional(
        stages, acp(), n, reference=True))
    t_cv, cv = best_of(lambda: simulate_conventional(stages, acp(), n))
    assert cr.cycles == cv.cycles
    assert t_cr / t_cv >= 20.0, (t_cr, t_cv)

"""Tests for the Fig. 2 / Fig. 5 fidelity simulator."""

import numpy as np
import pytest

from repro.core.simulator import (
    CacheConfig, CacheSim, MemAccess, MemoryModel, SimStage,
    acp, acp_cache, hp, hp_cache,
    simulate_conventional, simulate_dataflow, simulate_processor,
)


def _seq_trace(n, stride=4, base=0):
    return np.arange(n) * stride + base


def _rand_trace(n, span_bytes, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, span_bytes // 4, size=n) * 4


def test_cache_lru_and_hit_rate():
    c = CacheSim(CacheConfig(size_bytes=1024, line_bytes=32, ways=2))
    # sequential pass over 2 KB: first touch of each line misses,
    # subsequent words in the line hit.
    for a in range(0, 2048, 4):
        c.access(a)
    assert c.misses == 2048 // 32
    assert c.hits == 2048 // 4 - c.misses
    # second pass over the SAME first 512 bytes (fits) now hits
    h0 = c.hits
    for a in range(1024, 2048, 4):
        c.access(a)
    assert c.hits > h0


def test_dataflow_hides_latency_conventional_does_not():
    """The paper's central claim (Fig. 2): with a long-latency compute stage
    downstream, random-access misses are shadowed in the dataflow engine but
    stall the conventional engine."""
    n = 4000
    stages = [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("idx", _seq_trace(n))]),
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", _rand_trace(n, 16 << 20))]),
        SimStage("fma", ii=6, latency=8),   # long-latency fp pipeline
        SimStage("store", ii=1, latency=2,
                 accesses=[MemAccess("y", _seq_trace(n), is_store=True)]),
    ]
    mem = acp()
    df = simulate_dataflow(stages, mem, n)
    cv = simulate_conventional(stages, mem, n)
    assert df.cycles < cv.cycles, (df.cycles, cv.cycles)
    speedup = cv.cycles / df.cycles
    assert speedup > 2.0, f"expected substantial speedup, got {speedup:.2f}"
    # dataflow throughput should approach the compute II bound (6 cyc/iter)
    assert df.cycles_per_iter < 2.5 * 6


def test_cache_helps_conventional_more_than_dataflow():
    """Fig. 5: adding the 64KB cache cut conventional runtime by ~45% but
    dataflow only by ~19% — dataflow already tolerates latency."""
    n = 4000
    # reuse-heavy random trace so a cache actually captures something
    rng = np.random.default_rng(1)
    hot = rng.integers(0, 48 << 10, size=n) & ~3
    stages = [
        SimStage("fetch", ii=1, latency=2, accesses=[MemAccess("x", hot)]),
        SimStage("fma", ii=6, latency=8),
    ]
    cv_nc = simulate_conventional(stages, acp(), n).cycles
    cv_c = simulate_conventional(stages, acp_cache(64), n).cycles
    df_nc = simulate_dataflow(stages, acp(), n).cycles
    df_c = simulate_dataflow(stages, acp_cache(64), n).cycles
    conv_gain = 1 - cv_c / cv_nc
    df_gain = 1 - df_c / df_nc
    assert conv_gain > df_gain, (conv_gain, df_gain)


def test_hp_port_hurts_conventional():
    """Fig. 5: conventional degrades ~40% on the uncached HP port vs ACP."""
    n = 3000
    stages = [
        SimStage("fetch", ii=1, latency=2,
                 accesses=[MemAccess("x", _rand_trace(n, 8 << 20))]),
        SimStage("fma", ii=6, latency=8),
    ]
    cv_acp = simulate_conventional(stages, acp(), n).cycles
    cv_hp = simulate_conventional(stages, hp(), n).cycles
    assert cv_hp > cv_acp * 1.2


def test_mem_in_scc_gives_no_benefit():
    """The DFS negative result (§V-A): a dependence cycle through memory
    serializes access latency; dataflow ≈ conventional."""
    n = 2000
    trace = _rand_trace(n, 3 << 20, seed=2)
    # DFS: the adjacency load feeds the stack push — the whole loop body is
    # one SCC *through memory*, so Algorithm 1 yields a single stage with
    # the accesses inside the dependence cycle.
    stages = [
        SimStage("dfs_scc", ii=3, latency=3, mem_in_scc=True,
                 accesses=[MemAccess("stk", trace),
                           MemAccess("adj", _rand_trace(n, 3 << 20, 3))]),
    ]
    mem = acp()
    df = simulate_dataflow(stages, mem, n)
    cv = simulate_conventional(stages, mem, n)
    ratio = cv.cycles / df.cycles
    assert ratio < 1.8, f"DFS-like kernel should not benefit much: {ratio}"


def test_backpressure_bounds_runahead():
    """A bounded FIFO must prevent the producer from running unboundedly
    ahead of a slow consumer."""
    n = 1000
    fast = SimStage("prod", ii=1, latency=1)
    slow = SimStage("cons", ii=20, latency=4)
    r = simulate_dataflow([fast, slow], acp(), n, fifo_depth=4)
    # producer start times can lead consumer's by at most depth iterations
    # → total time governed by the slow stage, not hidden
    assert r.cycles >= 20 * (n - 1)


def test_processor_baseline_reasonable():
    n = 4000
    accesses = [MemAccess("x", _rand_trace(n, 16 << 20))]
    r = simulate_processor(instrs_per_iter=12, accesses=accesses, n_iters=n)
    assert r.cycles > 0
    assert r.freq_mhz == 667.0
    # scaled runtime extrapolation is monotone in iterations
    assert r.scaled_runtime(10 * n) > r.scaled_runtime(n)

"""Tests for the static dataflow verifier (repro.dataflow.verify).

Three groups:

* **mutation tests** — one per rule id: seed exactly the violation the
  rule exists to catch (split an SCC, drop a token channel, corrupt a
  width, duplicate a fed node, ...) and assert the verifier reports it
  under the right id, with error severity; each has a clean control.
* **property tests** (hypothesis, skipped without it) — every
  ``neighbor_plans`` / ``enumerate_plans`` candidate of a real CDFG
  passes the verifier, and the verifier agrees with ``plan_is_legal``.
* **the DSE acceptance test** — an exploration over a deliberately
  undersized ``fifo_depths`` axis statically prunes >0 candidates
  pre-simulation while the surviving Pareto front is bit-identical to
  a ``verify=False`` run (the pruning-soundness criterion
  ``bench_trend`` also gates on recorded artifacts).
"""

import dataclasses
import types

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cdfg import CDFG, Edge, Node
from repro.core.partition import (Channel, derive_channels,
                                  duplicate_cheap_rewrite, fused_plan,
                                  materialize, maximal_plan, merge_move,
                                  neighbor_plans, plan_is_legal,
                                  stage_groups)
from repro.dataflow import compile as dcompile
from repro.dataflow import (CompileOptions, ResourceConstraints,
                            enumerate_plans, explore_plans)
from repro.dataflow.verify import (RULES, Diagnostic, VerifyError,
                                   chain_deadlock_bound,
                                   deadlock_min_depth, enabled,
                                   fifo_depth_diagnostics, verify_compiled,
                                   verify_partition, verify_plan,
                                   verify_program)


def _fake_cdfg(nodes, edges):
    cdfg = types.SimpleNamespace(nodes=nodes, edges=edges)
    by_id = {n.id: n for n in nodes}
    cdfg.node = lambda nid: by_id[nid]
    return cdfg


def _node(nid, prim, *, memory=False, latency=1, region=None,
          store=False):
    return Node(id=nid, prim=prim, eqn=None, is_memory=memory,
                latency=latency, region=region, is_store=store)


class _FakeVar:
    def __init__(self):
        self.aval = types.SimpleNamespace(shape=(),
                                          dtype=np.dtype(np.float32))


def _chain_cdfg():
    """gather -> mul -> add, each its own SCC: a 3-stage chain."""
    v1, v2 = _FakeVar(), _FakeVar()
    nodes = [_node(0, "gather", memory=True, latency=4, region="t"),
             _node(1, "mul", latency=2), _node(2, "add")]
    edges = [Edge(0, 1, v1, "data"), Edge(1, 2, v2, "data")]
    return _fake_cdfg(nodes, edges)


def _rules_of(diags):
    return {d.rule for d in diags if d.severity == "error"}


# ---------------------------------------------------------------------------
# Mutation tests: one seeded violation per rule id
# ---------------------------------------------------------------------------


def test_clean_chain_verifies_clean():
    cdfg = _chain_cdfg()
    plan = stage_groups(cdfg)
    part = materialize(cdfg, plan)
    assert verify_plan(cdfg, plan) == []
    assert [d for d in verify_partition(part)
            if d.severity == "error"] == []


def test_mutation_plan_cover():
    cdfg = _chain_cdfg()
    plan = stage_groups(cdfg)
    bad = dataclasses.replace(plan, groups=plan.groups[:-1])  # drop one
    assert "plan-cover" in _rules_of(verify_plan(cdfg, bad))
    assert not plan_is_legal(cdfg, bad)


def test_mutation_plan_topo():
    cdfg = _chain_cdfg()
    plan = stage_groups(cdfg)
    bad = dataclasses.replace(plan, groups=list(reversed(plan.groups)))
    assert "plan-topo" in _rules_of(verify_plan(cdfg, bad))
    assert not plan_is_legal(cdfg, bad)


def test_mutation_scc_integrity():
    """Split a 2-node SCC across two groups: both the plan- and the
    partition-level check must name scc-integrity."""
    v1, v2 = _FakeVar(), _FakeVar()
    nodes = [_node(0, "add"), _node(1, "mul"), _node(2, "add")]
    # 0 <-> 1 is an SCC; 2 consumes it
    edges = [Edge(0, 1, v1, "data"), Edge(1, 0, v1, "carry"),
             Edge(1, 2, v2, "data")]
    cdfg = _fake_cdfg(nodes, edges)
    plan = stage_groups(cdfg)
    # corrupt the SCC map: claim node 1 belongs to node 2's SCC
    bad_map = dict(plan.scc_of_node)
    bad_map[1] = plan.scc_of_node[2]
    bad = dataclasses.replace(plan, scc_of_node=bad_map)
    assert "scc-integrity" in _rules_of(verify_plan(cdfg, bad))
    # partition-level: force one SCC member into a foreign stage
    part = materialize(cdfg, plan)
    part.stage_of_node[1] = 999
    assert "scc-integrity" in _rules_of(verify_partition(part))


def test_mutation_chan_missing():
    cdfg = _chain_cdfg()
    part = materialize(cdfg, stage_groups(cdfg))
    assert len(part.channels) == 2
    part.channels.pop()          # drop a data channel
    assert "chan-missing" in _rules_of(verify_partition(part))
    # the dual: a channel with no underlying edge
    part2 = materialize(cdfg, stage_groups(cdfg))
    part2.channels.append(Channel(0, 2, _FakeVar(), 4))
    assert "chan-missing" in _rules_of(verify_partition(part2))


def test_mutation_chan_width():
    cdfg = _chain_cdfg()
    part = materialize(cdfg, stage_groups(cdfg))
    part.channels[0] = dataclasses.replace(part.channels[0],
                                           nbytes=part.channels[0]
                                           .nbytes * 2)
    assert "chan-width" in _rules_of(verify_partition(part))


def test_mutation_mem_order_dropped_token():
    """Two same-region memory ops in different stages with a mem edge:
    removing the token channel is a mem-order error (not chan-missing —
    the diagnostic must name the §III-A family)."""
    v1 = _FakeVar()
    nodes = [_node(0, "scatter", memory=True, latency=2, region="t",
                   store=True),
             _node(1, "gather", memory=True, latency=8, region="t")]
    edges = [Edge(0, 1, v1, "data"), Edge(0, 1, None, "mem")]
    cdfg = _fake_cdfg(nodes, edges)
    part = materialize(cdfg, stage_groups(cdfg))
    toks = [c for c in part.channels if c.kind == "mem"]
    assert toks, "expected a materialized ordering-token channel"
    part.channels = [c for c in part.channels if c.kind != "mem"]
    diags = verify_partition(part)
    assert "mem-order" in _rules_of(diags)


def test_mutation_mem_order_duplicated_feeder():
    """A §III-B1 replica of a node that has feeder edges drops the
    feeders' ordering — the verifier re-checks the rewrite's guard."""
    cdfg = _chain_cdfg()
    part = materialize(cdfg, stage_groups(cdfg))
    # node 1 has a feeder (edge 0->1); pretend it was duplicated anyway
    part.duplicated[1] = [part.stage_of_node[2]]
    assert "mem-order" in _rules_of(verify_partition(part))


def test_mutation_chan_cycle():
    cdfg = _chain_cdfg()
    part = materialize(cdfg, stage_groups(cdfg))
    part.channels.append(Channel(part.stage_of_node[2],
                                 part.stage_of_node[0], None, 0, "mem"))
    assert "chan-cycle" in _rules_of(verify_partition(part))


def test_mutation_fifo_depth():
    """A chunky-latency first stage at depth 1 statically deadlocks
    (error); a depth between the collapse and full-throughput bounds
    warns."""
    v1 = _FakeVar()
    nodes = [_node(0, "gather", memory=True, latency=40, region="t"),
             _node(1, "add")]
    cdfg = _fake_cdfg(nodes, [Edge(0, 1, v1, "data")])
    part = materialize(cdfg, stage_groups(cdfg))
    dead = deadlock_min_depth(part)
    assert dead > 1
    diags = fifo_depth_diagnostics(part, [1, dead, 0])
    by_loc = {d.loc: d for d in diags}
    assert by_loc["fifo_depth=1"].severity == "error"
    assert by_loc["fifo_depth=0"].severity == "error"
    # at the bound itself: legal, at worst a throughput warning
    assert all(d.severity != "error" for d in diags
               if d.loc == f"fifo_depth={dead}")
    assert all(d.rule == "fifo-depth" for d in diags)


def test_mutation_race():
    """Same-region stores in parallel stages with no ordering path: an
    error under strict races, a warning when the user opted out of
    §III-A ordering."""
    # two independent stores to the same region: no dependence edge, so
    # no channel path — exactly what add_memory_order_edges would have
    # serialized
    nodes = [_node(0, "scatter", memory=True, region="m", store=True),
             _node(1, "scatter", memory=True, region="m", store=True)]
    cdfg = _fake_cdfg(nodes, [])
    part = materialize(cdfg, stage_groups(cdfg))
    assert part.stage_of_node[0] != part.stage_of_node[1]
    diags = verify_partition(part, strict_races=True)
    assert "race" in _rules_of(diags)
    relaxed = verify_partition(part, strict_races=False)
    assert "race" not in _rules_of(relaxed)
    assert any(d.rule == "race" and d.severity == "warning"
               for d in relaxed)
    # control: the §III-A ordering token kills the race
    cdfg2 = _fake_cdfg(nodes, [Edge(0, 1, None, "mem")])
    part2 = materialize(cdfg2, stage_groups(cdfg2))
    if part2.stage_of_node[0] != part2.stage_of_node[1]:
        assert "race" not in _rules_of(
            verify_partition(part2, strict_races=True))
    # loads-only pairs always commute
    loads = [_node(0, "gather", memory=True, region="m"),
             _node(1, "gather", memory=True, region="m")]
    cdfg3 = _fake_cdfg(loads, [])
    part3 = materialize(cdfg3, stage_groups(cdfg3))
    assert "race" not in _rules_of(
        verify_partition(part3, strict_races=True))


def test_mutation_transform_timing():
    cdfg = _chain_cdfg()
    part = materialize(cdfg, stage_groups(cdfg))
    st0 = part.stages[0]
    part.stages[0] = dataclasses.replace(st0, latency=st0.latency + 7)
    assert "transform" in _rules_of(verify_partition(part))


def test_mutation_decouple():
    def fn(table, idx, w):
        return jnp.tanh(table[idx] * w) + 1.0

    c = dcompile(fn, jnp.arange(8, dtype=jnp.float32), jnp.int32(1),
                 jnp.float32(2.0))
    prog = c.program
    assert verify_program(prog) == []
    bad = dataclasses.replace(
        prog, producer_stage={**prog.producer_stage,
                              "ghost-var": 10_000})
    assert "decouple" in _rules_of(verify_program(bad))
    # stage-count mismatch
    bad2 = dataclasses.replace(prog, stages=prog.stages[:-1])
    assert "decouple" in _rules_of(verify_program(bad2))


def test_every_rule_id_has_a_mutation_test():
    """The catalog and this module stay in sync: every id in RULES is
    asserted somewhere above."""
    import pathlib
    src = pathlib.Path(__file__).read_text()
    for rule in RULES:
        assert f'"{rule}"' in src, f"no mutation coverage for {rule!r}"


# ---------------------------------------------------------------------------
# Pipeline hook + surfaces
# ---------------------------------------------------------------------------


def test_pipeline_hook_names_offending_pass():
    """A pass that corrupts the partition is caught by the inter-pass
    hook, which names it."""
    from repro.dataflow.passes import Pass, default_pipeline

    class CorruptPass(Pass):
        name = "corrupt"

        def run(self, ctx):
            ctx.partition.channels.pop()

    def fn(table, idx, w):
        return jnp.tanh(table[idx] * w) + 1.0

    pipe = default_pipeline().insert_after("rewrite", CorruptPass())
    with pytest.raises(VerifyError) as ei:
        dcompile(fn, jnp.arange(8, dtype=jnp.float32), jnp.int32(1),
                 jnp.float32(2.0), pipeline=pipe, use_cache=False)
    assert ei.value.where == "corrupt"
    assert any(d.rule in ("chan-missing", "mem-order")
               for d in ei.value.diagnostics)
    # verify=False compiles straight through the same corruption
    c = dcompile(fn, jnp.arange(8, dtype=jnp.float32), jnp.int32(1),
                 jnp.float32(2.0), pipeline=pipe, use_cache=False,
                 options=CompileOptions(verify=False))
    assert c.program is not None


def test_env_escape_hatch(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert not enabled(CompileOptions(verify=True))
    monkeypatch.delenv("REPRO_VERIFY")
    assert enabled(CompileOptions(verify=True))
    assert not enabled(CompileOptions(verify=False))
    assert enabled(None)


def test_compiled_verify_and_report():
    def fn(table, idx, w):
        return jnp.tanh(table[idx] * w) + 1.0

    c = dcompile(fn, jnp.arange(8, dtype=jnp.float32), jnp.int32(1),
                 jnp.float32(2.0))
    diags = c.verify()
    assert all(isinstance(d, Diagnostic) for d in diags)
    assert not [d for d in diags if d.severity == "error"]
    assert "verify:" in c.report()
    # an undersized depth axis surfaces as fifo-depth errors + raise
    bad = c.verify(fifo_depths=[0])
    assert "fifo-depth" in _rules_of(bad)
    with pytest.raises(VerifyError):
        c.verify(fifo_depths=[0], raise_on_error=True)
    assert verify_compiled(c) == c.verify()


# ---------------------------------------------------------------------------
# Deadlock bounds
# ---------------------------------------------------------------------------


def test_chain_bound_matches_simulator_floor():
    """Below the chain bound, the simulated machine is no faster than
    serialized execution; at the bound it strictly beats it (the bound
    is tight on this chain)."""
    from repro.core.simulator import MemAccess, SimStage, acp, \
        simulate_dataflow

    n = 256
    tr = MemAccess("t", np.arange(n) * 4)
    lats, iis = [40, 1], [1, 1]
    stages = [SimStage("s0", 1, 40, [tr], False),
              SimStage("s1", 1, 1, [], False)]
    bound = chain_deadlock_bound(lats, iis)
    assert bound > 1
    serial = sum(iis)

    def cyc_per_iter(depth):
        r = simulate_dataflow(stages, acp(), n, fifo_depth=depth, seed=0)
        return r.cycles / n

    # depths below the bound cannot beat back-to-back execution...
    assert cyc_per_iter(bound - 1) >= serial
    # ...while the bound itself restores pipelining over depth 1
    assert cyc_per_iter(bound) < cyc_per_iter(1)


def test_chain_bound_edge_cases():
    assert chain_deadlock_bound([], []) == 1
    assert chain_deadlock_bound([100], [1]) == 1     # single stage
    assert chain_deadlock_bound([1, 1], [1, 1]) == 1  # cheap chain
    # final-stage latency never binds (nothing downstream backpressures)
    assert chain_deadlock_bound([1, 100], [1, 1]) == 1


def test_deadlock_min_depth_matches_chain_on_chains():
    cdfg = _chain_cdfg()
    part = materialize(cdfg, stage_groups(cdfg))
    lats = [s.latency for s in part.stages]
    iis = [s.ii for s in part.stages]
    assert deadlock_min_depth(part) == chain_deadlock_bound(lats, iis)


# ---------------------------------------------------------------------------
# Property tests: the move set stays inside the verified space
# ---------------------------------------------------------------------------


def _real_cdfg():
    def body(acc, j, vals, cols, xv):
        return acc + vals[j] * xv[cols[j]]

    vals = jnp.arange(64, dtype=jnp.float32)
    cols = jnp.arange(64) % 16
    xv = jnp.arange(16, dtype=jnp.float32)
    return CDFG.from_function(body, jnp.float32(0.0), jnp.int32(0),
                              vals, cols, xv)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_neighbor_plans_verify_clean(data):
    """Any random walk through neighbor_plans stays verifier-clean:
    merge/split moves can never break cover, SCC integrity, or the topo
    order — and the materialized partitions re-derive cleanly."""
    cdfg = _real_cdfg()
    plan = stage_groups(cdfg)
    for _ in range(data.draw(st.integers(min_value=1, max_value=4))):
        nbrs = neighbor_plans(plan)
        if not nbrs:
            break
        _, plan = data.draw(st.sampled_from(nbrs))
        assert plan_is_legal(cdfg, plan)
        assert verify_plan(cdfg, plan) == []
    part = materialize(cdfg, plan)
    duplicate_cheap_rewrite(part)
    assert not _rules_of(verify_partition(part))


def test_enumerated_candidates_verify_clean():
    """Deterministic version of the property: every enumerate_plans
    candidate (the DSE's actual move lane) is legal and verifier-clean,
    and the verifier agrees with plan_is_legal on seeded illegals."""
    cdfg = _real_cdfg()
    base = stage_groups(cdfg)
    cands = enumerate_plans(cdfg, base, 32)
    assert len(cands) > 2
    for _, plan in cands:
        assert plan_is_legal(cdfg, plan)
        assert verify_plan(cdfg, plan) == []
        part = materialize(cdfg, plan)
        assert not _rules_of(verify_partition(part))
    for plan in (fused_plan(base), maximal_plan(base)):
        assert plan_is_legal(cdfg, plan)
        assert verify_plan(cdfg, plan) == []
    # verifier <-> legality-oracle agreement on a seeded illegal
    bad = dataclasses.replace(base, groups=list(reversed(base.groups)))
    if len(base.groups) > 1:
        assert not plan_is_legal(cdfg, bad)
        assert _rules_of(verify_plan(cdfg, bad))


def test_plan_is_legal_rejects_uncovered_mem_edge():
    """Satellite 1: a plan that does not cover a mem edge's endpoint
    would silently drop the ordering token in derive_channels — the
    legality oracle must reject it (it used to KeyError or pass)."""
    v1 = _FakeVar()
    nodes = [_node(0, "scatter", memory=True, region="t", store=True),
             _node(1, "gather", memory=True, region="t")]
    edges = [Edge(0, 1, v1, "data"), Edge(0, 1, None, "mem")]
    cdfg = _fake_cdfg(nodes, edges)
    plan = stage_groups(cdfg)
    # a plan built for a smaller CDFG: node 1 unmapped
    stale = dataclasses.replace(
        plan,
        scc_of_node={k: v for k, v in plan.scc_of_node.items()
                     if k != 1})
    assert not plan_is_legal(cdfg, stale)
    assert "mem-order" in _rules_of(verify_plan(cdfg, stale))
    # and the verifier agrees with the oracle on the clean plan
    assert plan_is_legal(cdfg, plan)
    assert verify_plan(cdfg, plan) == []


# ---------------------------------------------------------------------------
# DSE acceptance: pruning wins wall time, never moves the front
# ---------------------------------------------------------------------------


def test_dse_prunes_deadlocking_depths_front_identical():
    """The acceptance criterion: with a deliberately undersized depth
    axis, verification prunes >0 (plan, depth) candidates before
    simulation, and the surviving Pareto front is bit-identical to the
    unpruned (verify=False) exploration."""
    def body(acc, j, vals, cols, xv):
        return acc + vals[j] * xv[cols[j]]

    vals = jnp.arange(64, dtype=jnp.float32)
    cols = jnp.arange(64) % 16
    xv = jnp.arange(16, dtype=jnp.float32)
    # chunky gather latency makes the collapse bound land inside the
    # explored depth axis
    c = dcompile(body, jnp.float32(0.0), jnp.int32(0), vals, cols, xv,
                 latency_table={"gather": 48}, long_threshold=4,
                 use_cache=False)
    kw = dict(n_iters=64, fifo_depths=[1, 2, 16],
              constraints=ResourceConstraints(max_candidates=6))
    r_on = c.explore(verify=True, **kw)
    r_off = c.explore(verify=False, **kw)

    assert r_on.eval_stats["pruned_deadlock"] > 0
    assert any("deadlock" in (cand.pruned or "")
               for cand in r_on.candidates)
    # every pruned candidate carries its bound, and sits below it
    for cand in r_on.candidates:
        if cand.pruned and cand.pruned.startswith("deadlock"):
            assert cand.fifo_depth < cand.deadlock_min_depth
    # pruned candidates were never simulated (the wall win; the
    # baseline is the one exception — it is always the comparison
    # point)
    assert all(cand.cycles is None for cand in r_on.candidates
               if cand.pruned and cand is not r_on.baseline)
    assert len(r_on.evaluated()) < len(r_off.evaluated())

    def key(front):
        return [(cand.groups, cand.duplicate, cand.transform,
                 cand.mem_name, cand.fifo_depth, cand.cycles,
                 cand.fifo_bits) for cand in front]

    assert key(r_on.front) == key(r_off.front)
    assert r_on.best().cycles == r_off.best().cycles
    # counters ride into the recorded artifact
    j = r_on.to_json()
    assert j["pruned_deadlock"] == r_on.eval_stats["pruned_deadlock"]
    assert j["front"][0]["deadlock_min_depth"] is not None


def test_dse_race_prune_requires_mem_edges():
    """Race pruning only fires when the CDFG carries §III-A mem edges;
    compiling with add_memory_edges=False must not prune (the user
    asserted non-aliasing)."""
    def body(acc, j, vals, cols, xv):
        return acc + vals[j] * xv[cols[j]]

    vals = jnp.arange(64, dtype=jnp.float32)
    cols = jnp.arange(64) % 16
    xv = jnp.arange(16, dtype=jnp.float32)
    c = dcompile(body, jnp.float32(0.0), jnp.int32(0), vals, cols, xv,
                 add_memory_edges=False, use_cache=False)
    r = c.explore(n_iters=32, verify=True,
                  constraints=ResourceConstraints(max_candidates=4))
    assert r.eval_stats["pruned_race"] == 0


def test_bench_trend_gates_pruned_front_points():
    """Satellite 2: the trend gate hard-fails a recorded front point
    that is pruned or sits below its own deadlock bound."""
    from benchmarks.bench_trend import compare

    def payload(point):
        return {"dse": {"smoke": True, "kernels": {"k": {
            "front": [point]}}}}

    ok = payload({"fifo_depth": 8, "deadlock_min_depth": 2,
                  "pruned": None, "fifo_bits": 64})
    fails, _ = compare({}, ok)
    assert not [f for f in fails if "dse k" in f]
    bad1 = payload({"fifo_depth": 8, "deadlock_min_depth": 2,
                    "pruned": "deadlock: ...", "fifo_bits": 64})
    fails, _ = compare({}, bad1)
    assert any("statically pruned" in f for f in fails)
    bad2 = payload({"fifo_depth": 1, "deadlock_min_depth": 5,
                    "pruned": None, "fifo_bits": 64})
    fails, _ = compare({}, bad2)
    assert any("below its static deadlock bound" in f for f in fails)


def test_merge_move_keeps_verifier_clean_after_dup():
    """Regression guard for the §III-B1 interaction: merging stages
    after duplication re-materializes cleanly under the verifier."""
    cdfg = _real_cdfg()
    plan = stage_groups(cdfg)
    if len(plan.groups) < 2:
        pytest.skip("needs a multi-stage plan")
    merged = merge_move(plan, 0)
    part = materialize(cdfg, merged)
    duplicate_cheap_rewrite(part)
    assert not _rules_of(verify_partition(part))
    assert {(ch.src_stage, ch.dst_stage, ch.var) for ch in part.channels} \
        == {(ch.src_stage, ch.dst_stage, ch.var)
            for ch in derive_channels(part)}

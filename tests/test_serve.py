"""Tests for the resolution daemon (repro.serve): bit-identity with the
library engines, three-way dedup, fairness/backpressure, failure
semantics (worker death, client disconnect), and the serve plumbing in
``simulate_dataflow_many`` / ``sweep_schedule``."""

import contextlib
import multiprocessing
import os
import subprocess
import sys
import tempfile
import threading
import time

import pytest

from repro.core import rescache as rc
from repro.core.simulator import (acp, acp_cache, hp_cache,
                                  simulate_dataflow_many)

import _serve_client
from _serve_client import pipeline


@pytest.fixture()
def store(tmp_path, monkeypatch):
    """Isolated store + a tiny canonical chunk grid (512), propagated
    to spawn children (daemon workers get it via the constructor, test
    client subprocesses via the environment)."""
    d = str(tmp_path / "store")
    rc.clear()
    rc.configure(enabled=True, directory=d)
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    monkeypatch.setenv("REPRO_CHUNK_ITERS", "512")
    yield d
    rc.clear()
    rc.configure(enabled=False)


@contextlib.contextmanager
def daemon(**kw):
    """A started in-process daemon on a short-path private socket
    (AF_UNIX paths cap at ~107 bytes; pytest tmp_paths can exceed it)."""
    from repro.serve.daemon import ResolutionDaemon
    sdir = tempfile.mkdtemp(prefix="serve-")
    kw.setdefault("workers", 2)
    d = ResolutionDaemon(address=os.path.join(sdir, "d.sock"), **kw)
    d.start()
    try:
        yield d
    finally:
        d.stop()


def _key(v):
    return (v.cycles, v.cache_hits, v.cache_misses,
            v.stage_stall_cycles)


# ---------------------------------------------------------------------------
# Bit-identity with library mode
# ---------------------------------------------------------------------------

def test_served_equals_library(store):
    """Cold daemon resolution == library streaming engine, down to
    cycles, stall buckets, and cache stats, across cached / uncached /
    write-around models and a FIFO-depth grid."""
    from repro.serve.client import simulate_dataflow_served
    n = 5000
    mems = {"ACP": acp(), "ACPC": acp_cache(), "HPC": hp_cache()}
    ref = simulate_dataflow_many(pipeline(n), dict(mems), n,
                                 fifo_depths=(4, 16),
                                 use_rescache=False)
    with daemon() as d:
        got = simulate_dataflow_served(pipeline(n), dict(mems), n,
                                       fifo_depths=(4, 16),
                                       address=d.address)
        st = d.stats()
    assert set(got) == set(ref)
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k
    assert st["dedup"]["cold_chunks"] == 10  # ceil(5000/512)
    assert st["jobs_completed"] == 1
    assert st["requests"] and st["requests"][0]["chunks"] == 10


def test_mid_chunk_tail_and_prefix_extension(store):
    """n_iters off the canonical grid (mid-chunk cache stats from the
    tail planes), then a longer run extending the same artifact: the
    extension resumes from the stored records, never re-resolving the
    prefix."""
    from repro.serve.client import simulate_dataflow_served
    mems = {"ACPC": acp_cache()}
    short, full = 1400, 5000  # 1400 ends mid-chunk (C=512)
    ref_s = simulate_dataflow_many(pipeline(full), {"ACPC": acp_cache()},
                                   short, fifo_depths=(8,),
                                   use_rescache=False)
    ref_f = simulate_dataflow_many(pipeline(full), {"ACPC": acp_cache()},
                                   full, fifo_depths=(8,),
                                   use_rescache=False)
    with daemon() as d:
        got_s = simulate_dataflow_served(
            pipeline(full), dict(mems), short, fifo_depths=(8,),
            address=d.address)
        st0 = d.stats()
        got_f = simulate_dataflow_served(
            pipeline(full), dict(mems), full, fifo_depths=(8,),
            address=d.address)
        st1 = d.stats()
    for k in ref_s:
        assert _key(got_s[k]) == _key(ref_s[k]), k
    for k in ref_f:
        assert _key(got_f[k]) == _key(ref_f[k]), k
    # the short run resolved 3 chunks; the extension only the residue
    assert st0["dedup"]["cold_chunks"] == 3
    assert st1["dedup"]["cold_chunks"] == 10
    assert st1["dedup"]["store_chunks"] \
        + st1["dedup"]["inflight_chunks"] == 3


def test_server_kwarg_falls_back_without_daemon(store):
    """simulate_dataflow_many(server=...) with no daemon answers from
    the local engines (ServeUnavailable is not a user-facing error)."""
    n = 2000
    ref = simulate_dataflow_many(pipeline(n), {"ACP": acp()}, n,
                                 fifo_depths=(8,), use_rescache=False)
    got = simulate_dataflow_many(pipeline(n), {"ACP": acp()}, n,
                                 fifo_depths=(8,),
                                 server=os.path.join(
                                     tempfile.mkdtemp(), "absent.sock"))
    for k in ref:
        assert got[k].cycles == ref[k].cycles


# ---------------------------------------------------------------------------
# Multi-tenant dedup (the acceptance scenario)
# ---------------------------------------------------------------------------

def test_racing_clients_resolve_exactly_once(store):
    """Two concurrent client *processes* race the same request through
    one daemon: results bit-identical to each other and to library
    mode, the shared keyset resolved exactly once (every chunk one
    client paid cold, the other got from the store prefix or by
    attaching in flight), and neither client resolved anything locally."""
    n = 5000
    ref = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), use_rescache=False)
    refd = {k: (v.cycles, v.cache_hits, v.cache_misses)
            for k, v in ref.items()}
    ctx = multiprocessing.get_context("spawn")
    with daemon(throttle_s=0.1) as d:
        barrier = ctx.Barrier(2)
        q = ctx.Queue()
        procs = [ctx.Process(target=_serve_client.race_client,
                             args=(i, store, d.address, barrier, q, n))
                 for i in range(2)]
        for p in procs:
            p.start()
        outs = {}
        try:
            for _ in range(2):
                i, o, local_cold = q.get(timeout=180)
                outs[i] = o
                assert local_cold == 0, o
        finally:
            for p in procs:
                p.join(timeout=30)
                if p.is_alive():
                    p.terminate()
        st = d.stats()
    assert outs[0] == refd, outs[0]
    assert outs[1] == refd, outs[1]
    ded = st["dedup"]
    assert ded["inflight_chunks"] > 0  # the race actually overlapped
    # exactly-once: every served chunk was resolved cold exactly once
    assert ded["cold_chunks"] == \
        ded["store_chunks"] + ded["inflight_chunks"] == 10
    assert st["jobs_completed"] == 1


# ---------------------------------------------------------------------------
# Failure semantics
# ---------------------------------------------------------------------------

def _raw_resolve(address, stages, mems, n, *, seed=0, req="t.0"):
    """Open a raw client connection and submit one resolve (the
    protocol-level moves of simulate_dataflow_served, without the fold
    loop — so tests can disconnect at a controlled point)."""
    import cloudpickle

    from repro.serve import protocol
    keys = {mn: rc.resolution_key("dataflow", stages, m, seed)
            for mn, m in mems.items()}
    payload = cloudpickle.dumps({
        "stages": stages, "mems": mems, "seed": seed, "n_iters": n,
        "keys": keys})
    conn = protocol.connect(address, timeout=10.0)
    conn.settimeout(120.0)
    protocol.send_msg(conn, {
        "type": "resolve", "req": req, "keys": keys, "mems": mems,
        "seed": seed, "n_iters": n, "chunk_iters": rc.CHUNK_ITERS,
        "store_dir": rc._dir(), "payload": payload, "weight": 1.0})
    return conn, protocol.recv_msg(conn)


def test_disconnect_keeps_shared_chunks_running(store):
    """Client A disconnects mid-request: the daemon survives, chunks
    client B still needs keep running, and B's results stay exact."""
    from repro.serve.client import ping, simulate_dataflow_served
    n = 5000
    ref = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), use_rescache=False)
    out, err = {}, []

    def client_b(address):
        try:
            out.update(simulate_dataflow_served(
                pipeline(n), {"ACPC": acp_cache()}, n,
                fifo_depths=(8,), address=address))
        except Exception as e:  # noqa: BLE001
            err.append(e)

    with daemon(throttle_s=0.1) as d:
        t = threading.Thread(target=client_b, args=(d.address,))
        t.start()
        # A attaches to B's in-flight job, then drops without reading
        conn, resp = _raw_resolve(d.address, pipeline(n),
                                  {"ACPC": acp_cache()}, n, req="a.0")
        assert resp["type"] == "accepted"
        conn.close()
        t.join(timeout=180)
        assert not t.is_alive()
        assert ping(d.address)  # the daemon did not die with A
        st = d.stats()
    assert not err, err
    for k in ref:
        assert _key(out[k]) == _key(ref[k]), k
    # B still needed every chunk: nothing was cancelled
    assert st["failures"]["cancelled_chunks"] == 0


def test_orphaned_request_cancels_undispatched_chunks(store):
    """A request nobody shares cancels its never-dispatched chunks on
    disconnect — and the partial prefix it did resolve stays in the
    store, so a later identical request resumes past it."""
    from repro.serve.client import simulate_dataflow_served
    n = 5000
    with daemon(throttle_s=0.25, workers=2) as d:
        conn, resp = _raw_resolve(d.address, pipeline(n),
                                  {"ACPC": acp_cache()}, n, req="o.0")
        assert resp["type"] == "accepted"
        time.sleep(0.6)  # a couple of dispatches at most (throttled)
        conn.close()
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            st = d.stats()
            if st["failures"]["cancelled_chunks"] > 0 \
                    and not st["jobs_active"]:
                break
            time.sleep(0.1)
        assert st["failures"]["cancelled_chunks"] > 0
        # revival: the same request later completes through the daemon
        got = simulate_dataflow_served(pipeline(n),
                                       {"ACPC": acp_cache()}, n,
                                       fifo_depths=(8,),
                                       address=d.address)
    ref = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), use_rescache=False)
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k


def test_worker_death_recovery_and_stats(store):
    """Killing a pool worker mid-run: the daemon respawns it, replays
    the lost chunks' phase messages, the run completes bit-identically,
    and the churn is visible in stats (worker_restarts / chunk_retries
    / census worker_retries)."""
    from repro.serve.client import simulate_dataflow_served
    n = 5000
    ref = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), use_rescache=False)
    out, err = {}, []

    def client(address):
        try:
            out.update(simulate_dataflow_served(
                pipeline(n), {"ACPC": acp_cache()}, n,
                fifo_depths=(8,), address=address))
        except Exception as e:  # noqa: BLE001
            err.append(e)

    with daemon(throttle_s=0.2, workers=2) as d:
        t = threading.Thread(target=client, args=(d.address,))
        t.start()
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if any(w == 0 for w in d._inflight.values()):
                d._procs[0].kill()  # worker 0 dies holding chunks
                break
            time.sleep(0.02)
        else:
            pytest.fail("worker 0 never held an in-flight chunk")
        t.join(timeout=180)
        assert not t.is_alive()
        st = d.stats()
    assert not err, err
    for k in ref:
        assert _key(out[k]) == _key(ref[k]), k
    assert st["failures"]["worker_restarts"] >= 1
    assert st["failures"]["chunk_retries"] >= 1
    assert st["census"]["worker_retries"] >= 1


def test_retry_budget_exhaustion_fails_loudly(store):
    """retry_budget=0: the first worker death fails the job and every
    attached request — no infinite respawn loops."""
    from repro.serve.client import (ServeUnavailable,
                                    simulate_dataflow_served)
    n = 5000
    err = []

    def client(address):
        try:
            simulate_dataflow_served(pipeline(n), {"ACPC": acp_cache()},
                                     n, fifo_depths=(8,),
                                     address=address)
        except ServeUnavailable as e:
            err.append(e)

    with daemon(throttle_s=0.2, workers=2, retry_budget=0) as d:
        t = threading.Thread(target=client, args=(d.address,))
        t.start()
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if any(w == 0 for w in d._inflight.values()):
                d._procs[0].kill()
                break
            time.sleep(0.02)
        t.join(timeout=180)
        assert not t.is_alive()
        st = d.stats()
    assert err and "retry budget" in str(err[0])
    assert st["failures"]["jobs_failed"] == 1


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_backpressure_rejects_with_retry_after(store):
    """A cold request past the global queue cap gets ``busy`` +
    retry-after, not an unbounded queue entry."""
    n = 5000  # 10 chunks > max_queued_chunks
    with daemon(max_queued_chunks=2) as d:
        conn, resp = _raw_resolve(d.address, pipeline(n),
                                  {"ACPC": acp_cache()}, n)
        conn.close()
        st = d.stats()
    assert resp["type"] == "busy"
    assert resp["retry_after_s"] > 0
    assert st["admission"]["rejected"] == 1 and \
        st["admission"]["accepted"] == 0


def test_per_client_budget(store):
    """The per-client outstanding-chunks budget rejects a second
    oversized request from the same connection."""
    from repro.serve import protocol
    n = 5000
    with daemon(max_client_chunks=15, throttle_s=0.2) as d:
        conn, resp = _raw_resolve(d.address, pipeline(n),
                                  {"ACPC": acp_cache()}, n, req="b.0")
        assert resp["type"] == "accepted"
        # second request on the same conn: 10 outstanding + 10 > 15
        import cloudpickle
        stages2 = pipeline(n, seed=7)
        mems = {"ACPC": acp_cache()}
        keys = {mn: rc.resolution_key("dataflow", stages2, m, 0)
                for mn, m in mems.items()}
        protocol.send_msg(conn, {
            "type": "resolve", "req": "b.1", "keys": keys,
            "mems": mems, "seed": 0, "n_iters": n,
            "chunk_iters": rc.CHUNK_ITERS, "store_dir": rc._dir(),
            "payload": cloudpickle.dumps({
                "stages": stages2, "mems": mems, "seed": 0,
                "n_iters": n, "keys": keys}),
            "weight": 1.0})
        while True:
            m = protocol.recv_msg(conn)
            if m.get("req") == "b.1":
                break
        conn.close()
    assert m["type"] == "busy"


def test_store_mismatch_rejected(store):
    """A client on a different store directory is refused (serving a
    foreign store would interleave incompatible artifacts)."""
    from repro.serve import protocol
    import cloudpickle
    stages = pipeline(1000)
    mems = {"ACP": acp()}
    keys = {"ACP": rc.resolution_key("dataflow", stages, mems["ACP"], 0)}
    with daemon() as d:
        conn = protocol.connect(d.address, timeout=10.0)
        protocol.send_msg(conn, {
            "type": "resolve", "req": "x", "keys": keys, "mems": mems,
            "seed": 0, "n_iters": 1000, "chunk_iters": rc.CHUNK_ITERS,
            "store_dir": tempfile.mkdtemp(),
            "payload": cloudpickle.dumps({}), "weight": 1.0})
        resp = protocol.recv_msg(conn)
        conn.close()
    assert resp["type"] == "error"
    assert "store" in resp["reason"]


# ---------------------------------------------------------------------------
# Driver / benchmark plumbing
# ---------------------------------------------------------------------------

def test_sweep_rows_record_resolution_mode(store):
    """sweep_schedule rows carry the resolution mode (streaming /
    sharded:N / served:ADDR) so BENCH trend comparisons can tell the
    paths apart."""
    from repro.dataflow.schedule import sweep_schedule

    class _Sched:
        channel_bytes = 4

        def sim_stages(self, traces=None, **kw):
            return pipeline(2000)

    res = sweep_schedule(_Sched(), n_iters=2000, mems={"ACP": acp},
                         fifo_depths=(8,))
    assert all(r["resolution_mode"] == "streaming" for r in res.rows)
    with daemon() as d:
        res2 = sweep_schedule(_Sched(), n_iters=2000,
                              mems={"ACP": acp}, fifo_depths=(8,),
                              server=d.address)
    assert all(r["resolution_mode"] == f"served:{d.address}"
               for r in res2.rows)
    for a, b in zip(res.rows, res2.rows):
        assert a["dataflow_cycles"] == b["dataflow_cycles"]


def test_default_workers_heuristic():
    """<4 cores fall back to streaming unless explicitly overridden;
    ≥4 cores split the leftover cores across concurrent jobs."""
    from repro.core.chunkgraph import default_workers
    assert default_workers(cpus=1) == 1
    assert default_workers(cpus=2) == 1
    assert default_workers(cpus=3) == 1
    assert default_workers(cpus=4) == 4
    assert default_workers(cpus=8, jobs=2) == 4
    assert default_workers(cpus=8, jobs=8) == 2   # floor of 2
    assert default_workers(cpus=2, explicit=6) == 6
    assert default_workers(cpus=16, full=False) == 1


def test_gc_cli(store):
    """``run.py gc --max-bytes`` drives rescache.gc() on the
    configured store."""
    d = rc._dir()
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "orphan.tmp"), "wb") as f:
        f.write(b"x" * 128)
    env = dict(os.environ, REPRO_RESCACHE_DIR=d,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "gc",
         "--max-bytes", "0"],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stderr
    assert "orphans_removed" in out.stdout
    assert not os.path.exists(os.path.join(d, "orphan.tmp"))


def test_daemon_cli_stats_and_shutdown(store):
    """The launch CLI: foreground daemon in a subprocess, stats as
    JSON, shutdown tears it down."""
    import json
    sdir = tempfile.mkdtemp(prefix="serve-")
    sock = os.path.join(sdir, "cli.sock")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "daemon",
         "--socket", sock, "--workers", "1", "--store-dir", rc._dir()],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        from repro.serve.client import ping
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline and not ping(sock):
            time.sleep(0.2)
        assert ping(sock)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "stats",
             "--socket", sock], env=env, capture_output=True, text=True)
        assert out.returncode == 0, out.stderr
        stats = json.loads(out.stdout)
        assert stats["chunk_iters"] == 512
        assert stats["workers"] == 1
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "shutdown",
             "--socket", sock], env=env, capture_output=True, text=True)
        assert out.returncode == 0, out.stderr
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()

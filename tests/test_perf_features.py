"""Correctness tests for the §Perf hillclimb features: each optimization
must preserve semantics (exactly, for reassociations; within quantization
bounds, for int8 paths)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_config, reduced
from repro.models import attention, decode_step, init_params, prefill
from repro.models import moe as moe_mod


@pytest.mark.slow
def test_mla_absorbed_matches_naive():
    """Absorbed MLA decode is the same linear algebra reassociated —
    results must match the naive decompress-and-attend path closely."""
    cfg = reduced(load_config("deepseek-v3-671b"), max_repeats=1)
    cfg_abs = dataclasses.replace(cfg, mla_absorbed=True)
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    B, n = 2, 6
    tokens = jax.random.randint(rng, (B, n + 1), 0, cfg.vocab_size)
    _, cache = prefill(params, tokens[:, :n], cfg, max_len=n + 4)
    naive, _ = decode_step(params, tokens[:, n], cache,
                           jnp.asarray(n, jnp.int32), cfg)
    absorbed, _ = decode_step(params, tokens[:, n], cache,
                              jnp.asarray(n, jnp.int32), cfg_abs)
    np.testing.assert_allclose(np.asarray(absorbed), np.asarray(naive),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_close_to_bf16():
    cfg = reduced(load_config("qwen2.5-14b"), max_repeats=1)
    cfg_q = dataclasses.replace(cfg, kv_cache_dtype="int8")
    rng = jax.random.PRNGKey(1)
    params = init_params(rng, cfg)
    B, n = 2, 8
    tokens = jax.random.randint(rng, (B, n + 1), 0, cfg.vocab_size)
    # baseline
    _, cache = prefill(params, tokens[:, :n], cfg, max_len=n + 4)
    base, _ = decode_step(params, tokens[:, n], cache,
                          jnp.asarray(n, jnp.int32), cfg)
    # quantized cache end-to-end
    _, cache_q = prefill(params, tokens[:, :n], cfg_q, max_len=n + 4)
    assert cache_q[f"segment_0"][0]["mixer"]["k"].dtype == jnp.int8
    quant, _ = decode_step(params, tokens[:, n], cache_q,
                           jnp.asarray(n, jnp.int32), cfg_q)
    # logits match to quantization tolerance (int8 ~ 1% per element)
    base_p = jax.nn.softmax(base.astype(jnp.float32))
    quant_p = jax.nn.softmax(quant.astype(jnp.float32))
    assert float(jnp.abs(base_p - quant_p).max()) < 0.05
    # greedy decisions overwhelmingly agree
    agree = (jnp.argmax(base, -1) == jnp.argmax(quant, -1)).mean()
    assert float(agree) == 1.0


@pytest.mark.slow
def test_int8_dispatch_close_to_bf16():
    cfg = reduced(load_config("llama4-scout-17b-a16e"), max_repeats=1)
    m8 = dataclasses.replace(cfg.moe, dispatch_dtype="int8",
                             capacity_factor=8.0)
    mbf = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    rng = jax.random.PRNGKey(2)
    params = init_params(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), jnp.float32)
    y_bf, _ = moe_mod.moe_apply(
        params["segment_0"]["[0]"]["mlp"]
        if False else jax.tree_util.tree_map(lambda p: p[0],
                                             params["segment_0"])[0]["mlp"],
        x, dataclasses.replace(cfg, moe=mbf))
    y_q, _ = moe_mod.moe_apply(
        jax.tree_util.tree_map(lambda p: p[0],
                               params["segment_0"])[0]["mlp"],
        x, dataclasses.replace(cfg, moe=m8))
    err = float(jnp.abs(y_bf.astype(jnp.float32)
                        - y_q.astype(jnp.float32)).max())
    ref = float(jnp.abs(y_bf.astype(jnp.float32)).max())
    assert err < 0.05 * ref + 0.05, (err, ref)


def test_kv_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 4, 16, 64)).astype(np.float32))
    q, s = attention._kv_quantize(x)
    back = attention._kv_dequantize(q, s, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # quantization half-step + f16 scale storage error (2^-11 relative)
    bound = (np.asarray(s, np.float32) * 0.51
             + np.abs(np.asarray(x)) * 2 ** -10 + 1e-6)
    assert (err <= bound).all()

"""Multi-device tests for the shard_map pipeline executors.

These run in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
because the main test process must keep seeing exactly one device (the
dry-run is the only other place allowed to fake a mesh).
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess + 8 forced host devices

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_systolic_pipeline_on_devices():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import CDFG, partition_cdfg, decouple, SystolicPipeline

        def kernel(x, idx, w):
            a = x[idx]
            b = a * w
            return jnp.tanh(b) + 1.0

        x = jnp.arange(64, dtype=jnp.float32)
        T = 9
        idxs = jnp.stack([(jnp.arange(8) * (t + 1)) % 64 for t in range(T)])
        w = jnp.float32(0.5)
        cdfg = CDFG.from_function(kernel, x, idxs[0], w)
        part = partition_cdfg(cdfg)
        prog = decouple(part)
        pipe = SystolicPipeline(prog, stream_argnums=(1,))
        S = pipe.num_stages
        mesh = jax.make_mesh((S,), ("stage",))
        run = pipe.build_sharded(mesh)
        outs = run(x, idxs, w)
        ref = jnp.stack([kernel(x, idxs[t], w) for t in range(T)])
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                                   rtol=1e-6)
        print("systolic sharded OK, stages =", S)
    """)


def test_pipeline_apply_on_devices_fwd_and_grad():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import pipeline_apply, pipeline_apply_emulated

        S, M, D = 8, 16, 4
        rng = np.random.default_rng(0)
        params = jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * .2)
        mbs = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))
        mesh = jax.make_mesh((S,), ("stage",))

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        got = pipeline_apply(stage_fn, params, mbs, mesh=mesh)
        ref = pipeline_apply_emulated(stage_fn, params, mbs, num_stages=S)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)

        # gradient flows through the ppermute channels (GPipe training)
        def loss(params):
            y = pipeline_apply(stage_fn, params, mbs, mesh=mesh)
            return jnp.mean(y ** 2)

        def loss_ref(params):
            y = pipeline_apply_emulated(stage_fn, params, mbs, num_stages=S)
            return jnp.mean(y ** 2)

        g = jax.grad(loss)(params)
        g_ref = jax.grad(loss_ref)(params)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-6)
        print("pipeline_apply fwd+grad OK")
    """)


def test_collectives_in_dp_tp_mesh():
    """Sanity: the production sharding pattern (DP×TP) compiles and runs
    a small matmul+psum on an 8-device (2,4) mesh."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ("data", "model"))

        def f(x, w):
            y = jnp.einsum('bd,df->bf', x, w)
            return jax.lax.psum(y, 'model')

        x = jnp.ones((8, 16), jnp.float32)
        w = jnp.ones((16, 32), jnp.float32)
        from repro.core import shard_map_compat
        out = jax.jit(shard_map_compat(
            f, mesh=mesh,
            in_specs=(P('data', 'model'), P('model', None)),
            out_specs=P('data', None)))(x, w)
        np.testing.assert_allclose(np.asarray(out), 16.0)
        print("dp-tp shard_map OK")
    """)


def test_transformer_pipeline_parallel():
    """The paper's template as pipeline parallelism for a real LM: layers
    split into 4 stages over a 'stage' mesh axis, microbatches streaming
    through ppermute channels; must match the sequential forward."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import pipeline_apply

        S, M, B, L, D = 4, 8, 2, 16, 32
        rng = np.random.default_rng(0)
        # per-stage params: one mini transformer block per stage
        def init_stage(k):
            k1, k2 = jax.random.split(k)
            return {
                "w_qkv": jax.random.normal(k1, (D, D), jnp.float32) * 0.05,
                "w_ff": jax.random.normal(k2, (D, D), jnp.float32) * 0.05,
            }
        keys = jax.random.split(jax.random.PRNGKey(0), S)
        params = jax.vmap(init_stage)(keys)

        def stage_fn(p, x):  # x: (B, L, D)
            h = jnp.tanh(x @ p["w_qkv"])
            return x + jnp.tanh(h @ p["w_ff"])

        mbs = jnp.asarray(rng.normal(size=(M, B, L, D)).astype(np.float32))
        mesh = jax.make_mesh((4,), ("stage",))

        def flat_stage(p, x):
            return stage_fn(p, x)

        got = pipeline_apply(flat_stage, params, mbs, mesh=mesh)

        def seq(x):
            for s in range(S):
                x = stage_fn(jax.tree_util.tree_map(lambda q: q[s], params), x)
            return x

        want = jnp.stack([seq(mbs[m]) for m in range(M)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        from repro.core import gpipe_bubble_fraction
        print("transformer PP OK, bubble =",
              gpipe_bubble_fraction(S, M))
    """)


def test_elastic_resharded_restore(tmp_path):
    """Checkpoint saved unsharded restores onto a live (2,4) mesh with
    NamedShardings — the elastic-scaling path (different mesh than the
    writer's)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.checkpoint import Checkpointer

    state = {"w": jnp.asarray(np.arange(64, dtype=np.float32)
                              .reshape(8, 8))}
    ck = Checkpointer(str(tmp_path))
    ck.save(3, state, blocking=True)

    _run(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        ck = Checkpointer({str(tmp_path)!r})
        example = {{"w": jnp.zeros((8, 8), jnp.float32)}}
        restored, step = ck.restore(example, shardings=sh)
        assert step == 3
        assert restored["w"].sharding.spec == P("data", "model")
        np.testing.assert_array_equal(
            np.asarray(restored["w"]),
            np.arange(64, dtype=np.float32).reshape(8, 8))
        print("elastic restore OK on", len(restored["w"].devices()),
              "devices")
    """)

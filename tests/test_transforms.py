"""Tests for the HLS transformation catalog (repro.dataflow.transforms):
config legality, the trace-layer rewrites, scaled stage timing / FIFO
accounting, the reassoc plan split, execution bit-identity with the
sequential backend, cycle-exactness of the scalar reference and the
chunk-graph / serving resolution modes on transformed pipelines, and the
transform/memory axes of the partition-space DSE."""

import contextlib
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rescache as rc
from repro.core.partition import materialize, stage_groups
from repro.core.simulator import (MemAccess, acp, acp_cache,
                                  simulate_dataflow,
                                  simulate_dataflow_many)
from repro.dataflow import (ResourceConstraints, TransformConfig,
                            TransformError, compile as dcompile)
from repro.dataflow.dse import (partition_resources,
                                sim_stages_for_partition, traces_by_node)
from repro.dataflow.schedule import _cyclic_nodes
from repro.dataflow.transforms import (IDENTITY, coalesced_access,
                                       coalescible, split_by_region,
                                       tiled_access, transform_access,
                                       unrolled_access)


@pytest.fixture()
def rescache_on():
    rc.clear()
    rc.configure(enabled=True)
    yield
    rc.clear()
    rc.configure(enabled=False)


def _spmv_like():
    def body(acc, j, vals, cols, xv):
        return acc + vals[j] * xv[cols[j]]

    vals = jnp.arange(64, dtype=jnp.float32)
    cols = jnp.arange(64) % 16
    xv = jnp.arange(16, dtype=jnp.float32)
    args = (jnp.float32(0.0), jnp.int32(0), vals, cols, xv)
    return body, args


def _compiled(transforms=None, **kw):
    body, args = _spmv_like()
    return dcompile(body, *args, loop=True, transforms=transforms, **kw)


def _sim_setup(c, n_iters, seed=0):
    nt = traces_by_node(c.cdfg, c.partition, None, n_iters=n_iters,
                        seed=seed)
    cyc_mem = {n for n in _cyclic_nodes(c.cdfg)
               if c.cdfg.node(n).is_memory}
    return nt, cyc_mem


# ---------------------------------------------------------------------------
# Config shape + structural legality
# ---------------------------------------------------------------------------


def test_config_shape_checks():
    with pytest.raises(TransformError):
        TransformConfig(unroll=0)
    with pytest.raises(TransformError):
        TransformConfig(coalesce=True)  # needs unroll >= 2
    with pytest.raises(TransformError):
        TransformConfig(tile=8)        # needs tile_rows
    with pytest.raises(TransformError):
        TransformConfig(tile_rows=8)   # needs tile
    assert TransformConfig().is_identity
    assert TransformConfig().signature() == "none"
    cfg = TransformConfig(unroll=2, coalesce=True, reassoc=True)
    assert cfg.active() == ("unroll=2", "coalesce", "reassoc")
    assert cfg.signature() == "unroll=2+coalesce+reassoc"


def test_tokens_is_ceil_division():
    assert TransformConfig().tokens(1000) == 1000
    assert TransformConfig(unroll=2).tokens(1000) == 500
    assert TransformConfig(unroll=4).tokens(1001) == 251


def test_tile_illegal_on_carried_memory_dependence():
    """The spmv accumulator carry is scalar (no memory on the cycle) so
    tiling is fine; a dp-table kernel whose load/store sits on the carry
    cycle pins the iteration order and must be rejected at compile."""

    def dp(table, j, w):
        cur = table[j]
        table = table.at[j].set(cur + w)
        return table

    table = jnp.zeros(16, dtype=jnp.float32)
    with pytest.raises(TransformError, match="dependence cycle"):
        dcompile(dp, table, jnp.int32(0), jnp.float32(1.0), loop=True,
                 transforms=TransformConfig(tile=4, tile_rows=4))
    # the identity config never validates anything
    c = _compiled(TransformConfig())
    assert c.schedule.transforms is None


# ---------------------------------------------------------------------------
# Trace-layer rewrites
# ---------------------------------------------------------------------------


def test_unrolled_lanes_partition_the_stream():
    addrs = np.arange(103, dtype=np.int64) * 4
    acc = MemAccess("x", addrs)
    lanes = [unrolled_access(acc, 4, u) for u in range(4)]
    assert all(len(l) == 26 for l in lanes)  # ceil(103/4)
    got = np.stack([l.window(0, 26, 64)[0] for l in lanes], axis=1).ravel()
    assert np.array_equal(got[:103], addrs)
    assert (got[103:] == -1).all()  # tail pads to no-access


def test_coalescible_legality():
    seq = MemAccess("x", np.arange(64, dtype=np.int64) * 4)
    assert coalescible(seq, 2, line_bytes=32)
    assert coalescible(seq, 4, line_bytes=32)
    # span > line
    assert not coalescible(seq, 4, line_bytes=8)
    # gather: data-dependent addresses, non-constant stride
    rng = np.random.default_rng(0)
    gather = MemAccess("x", rng.integers(0, 1024, 64) * 4)
    assert not coalescible(gather, 2, line_bytes=32)
    # misaligned group bases straddle lines
    assert not coalescible(MemAccess("x", np.arange(64) * 4 + 4), 2,
                           line_bytes=32) or True  # base 4 % 8 != 0
    assert not coalescible(MemAccess("x", np.arange(64) * 4 + 4), 2)
    # descending stride is not a legal burst group
    assert not coalescible(MemAccess("x", np.arange(64)[::-1] * 4), 2)


def test_coalesced_access_is_lane0_with_width():
    acc = MemAccess("x", np.arange(64, dtype=np.int64) * 4)
    co = coalesced_access(acc, 2)
    assert co.width == 2 and len(co) == 32
    w, _ = co.window(0, 32, 64)
    assert np.array_equal(w, np.arange(32, dtype=np.int64) * 8)


def test_tiled_access_is_a_permutation():
    addrs = (np.arange(48, dtype=np.int64) * 8) ^ 0x40  # distinct, odd order
    acc = MemAccess("x", addrs)
    t = tiled_access(acc, 4, 3)  # 4 rows x 12 cols, col-tiles of 3
    assert len(t) == 48
    w = t._raw_window(0, 48)
    assert sorted(w.tolist()) == sorted(addrs.tolist())
    assert not np.array_equal(w, addrs)  # actually reorders
    # first tile: rows of the first 3 columns
    expect = addrs.reshape(4, 12)[:, :3].ravel()
    assert np.array_equal(w[:12], expect)
    # windows are pure in (lo, hi)
    assert np.array_equal(t._raw_window(5, 29), w[5:29])
    with pytest.raises(TransformError, match="does not factor"):
        tiled_access(MemAccess("x", np.arange(10) * 4), 3, 2)


def test_transform_access_memoizes_and_respects_scc():
    acc = MemAccess("x", np.arange(64, dtype=np.int64) * 4)
    cfg = TransformConfig(unroll=2, coalesce=True)
    a = transform_access(cfg, acc)
    assert [x.width for x in a] == [2]        # legal -> coalesced
    assert transform_access(cfg, acc) is a    # memoized on the base acc
    b = transform_access(cfg, acc, allow_coalesce=False)
    assert [x.width for x in b] == [1, 1]     # mem-in-scc: stays unrolled
    gather = MemAccess(
        "x", np.random.default_rng(1).integers(0, 1024, 64) * 4)
    g = transform_access(cfg, gather)
    assert [x.width for x in g] == [1, 1]     # illegal -> unrolled lanes


def test_transformed_streams_get_distinct_rescache_keys():
    acc = MemAccess("x", np.arange(4096, dtype=np.int64) * 4)
    fps = {rc.trace_fingerprint(a) for a in (
        acc, unrolled_access(acc, 2, 0), unrolled_access(acc, 2, 1),
        unrolled_access(acc, 4, 0), tiled_access(acc, 4, 8))}
    assert len(fps) == 5


def test_width_is_fold_only_in_resolution_key():
    from repro.core.simulator import SimStage
    addrs = np.arange(256, dtype=np.int64) * 8
    s1 = [SimStage("m", ii=1, latency=2,
                   accesses=[MemAccess("x", addrs)])]
    s2 = [SimStage("m", ii=1, latency=2,
                   accesses=[MemAccess("x", addrs, width=2)])]
    mem = acp()
    assert rc.resolution_key("dataflow", s1, mem, 0) == \
        rc.resolution_key("dataflow", s2, mem, 0)


# ---------------------------------------------------------------------------
# Timing / resource scaling
# ---------------------------------------------------------------------------


def test_unroll_scales_fifo_bits_and_scc_ii():
    c = _compiled()
    plan = c.context.plan
    base = materialize(c.cdfg, plan, transforms=IDENTITY)
    u2 = materialize(c.cdfg, plan, transforms=TransformConfig(unroll=2))
    d = 8
    assert partition_resources(u2, d)["fifo_bits"] == \
        2 * partition_resources(base, d)["fifo_bits"]
    for sb, su in zip(base.stages, u2.stages):
        if sb.scc_ii > 0:  # the carried accumulator serializes
            assert su.ii == 2 * sb.scc_ii
            assert su.latency == sb.latency + sb.scc_ii
        else:              # acyclic stages replicate spatially
            assert (su.ii, su.latency) == (sb.ii, sb.latency)


def test_unroll_factors_pruned_by_fifo_budget():
    c = _compiled()
    res = c.explore(
        n_iters=600, max_candidates=4,
        constraints=ResourceConstraints(
            n_iters=600, max_fifo_bits=partition_resources(
                c.partition, 8)["fifo_bits"],  # exactly the base budget
            unroll_factors=(2,)),
        fifo_depth=8)
    tf_cands = [x for x in res.candidates if x.transform != "none"]
    assert tf_cands and all(
        x.pruned is not None for x in tf_cands
        if x.groups == res.baseline.groups and not x.duplicate)
    assert res.transforms == ("unroll=2",)


def test_reassoc_splits_stages_by_region():
    c = _compiled()
    plan = stage_groups(c.cdfg, policy="fused")
    split = split_by_region(c.cdfg, plan)
    assert len(split.groups) > len(plan.groups)

    def regions_of(grp):
        return {c.cdfg.node(n).region for k in grp for n in plan.sccs[k]
                if c.cdfg.node(n).is_memory and c.cdfg.node(n).region}

    for grp in split.groups:
        assert len(regions_of(grp)) <= 1
    # as a compile option: every stage ends up single-region
    ct = _compiled(TransformConfig(reassoc=True))
    cb = _compiled()
    assert ct.schedule.num_stages >= cb.schedule.num_stages
    for s in ct.schedule.stages:
        assert len(s.regions) <= 1
    # as a DSE seed: from a fused base (multi-region single stage) the
    # reassoc plan joins the enumeration as its own move (the paper
    # policy's base plan is already single-region per stage, so there
    # the seed dedups away)
    cf = _compiled(policy="fused")
    res = cf.explore(n_iters=400, max_candidates=6,
                     constraints=ResourceConstraints(
                         n_iters=400, explore_reassoc=True))
    assert any("reassoc" in x.moves for x in res.candidates)


# ---------------------------------------------------------------------------
# Execution bit-identity (sequential backend) per transform
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [
    TransformConfig(unroll=2),
    TransformConfig(unroll=3),
    TransformConfig(unroll=2, coalesce=True),
    TransformConfig(tile=4, tile_rows=4),
    TransformConfig(reassoc=True),
    TransformConfig(unroll=2, coalesce=True, reassoc=True),
], ids=lambda c: c.signature())
def test_transformed_compile_matches_sequential(cfg):
    """Every catalog transform is semantics-preserving: the transformed
    artifact's sequential-backend output is bit-for-bit the
    untransformed one's on a seeded kernel."""
    base = _compiled()
    tf = _compiled(cfg)
    assert tf.transform_signature == cfg.signature()
    body, args = _spmv_like()
    want = base(*args, backend="sequential")
    got = tf(*args, backend="sequential")
    assert np.array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# Cycle-exactness across engines and resolution modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [
    TransformConfig(unroll=2),
    TransformConfig(unroll=2, coalesce=True),
    TransformConfig(tile=4, tile_rows=4),
], ids=lambda c: c.signature())
def test_transformed_vectorized_matches_scalar_reference(cfg):
    """The vectorized solver and the scalar ``reference=True`` loop agree
    cycle-exactly on transformed pipelines (unroll serialization, burst
    width continuation, tile permutation)."""
    c = _compiled(cfg)
    stages = c.sim_stages()
    n_tok = cfg.tokens(1024)
    assert c.schedule.transforms == cfg
    for mem in (acp(), acp_cache()):
        vec = simulate_dataflow(stages, mem, n_tok, fifo_depth=8,
                                use_rescache=False)
        ref = simulate_dataflow(stages, mem, n_tok, fifo_depth=8,
                                reference=True)
        assert vec.cycles == ref.cycles


def test_transformed_cycles_identical_across_resolution_modes(
        rescache_on, monkeypatch, tmp_path):
    """Streaming, chunk-graph ``workers=2``, and the resolution daemon
    produce identical cycle counts for a transformed pipeline (the
    transformed closure generators ship to workers via cloudpickle)."""
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    monkeypatch.setenv("REPRO_CHUNK_ITERS", "512")
    rc.configure(directory=str(tmp_path / "store"))
    cfg = TransformConfig(unroll=2, coalesce=True)
    c = _compiled(cfg)
    stages = c.sim_stages()
    n_tok = cfg.tokens(4096)
    mems = {"ACPC": acp_cache()}
    ref = simulate_dataflow_many(stages, dict(mems), n_tok,
                                 fifo_depths=(8,), use_rescache=False)
    sharded = simulate_dataflow_many(stages, dict(mems), n_tok,
                                     fifo_depths=(8,), use_rescache=False,
                                     workers=2)
    assert sharded[("ACPC", 8)].cycles == ref[("ACPC", 8)].cycles
    # served: a private daemon on a short-path socket
    from repro.serve.daemon import ResolutionDaemon
    sdir = tempfile.mkdtemp(prefix="serve-tf-")
    d = ResolutionDaemon(address=os.path.join(sdir, "d.sock"), workers=2)
    d.start()
    try:
        served = simulate_dataflow_many(stages, dict(mems), n_tok,
                                        fifo_depths=(8,),
                                        server=d.address)
    finally:
        with contextlib.suppress(Exception):
            d.stop()
    assert served[("ACPC", 8)].cycles == ref[("ACPC", 8)].cycles


def test_simulate_pits_transformed_dataflow_against_full_baseline():
    """``Compiled.simulate`` on a transformed artifact runs the dataflow
    machine at the token count but the conventional baseline on the
    UNtransformed fused machine at the full iteration count — same total
    work on both sides."""
    cfg = TransformConfig(unroll=2)
    c = _compiled(cfg)
    base = _compiled()
    rep = c.simulate(n_iters=1024, use_rescache=False)
    rep_b = base.simulate(n_iters=1024, use_rescache=False)
    assert rep.conventional.cycles == rep_b.conventional.cycles
    assert rep.n_iters == 1024


def test_sweep_rows_carry_transform_signature():
    cfg = TransformConfig(unroll=2, coalesce=True)
    c = _compiled(cfg)
    res = c.sweep(n_iters=512, mems={"ACP": acp},
                  fifo_depths=(8,), use_rescache=False)
    for row in res.rows:
        assert row["transform"] == "unroll=2+coalesce"
        assert row["n_tokens"] == 256
    base_rows = _compiled().sweep(n_iters=512, mems={"ACP": acp},
                                  fifo_depths=(8,),
                                  use_rescache=False).rows
    assert all(r["transform"] == "none" for r in base_rows)


# ---------------------------------------------------------------------------
# The DSE transform / memory axes
# ---------------------------------------------------------------------------


def test_explore_transform_axis_and_cold_bit_identity(rescache_on):
    """The widened front: transformed candidates join the search, every
    front point's cycles are bit-identical to a fresh cold simulation of
    its transformed stage list, and the dominance probe runs."""
    c = _compiled()
    res = c.explore(
        n_iters=1200, max_candidates=6, fifo_depths=(8, 4),
        transforms=[TransformConfig(unroll=2),
                    TransformConfig(unroll=2, coalesce=True)])
    assert res.transforms == ("unroll=2", "unroll=2+coalesce")
    sigs = {x.transform for x in res.candidates}
    assert {"none", "unroll=2", "unroll=2+coalesce"} <= sigs
    mem = acp()
    nt, cyc_mem = _sim_setup(c, 1200)
    from repro.dataflow.transforms import transform_node_traces
    for cand in res.front:
        assert cand.compiled is not None
        assert cand.compiled.transform_signature == cand.transform
        eff = cand.tf
        cnt = nt if eff is None else transform_node_traces(
            nt, eff, serialized_nodes=cyc_mem)
        stages = sim_stages_for_partition(cand.compiled.partition, cnt,
                                          cyc_mem)
        fresh = simulate_dataflow(stages, mem, cand.n_tokens,
                                  fifo_depth=cand.fifo_depth,
                                  use_rescache=False)
        assert fresh.cycles == cand.cycles
    assert isinstance(res.transformed_dominates(), bool)
    assert res.to_json()["transforms"] == ["unroll=2", "unroll=2+coalesce"]


def test_explore_spans_memory_models():
    """One ``explore(mems=[...])`` call evaluates every candidate under
    several models; fronts are per-model and candidates record theirs."""
    c = _compiled()
    res = c.explore(n_iters=800, max_candidates=4,
                    mems=["ACP", "ACP+64KB"],
                    transforms=[TransformConfig(unroll=2)])
    assert res.mem_names == ("ACP", "ACP+64KB")
    assert {x.mem_name for x in res.candidates} == {"ACP", "ACP+64KB"}
    assert res.baseline.mem_name == "ACP"  # primary hosts the baseline
    front_mems = {x.mem_name for x in res.front}
    assert front_mems == {"ACP", "ACP+64KB"}
    # per-model sub-fronts are each Pareto in (bits, cycles)
    for mn in res.mem_names:
        sub = [x for x in res.front if x.mem_name == mn]
        bits = [x.fifo_bits for x in sub]
        cyc = [x.cycles for x in sub]
        assert bits == sorted(bits)
        assert cyc == sorted(cyc, reverse=True)
    # best()/dominates_baseline() never compare across models
    assert res.best().mem_name == "ACP"
    # rc.mems expresses the same axis declaratively
    res2 = c.explore(n_iters=800, max_candidates=4,
                     constraints=ResourceConstraints(
                         n_iters=800, mems=("ACP", "ACP+64KB")))
    assert res2.mem_names == ("ACP", "ACP+64KB")


def test_transformed_candidate_dominates_on_gather_kernel(rescache_on):
    """On the spmv-style gather kernel the unrolled lane strictly
    dominates the best untransformed point at equal-or-lower FIFO bits —
    the acceptance property the full-scale harness gates."""
    c = _compiled()
    res = c.explore(
        n_iters=2000, max_candidates=6, fifo_depths=(8, 4),
        transforms=[TransformConfig(unroll=2),
                    TransformConfig(unroll=2, coalesce=True)])
    assert res.transformed_dominates()

"""Tests for the repro.dataflow compiler driver: backend parity, the
compilation cache, the pass pipeline surface, and the schedule reports."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dataflow import (Backend, CompileOptions, Pass,
                            clear_cache, cache_stats, compile as dcompile,
                            dataflow_jit, default_pipeline, execute_backends,
                            get_backend, register_backend,
                            unregister_backend)


def _quickstart_kernel(table, idx, w):
    g = table[idx]
    h = g * w
    return jnp.tanh(h) + 1.0


def _example():
    table = jnp.arange(1024, dtype=jnp.float32)
    idx = jnp.asarray([3, 997, 41, 512, 7, 800, 64, 2])
    w = jnp.float32(1.5)
    return table, idx, w


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_cache()
    yield
    clear_cache()


# ---------------------------------------------------------------------------
# Backend parity
# ---------------------------------------------------------------------------

def test_backend_parity_on_quickstart_kernel():
    """sequential == emulated == xla == direct call."""
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w, stream_argnums=(1,))
    ref = np.asarray(_quickstart_kernel(table, idx, w))
    for name in execute_backends():
        if name not in c.backends():  # systolic needs one device per stage
            continue
        got = np.asarray(c(table, idx, w, backend=name))
        np.testing.assert_allclose(got, ref, rtol=1e-6, err_msg=name)


@pytest.mark.slow
def test_all_backends_including_systolic_subprocess():
    """With forced host devices every registered execute backend runs and
    matches the direct call (the quickstart acceptance check)."""
    import os
    import subprocess
    import sys
    import textwrap

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = textwrap.dedent("""
        import numpy as np, jax.numpy as jnp
        from repro.dataflow import compile as dcompile, execute_backends

        def kernel(table, idx, w):
            return jnp.tanh(table[idx] * w) + 1.0

        table = jnp.arange(1024, dtype=jnp.float32)
        idx = jnp.asarray([3, 997, 41, 512, 7, 800, 64, 2])
        w = jnp.float32(1.5)
        c = dcompile(kernel, table, idx, w, stream_argnums=(1,))
        assert set(execute_backends()) <= set(c.backends()), c.backends()
        ref = np.asarray(kernel(table, idx, w))
        for name in execute_backends():
            got = np.asarray(c(table, idx, w, backend=name))
            np.testing.assert_allclose(got, ref, rtol=1e-6, err_msg=name)
        print("parity across", execute_backends())
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"


def test_simulate_backend_returns_report():
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w)
    rep = c(table, idx, w, backend="simulate")
    assert rep.dataflow.cycles > 0
    assert rep.conventional.cycles >= rep.dataflow.cycles
    assert "Fig. 2" in rep.summary()


def test_compiled_sweep_grid():
    """Compiled.sweep: the design-space grid over memory models × FIFO
    depths × SCC modes, dispatched through the simulate backend."""
    import json

    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w)
    res = c.sweep(n_iters=1500, fifo_depths=(4, 16),
                  scc_modes=("auto", "forced"))
    # 4 memory models x 2 depths x 2 modes
    assert len(res.rows) == 16
    assert {r["mem"] for r in res.rows} == {"ACP", "ACP+64KB", "HP",
                                            "HP+64KB"}
    for r in res.rows:
        assert r["dataflow_cycles"] > 0
        assert r["speedup"] == r["conventional_cycles"] / r["dataflow_cycles"]
    # the grid is JSON-ready (the BENCH_sim.json contract)
    json.dumps(res.to_json())
    best = res.best()
    assert best["dataflow_cycles"] == min(r["dataflow_cycles"]
                                          for r in res.rows)
    assert "best dataflow config" in res.summary()
    # forcing the DFS pathology can never make the pipeline faster
    for mem in ("ACP", "HP"):
        auto = [r for r in res.rows if r["mem"] == mem
                and r["mem_in_scc"] == "auto" and r["fifo_depth"] == 16]
        forced = [r for r in res.rows if r["mem"] == mem
                  and r["mem_in_scc"] == "forced" and r["fifo_depth"] == 16]
        assert forced[0]["dataflow_cycles"] >= auto[0]["dataflow_cycles"]


def test_sweep_conventional_shared_across_depths():
    """The conventional engine has no FIFOs: one simulation per
    (memory, SCC mode) is reused across the depth axis."""
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w)
    res = c.sweep(n_iters=800, fifo_depths=(2, 8, 32))
    by_mem: dict = {}
    for r in res.rows:
        by_mem.setdefault(r["mem"], set()).add(r["conventional_cycles"])
    for mem, cycles in by_mem.items():
        assert len(cycles) == 1, (mem, cycles)


def test_stream_matches_per_microbatch_calls():
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w, stream_argnums=(1,))
    T = 5
    idxs = jnp.stack([(idx + t) % 1024 for t in range(T)])
    outs = c.stream(table, idxs, w)
    ref = np.stack([np.asarray(_quickstart_kernel(table, idxs[t], w))
                    for t in range(T)])
    np.testing.assert_allclose(np.asarray(outs), ref, rtol=1e-6)


def test_zero_rank_channel_var_roundtrips():
    """A scalar crossing a stage boundary (satellite: _example_for_var must
    handle zero-rank avals consistently with the channel specs)."""

    def kernel(x, idx):
        s = jnp.exp(jnp.float32(0.5)) * x.sum()   # zero-rank, long op
        return x[idx] * s

    x = jnp.arange(16, dtype=jnp.float32)
    idx = jnp.asarray([3, 1, 7, 2])
    c = dcompile(kernel, x, idx, stream_argnums=(1,))
    ref = np.asarray(kernel(x, idx))
    np.testing.assert_allclose(
        np.asarray(c(x, idx, backend="emulated")), ref, rtol=1e-6)


def test_pytree_outputs_roundtrip():
    def kernel(x):
        return {"a": x * 2.0, "b": (jnp.tanh(x), x.sum())}

    x = jnp.arange(8, dtype=jnp.float32)
    c = dcompile(kernel, x)
    ref = kernel(x)
    for backend in ("sequential", "xla"):
        got = c(x, backend=backend)
        assert set(got) == {"a", "b"}
        np.testing.assert_allclose(np.asarray(got["a"]),
                                   np.asarray(ref["a"]))
        np.testing.assert_allclose(np.asarray(got["b"][1]),
                                   np.asarray(ref["b"][1]))


# ---------------------------------------------------------------------------
# dataflow_jit decorator
# ---------------------------------------------------------------------------

def test_dataflow_jit_decorator_and_lower():
    table, idx, w = _example()

    @dataflow_jit(stream_argnums=(1,), backend="emulated")
    def kernel(table, idx, w):
        return jnp.tanh(table[idx] * w) + 1.0

    ref = np.asarray(kernel.__wrapped__(table, idx, w))
    np.testing.assert_allclose(np.asarray(kernel(table, idx, w)), ref,
                               rtol=1e-6)
    compiled = kernel.lower(table, idx, w)
    assert compiled.num_stages >= 3
    assert "stage 0" in compiled.report()
    # second lower with the same shapes returns the same artifact
    assert kernel.lower(table, idx, w) is compiled


def test_dataflow_jit_loop_mode():
    """Loop mode keeps the carried SCC in one stage (paper §III)."""

    @dataflow_jit(loop=True, backend="sequential")
    def body(carry, x):
        y = jnp.exp(x)
        return carry * 0.9 + y

    c = body.lower(jnp.float32(0.0), jnp.float32(1.0))
    part = c.partition
    carried = [n.id for n in c.cdfg.nodes if n.prim in ("mul", "add")]
    stages = {part.stage_of_node[n] for n in carried}
    assert len(stages) == 1, "loop-carried SCC split across stages"


# ---------------------------------------------------------------------------
# Compilation cache
# ---------------------------------------------------------------------------

def test_cache_hit_on_identical_options():
    table, idx, w = _example()
    opts = CompileOptions(stream_argnums=(1,))
    c1 = dcompile(_quickstart_kernel, table, idx, w, options=opts)
    c2 = dcompile(_quickstart_kernel, table, idx, w, options=opts)
    assert c1 is c2
    stats = cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


def test_cache_miss_on_changed_options():
    table, idx, w = _example()
    c1 = dcompile(_quickstart_kernel, table, idx, w, policy="paper")
    c2 = dcompile(_quickstart_kernel, table, idx, w, policy="fused")
    assert c1 is not c2
    assert c2.num_stages == 1
    assert cache_stats()["misses"] == 2


def test_cache_distinguishes_output_trees():
    """Identical flat computations with different return containers must
    not alias in the cache (regression: out_tree is part of the key)."""

    def as_tuple(x):
        return (x * 2, x + 1)

    def as_dict(x):
        return {"a": x * 2, "b": x + 1}

    x = jnp.arange(4.)
    c1 = dcompile(as_tuple, x)
    c2 = dcompile(as_dict, x)
    assert c1 is not c2
    assert isinstance(c1(x), tuple)
    assert isinstance(c2(x), dict)


def test_fallback_rejects_explicit_backend():
    """on_error='fallback' may reroute the default call to jax.jit, but an
    explicit backend request must raise, not silently run fused."""
    from repro.dataflow import default_pipeline

    class Boom(Pass):
        name = "partition"

        def run(self, ctx):
            raise RuntimeError("boom")

    pipeline = default_pipeline().replace("partition", Boom())
    f = dataflow_jit(lambda x: x + 1, pipeline=pipeline,
                     on_error="fallback")
    x = jnp.arange(3.)
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x + 1))
    with pytest.raises(RuntimeError, match="cannot honor backend"):
        f(x, backend="simulate")


def test_cache_miss_on_changed_shapes():
    table, idx, w = _example()
    c1 = dcompile(_quickstart_kernel, table, idx, w)
    c2 = dcompile(_quickstart_kernel, table, idx[:4], w)
    assert c1 is not c2


# ---------------------------------------------------------------------------
# Pass pipeline surface
# ---------------------------------------------------------------------------

def test_default_pipeline_names():
    assert default_pipeline().names() == [
        "trace", "memdep", "transform", "partition", "rewrite", "dse",
        "decouple",
        "schedule"]


def test_pipeline_pass_swap():
    """A custom partition pass slots into the pipeline by name."""
    from repro.core.partition import materialize, stage_groups

    class MaximalPartitionPass(Pass):
        name = "partition"

        def run(self, ctx):
            ctx.plan = stage_groups(ctx.cdfg, policy="maximal")
            ctx.partition = materialize(ctx.cdfg, ctx.plan)

    pipeline = default_pipeline().replace("partition",
                                          MaximalPartitionPass())
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w, pipeline=pipeline,
                 duplicate_cheap=False)
    assert c.num_stages == len(c.cdfg.nodes)
    ref = np.asarray(_quickstart_kernel(table, idx, w))
    np.testing.assert_allclose(np.asarray(c(table, idx, w)), ref)


def test_pipeline_without_and_insert_after():
    ran = []

    class ProbePass(Pass):
        name = "probe"

        def run(self, ctx):
            ran.append(ctx.partition.num_stages)

    p = default_pipeline().without("rewrite").insert_after("partition",
                                                           ProbePass())
    assert "rewrite" not in p.names()
    assert p.names().index("probe") == p.names().index("partition") + 1
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w, pipeline=p)
    assert ran == [c.num_stages]
    assert not c.partition.duplicated  # rewrite pass removed


def test_pass_timings_recorded():
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w)
    assert set(c.context.timings) == set(default_pipeline().names())


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

def test_register_custom_backend_dispatch():
    class DoublingBackend(Backend):
        name = "test-doubling"

        def execute(self, compiled, args):
            seq = get_backend("sequential")
            return jax.tree_util.tree_map(lambda x: x * 2,
                                          seq.execute(compiled, args))

    register_backend(DoublingBackend)
    try:
        table, idx, w = _example()
        c = dcompile(_quickstart_kernel, table, idx, w)
        ref = np.asarray(_quickstart_kernel(table, idx, w))
        got = np.asarray(c(table, idx, w, backend="test-doubling"))
        np.testing.assert_allclose(got, 2 * ref, rtol=1e-6)
    finally:
        unregister_backend("test-doubling")


def test_unknown_backend_raises():
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w)
    with pytest.raises(KeyError, match="unknown backend"):
        c(table, idx, w, backend="nope")


def test_duplicate_backend_name_rejected():
    class Clash(Backend):
        name = "sequential"

    with pytest.raises(ValueError, match="already registered"):
        register_backend(Clash)


# ---------------------------------------------------------------------------
# Options
# ---------------------------------------------------------------------------

def test_options_freeze_mappings_and_hash():
    o1 = CompileOptions(latency_table={"mul": 1, "add": 2},
                        regions={0: "table"})
    o2 = CompileOptions(latency_table={"add": 2, "mul": 1},
                        regions={0: "table"})
    assert o1 == o2 and hash(o1) == hash(o2)
    assert o1.latency_model().latency("mul") == 1
    assert o1.regions_map() == {0: "table"}


def test_options_regions_flow_into_report():
    table, idx, w = _example()
    c = dcompile(_quickstart_kernel, table, idx, w,
                 regions={0: "embedding_table"})
    assert any("embedding_table" in s.regions for s in c.schedule.stages)

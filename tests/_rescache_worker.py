"""Spawn-pool worker for the rescache cross-process test (top-level
module so a spawn context can import it)."""

import numpy as np


def run_cell(args):
    """Simulate one dataflow cell; returns (cycles, rescache stats)."""
    cache_dir, seed = args
    from repro.core import rescache as rc
    from repro.core.simulator import MemAccess, SimStage, acp_cache, \
        simulate_dataflow
    rc.configure(enabled=True, directory=cache_dir)
    rng = np.random.default_rng(11)
    n = 4000
    stages = [
        SimStage("f", ii=1, latency=2,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 18, n) * 4)]),
        SimStage("g", ii=2, latency=3),
    ]
    r = simulate_dataflow(stages, acp_cache(), n, fifo_depth=8, seed=seed)
    return r.cycles, rc.stats()

"""Tests for the chunk-graph executor (sharded resolution), the v3
prefix-serving rescache, depth-incremental solving, and the finite
store-buffer model."""

import os

import numpy as np
import pytest

from repro.core import rescache as rc
from repro.core.simulator import (
    BatchedCacheSim, CacheConfig, MemAccess, MemoryModel, SimStage, acp,
    acp_cache, compose_stacks, hp_cache, simulate_conventional,
    simulate_dataflow, simulate_dataflow_many, simulate_processor,
)


@pytest.fixture()
def small_chunks(tmp_path, monkeypatch):
    """Fresh isolated store with a tiny canonical chunk grid, so
    multi-chunk behaviour (sharding, prefix serving, resume) is
    exercised at test-sized iteration counts."""
    d = str(tmp_path / "rescache")
    rc.clear()
    rc.configure(enabled=True, directory=d)
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    yield d
    rc.clear()
    rc.configure(enabled=False)


def _pipeline(n=5000, seed=5):
    rng = np.random.default_rng(seed)
    return [
        SimStage("addr", ii=1, latency=2,
                 accesses=[MemAccess("i", np.arange(n) * 4)]),
        SimStage("fetch", ii=1, latency=3,
                 accesses=[MemAccess("x", rng.integers(0, 1 << 19, n) * 4),
                           MemAccess("y", np.arange(n) * 4 + (1 << 22),
                                     is_store=True)]),
        SimStage("fma", ii=4, latency=6),
    ]


def _assert_same(a, b, what=""):
    assert a.cycles == b.cycles, what
    assert a.stage_stall_cycles == b.stage_stall_cycles, what
    assert (a.cache_hits, a.cache_misses) == \
        (b.cache_hits, b.cache_misses), what


# ---------------------------------------------------------------------------
# Cache-state transport: export / import / compose
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ways", [2, 4, 8])
def test_export_import_split_replay(ways):
    """Splitting a trace at any point and carrying the state through
    export→import must reproduce the straight replay exactly."""
    rng = np.random.default_rng(11)
    cfg = CacheConfig(size_bytes=4096, line_bytes=32, ways=ways)
    addrs = rng.integers(0, 1 << 14, 4000) * 4
    straight = BatchedCacheSim(cfg)
    want = straight.lookup(addrs)
    for cut in (1, 137, 2000, 3999):
        a = BatchedCacheSim(cfg)
        h0 = a.lookup(addrs[:cut])
        stacks, mt = a.export_stacks()
        b = BatchedCacheSim(cfg)
        b.import_stacks(stacks, mt)
        h1 = b.lookup(addrs[cut:])
        got = np.concatenate([h0, h1])
        assert np.array_equal(got, want), (ways, cut)


@pytest.mark.parametrize("ways", [2, 4])
def test_effect_composition(ways):
    """A chunk's own effect (replayed from empty) composed onto any
    incoming state equals replaying through that state — the monoid
    property the sharded resolver's phase A/compose relies on."""
    rng = np.random.default_rng(12)
    cfg = CacheConfig(size_bytes=2048, line_bytes=32, ways=ways)
    a1 = rng.integers(0, 1 << 12, 1500) * 4
    a2 = rng.integers(0, 1 << 12, 1500) * 4
    seq = BatchedCacheSim(cfg)
    seq.lookup(a1)
    seq.lookup(a2)
    want, _ = seq.export_stacks()
    first = BatchedCacheSim(cfg)
    first.lookup(a1)
    st1, _ = first.export_stacks()
    own = BatchedCacheSim(cfg)
    own.lookup(a2)
    st2, _ = own.export_stacks()
    assert np.array_equal(compose_stacks(st1, st2), want)


# ---------------------------------------------------------------------------
# Sharded resolution == streaming, bit for bit
# ---------------------------------------------------------------------------

def _sharded(*args, **kwargs):
    """simulate_dataflow_many via the pool, asserting the sharded path
    actually engaged (a silent fallback to streaming would make the
    equality tests vacuous)."""
    from repro.core import chunkgraph
    runs0 = chunkgraph._POOL_RUNS
    out = simulate_dataflow_many(*args, **kwargs)
    assert chunkgraph._POOL_RUNS == runs0 + 1, \
        "chunk-graph pool did not engage"
    return out


@pytest.mark.parametrize("workers", [2, 3])
def test_sharded_equals_streaming_no_cache(workers, monkeypatch):
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    rc.configure(enabled=False)
    stages = _pipeline()
    mems = {"ACP": acp(), "ACPC": acp_cache(), "HPC": hp_cache()}
    ref = simulate_dataflow_many(stages, dict(mems), 5000,
                                 fifo_depths=(4, 16), use_rescache=False)
    got = _sharded(stages, dict(mems), 5000,
                   fifo_depths=(4, 16), use_rescache=False,
                   workers=workers)
    for key in ref:
        _assert_same(got[key], ref[key], key)


def test_sharded_write_around_draw_positions(monkeypatch):
    """Write-around stores bypass the cache but still draw from the
    backing store: the sharded master's draw offsets must count them
    (misses alone under-count), or every later chunk's latencies
    shift."""
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    rc.configure(enabled=False)
    rng = np.random.default_rng(21)
    n = 5000
    stages = [
        SimStage("ld", ii=1, latency=2,
                 accesses=[MemAccess("x",
                                     rng.integers(0, 1 << 14, n) * 4)]),
        SimStage("st", ii=1, latency=2,
                 accesses=[MemAccess("y",
                                     rng.integers(0, 1 << 14, n) * 4,
                                     is_store=True)]),
    ]
    wa = MemoryModel(name="wa",
                     cache=CacheConfig(write_allocate=False))
    ref = simulate_dataflow_many(stages, {"wa": wa}, n,
                                 fifo_depths=(16,), use_rescache=False)
    got = _sharded(stages, {"wa": MemoryModel(
        name="wa", cache=CacheConfig(write_allocate=False))}, n,
        fifo_depths=(16,), use_rescache=False, workers=2)
    _assert_same(got[("wa", 16)], ref[("wa", 16)])


@pytest.mark.parametrize("chunk", [512, 1024])
def test_sharded_equals_streaming_with_store(chunk, small_chunks,
                                             monkeypatch):
    """Sharded (writing records) vs cold streaming, then a fully-served
    rerun — all bit-identical, and the rerun resolves nothing."""
    monkeypatch.setattr(rc, "CHUNK_ITERS", chunk)
    stages = _pipeline(seed=6)
    mems = {"ACP": acp(), "ACPC": acp_cache()}
    ref = simulate_dataflow_many(stages, dict(mems), 5000,
                                 fifo_depths=(16,), use_rescache=False)
    got = _sharded(stages, dict(mems), 5000,
                   fifo_depths=(16,), workers=2)
    for key in ref:
        _assert_same(got[key], ref[key], key)
    assert rc.census()["chunks"] > 0
    cold0 = rc.stats()["cold_chunks"]
    # fully served rerun: falls back to the cheap streaming fold+solve
    again = simulate_dataflow_many(stages, dict(mems), 5000,
                                   fifo_depths=(16,), workers=2)
    for key in ref:
        _assert_same(again[key], ref[key], key)
    assert rc.stats()["cold_chunks"] == cold0


@pytest.mark.slow
def test_sharded_paper_kernels_bit_identical(monkeypatch):
    """All four paper kernels, full-scale window-generated traces at a
    truncated count, multiple chunk sizes and worker counts: the
    sharded executor must match the streaming engine exactly."""
    from benchmarks.paper_fig5 import _dataflow_mems, _make_kernel, \
        build_stages
    rc.configure(enabled=False)
    for kname in ("spmv", "knapsack", "floyd_warshall", "dfs"):
        stages, _ = build_stages(_make_kernel(kname))
        n = 60_000
        mems = _dataflow_mems()
        ref = simulate_dataflow_many(stages, dict(mems), n,
                                     fifo_depths=(256,),
                                     use_rescache=False)
        for chunk, workers in ((16384, 2), (10000, 3)):
            monkeypatch.setattr(rc, "CHUNK_ITERS", chunk)
            got = simulate_dataflow_many(stages, dict(mems), n,
                                         fifo_depths=(256,),
                                         use_rescache=False,
                                         workers=workers)
            for key in ref:
                _assert_same(got[key], ref[key], (kname, chunk, key))


# ---------------------------------------------------------------------------
# Prefix serving and resume
# ---------------------------------------------------------------------------

def test_prefix_serves_any_shorter_run(small_chunks):
    """An N-iteration artifact serves every M ≤ N run — including
    mid-chunk M — with zero cold resolution and results identical to a
    cold M-iteration run (cycles, stalls, cache statistics)."""
    stages = _pipeline(seed=7)
    simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    for m in (5000, 4608, 3000, 517, 40):
        cold = simulate_dataflow(stages, acp_cache(), m, fifo_depth=16,
                                 use_rescache=False)
        before = rc.stats()["cold_chunks"]
        served = simulate_dataflow(stages, acp_cache(), m, fifo_depth=16)
        _assert_same(served, cold, m)
        assert rc.stats()["cold_chunks"] == before, m


def test_conventional_and_processor_prefix_serving(small_chunks):
    """The conventional engine's stall fold and the processor's hit
    levels prefix-serve too (the Fig. 5 --quick regime)."""
    stages = _pipeline(seed=8)
    accs = [a for st in stages for a in st.accesses]
    simulate_conventional(stages, acp_cache(), 5000)
    simulate_processor(10.0, accs, 5000)
    for m in (5000, 3000, 700):
        cv_cold = simulate_conventional(stages, acp_cache(), m,
                                        use_rescache=False)
        p_cold = simulate_processor(10.0, accs, m, use_rescache=False)
        before = rc.stats()["cold_chunks"]
        cv = simulate_conventional(stages, acp_cache(), m)
        p = simulate_processor(10.0, accs, m)
        _assert_same(cv, cv_cold, m)
        assert (p.cycles, p.cache_hits, p.cache_misses) == \
            (p_cold.cycles, p_cold.cache_hits, p_cold.cache_misses), m
        assert rc.stats()["cold_chunks"] == before, m
    # posted_writes is fold-only for the conventional artifact: the
    # blocking-store variant serves from the same records
    blocking = acp_cache()
    blocking.posted_writes = False
    cv_cold = simulate_conventional(stages, blocking, 5000,
                                    use_rescache=False)
    before = rc.stats()["cold_chunks"]
    cv = simulate_conventional(stages, blocking, 5000)
    _assert_same(cv, cv_cold)
    assert rc.stats()["cold_chunks"] == before


def test_resume_from_interrupted_run(small_chunks):
    """A run that stopped partway leaves completed chunk records; the
    next run resolves only the missing chunks and is bit-identical to
    an uninterrupted cold run."""
    stages = _pipeline(seed=9)
    cold = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16,
                             use_rescache=False)
    simulate_dataflow(stages, acp_cache(), 1500, fifo_depth=16)
    before = rc.stats()["cold_chunks"]
    full = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    _assert_same(full, cold)
    # chunks 0-1 (full 512-records) were resumed over; 8 of 10 resolve
    assert rc.stats()["cold_chunks"] - before == 8
    # resume works for the sharded executor too
    rc.clear(disk=True)
    rc.configure(enabled=True)
    simulate_dataflow(stages, acp_cache(), 1500, fifo_depth=16)
    sharded = simulate_dataflow_many(stages, {"M": acp_cache()}, 5000,
                                     fifo_depths=(16,),
                                     workers=2)[("M", 16)]
    _assert_same(sharded, cold)


def test_resume_after_missing_middle_chunk(small_chunks):
    """A gap in the stored chunks (evicted mid-prefix) truncates the
    usable prefix; the run re-resolves from the gap, still exact."""
    stages = _pipeline(seed=10)
    cold = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16,
                             use_rescache=False)
    simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    # knock out chunk 3 on disk and in memory
    victims = [f for f in os.listdir(small_chunks)
               if f.endswith(".c00003.npz")]
    assert victims
    for f in victims:
        os.unlink(os.path.join(small_chunks, f))
    rc._mem.clear()
    rc._mem_bytes = 0
    again = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    _assert_same(again, cold)


# ---------------------------------------------------------------------------
# Depth-incremental solving
# ---------------------------------------------------------------------------

def test_depth_incremental_equals_cold_at_every_depth():
    """Warm-started depth grids must equal cold per-depth solves even
    when shallow depths bind backpressure (Gauss–Seidel / block-mode
    territory), cycles and stall buckets alike."""
    rc.configure(enabled=False)
    stages = _pipeline(seed=13)
    depths = (2, 3, 8, 64)
    warm = simulate_dataflow_many(stages, {"M": acp_cache()}, 4000,
                                  fifo_depths=depths, use_rescache=False)
    cold = simulate_dataflow_many(stages, {"M": acp_cache()}, 4000,
                                  fifo_depths=depths, use_rescache=False,
                                  depth_incremental=False)
    for d in depths:
        _assert_same(warm[("M", d)], cold[("M", d)], d)
        ref = simulate_dataflow(stages, acp_cache(), 4000, fifo_depth=d,
                                use_rescache=False, reference=True)
        _assert_same(warm[("M", d)], ref, d)


# ---------------------------------------------------------------------------
# Finite store buffer
# ---------------------------------------------------------------------------

def test_store_buffer_pushback_monotone_and_mirrored():
    """Shrinking the posted-write buffer can only slow the pipeline
    (pushback through max_outstanding), ``None`` equals a buffer at
    least as deep as the outstanding cap, and the scalar reference
    mirrors the vectorized fold exactly at every depth."""
    rc.configure(enabled=False)
    rng = np.random.default_rng(14)
    n = 3000
    stages = [
        SimStage("w", ii=1, latency=2,
                 accesses=[MemAccess("out",
                                     rng.integers(0, 1 << 20, n) * 4,
                                     is_store=True)]),
        SimStage("mix", ii=1, latency=2,
                 accesses=[MemAccess("x",
                                     rng.integers(0, 1 << 20, n) * 4),
                           MemAccess("y", np.arange(n) * 4 + (1 << 23),
                                     is_store=True)]),
        SimStage("c", ii=2, latency=4),
    ]
    prev = None
    for depth in (None, 64, 8, 4, 2, 1):
        mem = MemoryModel(name=f"sb{depth}", store_buffer_depth=depth)
        vec = simulate_dataflow(stages, mem, n, use_rescache=False)
        ref = simulate_dataflow(stages, mem, n, reference=True)
        _assert_same(vec, ref, depth)
        if prev is not None:
            assert vec.cycles >= prev, depth
        prev = vec.cycles
    deep = MemoryModel(name="deep", store_buffer_depth=64)
    inf = MemoryModel(name="inf", store_buffer_depth=None)
    assert simulate_dataflow(stages, deep, n, use_rescache=False).cycles \
        == simulate_dataflow(stages, inf, n, use_rescache=False).cycles
    # fold-only: the buffer depth never keys the resolution artifact
    k1 = rc.resolution_key("dataflow", stages, deep, 0)
    k2 = rc.resolution_key("dataflow", stages,
                           MemoryModel(name="sb1",
                                       store_buffer_depth=1), 0)
    assert k1 == k2
    # blocking stores have no write buffer: depth is irrelevant
    b1 = MemoryModel(name="b1", posted_writes=False, store_buffer_depth=1)
    b2 = MemoryModel(name="b2", posted_writes=False)
    assert simulate_dataflow(stages, b1, n, use_rescache=False).cycles \
        == simulate_dataflow(stages, b2, n, use_rescache=False).cycles


# ---------------------------------------------------------------------------
# Store hygiene: gc and the census
# ---------------------------------------------------------------------------

def test_gc_removes_orphans_and_enforces_cap(small_chunks):
    stages = _pipeline(seed=15)
    simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    chunks_before = rc.census()["chunks"]
    assert chunks_before > 0
    # plant v1/v2-era orphans: whole-run npz, json summary, tmp debris
    fake = "ab" * 16
    for name in (fake + ".npz", fake + ".json", "x.tmp"):
        with open(os.path.join(small_chunks, name), "wb") as f:
            f.write(b"\x00" * 2048)
    report = rc.gc()
    assert report["orphans_removed"] == 3
    assert rc.census()["chunks"] == chunks_before
    served = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    assert served.cycles > 0  # records survived the gc
    # byte cap: evict down to a single chunk's worth
    one = min(os.path.getsize(os.path.join(small_chunks, f))
              for f in os.listdir(small_chunks))
    report = rc.gc(max_bytes=one)
    assert report["evicted"] > 0
    assert report["remaining_bytes"] <= one
    # a gutted store degrades to cold resolution, not an error
    cold = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16,
                             use_rescache=False)
    again = simulate_dataflow(stages, acp_cache(), 5000, fifo_depth=16)
    _assert_same(again, cold)

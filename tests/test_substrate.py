"""Tests for the training substrate: optimizer, schedule, compression,
data pipeline, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # noqa: E402 — skips when hypothesis is missing

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, prefetched, synthetic_stream
from repro.optim import (AdamWConfig, apply_updates, compress,
                         init_opt_state, warmup_cosine)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _toy_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "dense": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                  "bias": jnp.zeros((4,), jnp.float32)},
        "norm": {"scale": jnp.ones((8,), jnp.float32)},
    }


@pytest.mark.slow
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip_norm=1e9)
    params = _toy_params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p), params)
    state = init_opt_state(params, cfg)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in zip(
            jax.tree_util.tree_leaves(p),
            jax.tree_util.tree_leaves(target)))

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(loss(params)) < 1e-3 * l0


def test_adamw_grad_clip():
    cfg = AdamWConfig(lr=1e-3, grad_clip_norm=1.0)
    params = _toy_params()
    state = init_opt_state(params, cfg)
    huge = jax.tree_util.tree_map(lambda p: 1e6 * jnp.ones_like(p), params)
    new_params, _, info = apply_updates(params, huge, state, cfg)
    # update magnitude bounded: params can't move more than ~lr per element
    delta = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(new_params),
        jax.tree_util.tree_leaves(params)))
    assert delta < 10 * cfg.lr
    assert float(info["grad_norm"]) > 1e5


def test_adamw_no_decay_on_norm_and_bias():
    cfg = AdamWConfig(lr=0.0, weight_decay=1.0)  # lr 0: only decay matters
    params = _toy_params()
    state = init_opt_state(params, cfg)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_params, _, _ = apply_updates(params, zeros, state, cfg)
    # with lr=0 nothing changes at all — decay also scales by lr
    for a, b in zip(jax.tree_util.tree_leaves(new_params),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_warmup_cosine_shape():
    s = [float(warmup_cosine(i, warmup_steps=10, total_steps=100))
         for i in range(100)]
    assert s[0] == 0.0
    assert abs(s[10] - 1.0) < 0.11
    assert s[99] < 0.2
    assert max(s) <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=2000),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=20, deadline=None)
def test_int8_quant_roundtrip_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    q, scale = compress.quantize_int8(x, chunk=256)
    back = compress.dequantize_int8(q, scale, (n,))
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(scale), 256)[:n] * 0.5 + 1e-9
    assert (err <= bound + 1e-6).all()


@pytest.mark.slow
def test_compressed_psum_multidevice():
    import subprocess, sys, textwrap
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum

        mesh = jax.make_mesh((8,), ("pod",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))

        def f(xs):
            return compressed_psum(xs, "pod")

        from repro.core import shard_map_compat
        got = jax.jit(shard_map_compat(f, mesh=mesh, in_specs=P("pod"),
                                       out_specs=P("pod")))(x)
        want = x.sum(0, keepdims=True).repeat(8, 0)
        # theoretical bound: per-contributor error <= shared_scale/2,
        # 8 contributors; shared scale = max|x| over shards / 127
        scale = np.abs(np.asarray(x)).max(axis=0) / 127.0
        bound = 8 * 0.5 * scale.max() + 1e-6
        err = np.abs(np.asarray(got) - np.asarray(want)).max()
        assert err <= bound, (err, bound)
        print("compressed psum OK", err, "<=", bound)
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_stream_deterministic_resume():
    cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=64, seed=3)
    a = synthetic_stream(cfg)
    batches = [next(a) for _ in range(6)]
    # resume from step 3 must reproduce batch 3 exactly
    b = synthetic_stream(cfg, start_step=3)
    resumed = next(b)
    np.testing.assert_array_equal(batches[3]["tokens"], resumed["tokens"])


def test_prefetched_pipeline_preserves_order():
    cfg = DataConfig(batch_size=1, seq_len=8, vocab_size=32)
    direct = synthetic_stream(cfg)
    want = [next(direct)["tokens"] for _ in range(5)]
    fifo = prefetched(synthetic_stream(cfg), depth=3)
    got = [np.asarray(next(fifo)["tokens"]) for _ in range(5)]
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)


def test_stream_is_learnable_structure():
    """The synthetic process must be predictable (loss can decrease)."""
    cfg = DataConfig(batch_size=4, seq_len=32, vocab_size=64)
    batch = next(synthetic_stream(cfg))["tokens"]
    # >50% of adjacent-token transitions repeat the previous token's block
    same = (np.diff(batch, axis=1) == 0).mean()
    assert same > 0.3


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------

def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(4, 4)),
                                        jnp.float32)},
            "step": jnp.asarray(seed, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    s = _state(7)
    ck.save(7, s, blocking=True)
    restored, step = ck.restore(_state(0))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))


def test_checkpoint_keep_n_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for i in range(5):
        ck.save(i, _state(i), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp file lying around must never be visible as a checkpoint."""
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, _state(1), blocking=True)
    # simulate a crashed write
    with open(os.path.join(str(tmp_path), "step_00000002.tmp"), "wb") as f:
        f.write(b"garbage")
    assert ck.all_steps() == [1]
    restored, step = ck.restore(_state(0))
    assert step == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), blocking=True)
    bad = {"params": {"w": jnp.zeros((2, 2))}, "step": jnp.zeros((),
                                                                 jnp.int32)}
    with pytest.raises(ValueError, match="shape"):
        ck.restore(bad)


# ---------------------------------------------------------------------------
# Fault tolerance end-to-end (train loop with injected failure)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_recovers_from_injected_failure(tmp_path):
    from repro.configs import load_config, reduced
    from repro.launch.train import train_loop

    cfg = reduced(load_config("smollm-135m"), max_repeats=1)
    # run A: uninterrupted
    out_a = train_loop(cfg, steps=12, batch_size=2, seq_len=16,
                       ckpt_dir=str(tmp_path / "a"), ckpt_every=4)
    # run B: failure injected at step 9 → restore from ckpt 8 → same result
    out_b = train_loop(cfg, steps=12, batch_size=2, seq_len=16,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=4,
                       fail_at=9)
    assert out_b["failures"] == 1 and out_b["restores"] == 1
    np.testing.assert_allclose(out_a["final_loss"], out_b["final_loss"],
                               rtol=1e-5)


@pytest.mark.slow
def test_train_resume_matches_uninterrupted(tmp_path):
    """Kill after 8 steps, restart to 12 — identical final loss to a
    single 12-step run (deterministic data + bitwise state restore)."""
    from repro.configs import load_config, reduced
    from repro.launch.train import train_loop

    cfg = reduced(load_config("smollm-135m"), max_repeats=1)
    full = train_loop(cfg, steps=12, batch_size=2, seq_len=16,
                      ckpt_dir=str(tmp_path / "full"), ckpt_every=100)
    part1 = train_loop(cfg, steps=8, batch_size=2, seq_len=16,
                       ckpt_dir=str(tmp_path / "r"), ckpt_every=100,
                       schedule_steps=12)
    part2 = train_loop(cfg, steps=12, batch_size=2, seq_len=16,
                       ckpt_dir=str(tmp_path / "r"), ckpt_every=100,
                       schedule_steps=12)
    np.testing.assert_allclose(full["final_loss"], part2["final_loss"],
                               rtol=1e-5)

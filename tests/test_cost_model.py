"""Unit tests for the analytic cost model and its §Perf knobs — every knob
must move exactly the term its hypothesis targets."""

import pytest

from repro.configs import SHAPES, load_config
from repro.runtime.cost_model import (ShardingAssumptions, cost_for_cell,
                                      step_cost)


def _sh(**kw):
    base = dict(dp=16, tp=16)
    base.update(kw)
    return ShardingAssumptions(**base)


def test_train_flops_match_6nd_dense():
    cfg = load_config("olmo-1b")
    c = step_cost(cfg, SHAPES["train_4k"], _sh())
    model = 6 * cfg.param_count() * 256 * 4096 / 256
    assert 0.5 < c.flops / model < 2.5


def test_moe_flops_use_active_params():
    cfg = load_config("deepseek-v3-671b")
    c = step_cost(cfg, SHAPES["train_4k"], _sh())
    active = 6 * cfg.active_param_count() * 256 * 4096 / 256
    total = 6 * cfg.param_count() * 256 * 4096 / 256
    assert c.flops < 0.5 * total        # NOT charged for all experts
    assert c.flops > 0.5 * active       # but at least the active ones


def test_int8_kv_halves_cache_term():
    cfg = load_config("qwen2.5-14b")
    bf = step_cost(cfg, SHAPES["decode_32k"], _sh(fsdp_params=False))
    q8 = step_cost(cfg, SHAPES["decode_32k"], _sh(fsdp_params=False,
                                                  kv_bytes=1))
    assert q8.breakdown["cache_bytes_chip"] == pytest.approx(
        bf.breakdown["cache_bytes_chip"] / 2)
    assert q8.hbm_bytes < bf.hbm_bytes


def test_int8_a2a_halves_dispatch_term():
    cfg = load_config("deepseek-v3-671b")
    bf = step_cost(cfg, SHAPES["train_4k"], _sh())
    q8 = step_cost(cfg, SHAPES["train_4k"], _sh(a2a_bytes=1))
    assert q8.breakdown["moe_a2a_bytes"] == pytest.approx(
        bf.breakdown["moe_a2a_bytes"] / 2)


def test_seq_parallel_halves_tp_ar():
    cfg = load_config("qwen2.5-14b")
    bf = step_cost(cfg, SHAPES["train_4k"], _sh())
    sp = step_cost(cfg, SHAPES["train_4k"], _sh(seq_parallel=True))
    assert sp.breakdown["tp_allreduce_bytes"] == pytest.approx(
        bf.breakdown["tp_allreduce_bytes"] / 2)


def test_ep_serve_removes_weight_gather():
    cfg = load_config("deepseek-v3-671b")
    two_d = step_cost(cfg, SHAPES["decode_32k"], _sh(fsdp_params=True))
    ep = step_cost(cfg, SHAPES["decode_32k"],
                   _sh(fsdp_params=True, ep_serve=True))
    assert "serve_weight_ag_bytes" in two_d.breakdown
    assert "serve_weight_ag_bytes" not in ep.breakdown
    assert ep.coll_bytes < 0.05 * two_d.coll_bytes
    assert ep.hbm_bytes < two_d.hbm_bytes


def test_device_limited_routing_scales_a2a():
    cfg = load_config("deepseek-v3-671b")
    full = step_cost(cfg, SHAPES["train_4k"], _sh())
    lim = step_cost(cfg, SHAPES["train_4k"], _sh(k_eff=4.0))
    assert lim.breakdown["moe_a2a_bytes"] == pytest.approx(
        full.breakdown["moe_a2a_bytes"] * 4 / 8)


def test_decode_dominated_by_memory_for_dense():
    cfg = load_config("qwen2.5-14b")
    r = cost_for_cell(cfg, SHAPES["decode_32k"]).roofline()
    assert r["dominant"] == "memory"


def test_train_dominated_by_collective_on_fixed_mesh():
    cfg = load_config("deepseek-v3-671b")
    r = cost_for_cell(cfg, SHAPES["train_4k"]).roofline()
    assert r["dominant"] == "collective"


def test_long500k_clamps_dp_to_batch():
    cfg = load_config("rwkv6-1.6b")
    c = cost_for_cell(cfg, SHAPES["long_500k"])
    assert c.flops > 0  # batch=1 must not divide away to zero work

"""End-to-end behaviour tests for the paper's system.

These exercise whole flows: the paper-claim reproduction (Fig. 5 trends),
the training loop (loss decreases, recovery), serving (prefill+decode),
and one dry-run cell (lower+compile on the 256-chip placeholder mesh, in a
subprocess so this process keeps one device).
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Paper claims (Fig. 5 trends on the SpMV + DFS kernels)
# ---------------------------------------------------------------------------

def test_paper_fig5_spmv_band():
    """SpMV dataflow-vs-conventional gain must land in the paper's band
    (3.3–9.1× best-config, wide tolerance for the simulator)."""
    sys.path.insert(0, _ROOT)
    from benchmarks.paper_fig5 import build_stages, run_kernel
    from benchmarks.paper_kernels import make_spmv

    k = make_spmv(scale=0.0625)
    r = run_kernel(k)
    cfgs = ("ACP", "ACP+64KB", "HP", "HP+64KB")
    best_df = min(r[m]["dataflow_s"] for m in cfgs)
    best_cv = min(r[m]["conventional_s"] for m in cfgs)
    gain = best_cv / best_df
    assert 2.0 < gain < 20.0, gain
    # conventional below the ARM baseline (paper §V-A)
    assert r["ACP"]["conventional_vs_baseline"] < 1.0


def test_paper_fig5_dfs_negative():
    """DFS must NOT benefit (memory SCC) — the paper's negative result."""
    sys.path.insert(0, _ROOT)
    from benchmarks.paper_fig5 import run_kernel
    from benchmarks.paper_kernels import make_dfs

    r = run_kernel(make_dfs())
    for m in ("ACP", "ACP+64KB"):
        assert r[m]["dataflow_vs_conventional"] < 1.5


def test_partitioner_collapses_dfs_to_one_stage():
    sys.path.insert(0, _ROOT)
    from benchmarks.paper_fig5 import build_stages
    from benchmarks.paper_kernels import make_dfs

    df_stages, _ = build_stages(make_dfs())
    mem_stages = [s for s in df_stages if s.accesses]
    assert all(s.mem_in_scc for s in mem_stages), \
        "DFS memory ops must sit inside the dependence cycle"


# ---------------------------------------------------------------------------
# Training end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    from repro.configs import load_config, reduced
    from repro.launch.train import train_loop

    cfg = reduced(load_config("smollm-135m"), d_model=128, max_repeats=2)
    out = train_loop(cfg, steps=40, batch_size=8, seq_len=64,
                     ckpt_dir=str(tmp_path), ckpt_every=50, lr=1e-3)
    first = float(np.mean(out["losses"][:5]))
    last = float(np.mean(out["losses"][-5:]))
    assert last < first, (first, last)


# ---------------------------------------------------------------------------
# Serving end-to-end
# ---------------------------------------------------------------------------

def test_serve_batched_deterministic():
    from repro.configs import load_config, reduced
    from repro.launch.serve import BatchedServer, Request
    from repro.models import init_params

    cfg = reduced(load_config("olmo-1b"), max_repeats=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=(8,))
                    .astype(np.int32), 8) for i in range(3)]
    a = server.serve(reqs)
    b = server.serve(reqs)
    for ra, rb in zip(a, b):
        assert ra.tokens == rb.tokens


# ---------------------------------------------------------------------------
# Dry-run: one full cell in a 512-device subprocess
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_cell_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    code = textwrap.dedent("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("smollm-135m", "train_4k", multi_pod=False,
                       save=False)
        assert rec["status"] == "ok", rec
        assert rec["coll"]["total"] > 0
        print("cell ok", rec["hlo_flops"])
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=_ROOT)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"


def test_roofline_cost_model_consistency():
    """Analytic cost model sanity: train flops/chip ≈ 6·N·D/chips for a
    dense arch (±2× for attention quadratic + logits)."""
    from repro.configs import SHAPES, load_config
    from repro.runtime.cost_model import cost_for_cell

    cfg = load_config("qwen2.5-14b")
    c = cost_for_cell(cfg, SHAPES["train_4k"])
    model = 6 * cfg.param_count() * (256 * 4096) / 256
    assert 0.5 < c.flops / model < 2.5, c.flops / model


def test_experiment_artifacts_exist():
    """The committed dry-run artifacts cover the full matrix."""
    import glob
    import json
    recs = []
    for p in glob.glob(os.path.join(_ROOT, "experiments/dryrun/*.json")):
        with open(p) as f:
            recs.append(json.load(f))
    base = [r for r in recs if not r.get("variant")]
    ok = [r for r in base if r["status"] == "ok"]
    skip = [r for r in base if r["status"] == "skip"]
    err = [r for r in base if r["status"] == "error"]
    assert len(ok) == 64, len(ok)
    assert len(skip) == 16, len(skip)
    assert not err
    # every ok cell compiled with nonzero flops and a collective census
    for r in ok:
        assert r["hlo_flops"] > 0
        assert "coll" in r

"""Chaos harness: deterministic fault injection (repro.serve.faults)
driven end-to-end through the resolution/serving stack.

The contract under test: **every fault scenario ends bit-identical to a
clean library run** — worker SIGKILL mid-chunk, corrupt/truncated store
records, daemon SIGKILL mid-stream, dropped/delayed client sockets, and
straggling workers all recover (respawn + replay, quarantine +
re-resolve, failover to library mode from the committed prefix,
speculative duplicate dispatch) without changing a single bit of the
result, and every recovery is visible in the counters
(``rescache.census()``, daemon ``stats``) instead of silent.

Also covers the supporting machinery: the fault plan itself
(occurrence windows, filters, the cross-process firing registry), the
client timeout/backoff envelope, stale-socket / pidfile spawn guards,
the journal's restart replay, and the ``runtime.fault_tolerance``
policies (StepGuard, StragglerPolicy, SpeculationPolicy).
"""

import contextlib
import json
import os
import queue as _queue
import subprocess
import sys
import tempfile
import threading
import time

import pytest

from repro.core import rescache as rc
from repro.core.simulator import (acp, acp_cache, simulate_dataflow_many)
from repro.serve import faults

from _serve_client import pipeline


@pytest.fixture()
def store(tmp_path, monkeypatch):
    d = str(tmp_path / "store")
    rc.clear()
    rc.configure(enabled=True, directory=d)
    monkeypatch.setattr(rc, "CHUNK_ITERS", 512)
    monkeypatch.setenv("REPRO_CHUNK_ITERS", "512")
    yield d
    rc.clear()
    rc.configure(enabled=False)


@pytest.fixture(autouse=True)
def disarm_faults(monkeypatch):
    """Every test starts and ends with no plan armed and a clean env."""
    monkeypatch.delenv(faults.ENV, raising=False)
    faults.reset()
    yield
    faults.reset()


@contextlib.contextmanager
def daemon(**kw):
    from repro.serve.daemon import ResolutionDaemon
    sdir = tempfile.mkdtemp(prefix="serve-")
    kw.setdefault("workers", 2)
    d = ResolutionDaemon(address=os.path.join(sdir, "d.sock"), **kw)
    d.start()
    try:
        yield d
    finally:
        d.stop()


def _key(v):
    return (v.cycles, v.cache_hits, v.cache_misses,
            v.stage_stall_cycles)


def _ref(n=5000, mems=None, depths=(8,)):
    """The clean library baseline: no rescache, streaming engine."""
    mems = mems or {"ACPC": acp_cache()}
    return simulate_dataflow_many(pipeline(n), dict(mems), n,
                                  fifo_depths=depths,
                                  use_rescache=False)


def _arm_env(monkeypatch, tmp_path, specs, name="plan"):
    """Arm a plan through the environment (reaches spawned workers and
    daemons) with a log file as the cross-process firing registry."""
    log = str(tmp_path / f"{name}.log")
    plan = {"faults": specs, "log": log}
    monkeypatch.setenv(faults.ENV, json.dumps(plan))
    faults.reset()  # re-read the env in this process too
    return log


# ---------------------------------------------------------------------------
# The fault plan itself
# ---------------------------------------------------------------------------

def test_fault_spec_matching_and_windows():
    p = faults.FaultPlan([
        {"kind": "worker_kill", "at": 2, "count": 2, "chunk": 7},
    ])
    # chunk filter: non-matching events are not even counted
    assert p.check("worker_kill", chunk=3) is None
    # occurrence window [2, 3] of *matching* events
    assert p.check("worker_kill", chunk=7) is None     # occurrence 1
    assert p.check("worker_kill", chunk=7) is not None  # 2
    assert p.check("worker_kill", chunk=7) is not None  # 3
    assert p.check("worker_kill", chunk=7) is None      # 4: window over
    assert p.injected == {"worker_kill": 2}
    with pytest.raises(ValueError):
        faults.FaultSpec("no_such_kind")


def test_fault_plan_json_roundtrip_and_env(monkeypatch, tmp_path):
    p = faults.FaultPlan([{"kind": "straggler", "delay_s": 1.5,
                           "target": 3}], seed=9, log="/tmp/x.log")
    q = faults.FaultPlan.from_json(p.to_json())
    assert q.seed == 9 and q.log == "/tmp/x.log"
    assert q.faults[0].kind == "straggler"
    assert q.faults[0].delay_s == 1.5 and q.faults[0].target == 3
    # env can hold a path to the JSON as well as inline JSON
    f = tmp_path / "plan.json"
    f.write_text(p.to_json())
    monkeypatch.setenv(faults.ENV, str(f))
    faults.reset()
    assert faults.active()
    assert faults.plan().faults[0].kind == "straggler"


def test_fault_log_is_cross_process_firing_registry(tmp_path):
    """A spec fires at most ``count`` times across *all* processes of
    the plan: a respawned worker re-armed with the same env plan must
    not re-kill itself forever (that would eat the retry budget)."""
    log = str(tmp_path / "fire.log")
    raw = json.dumps({"faults": [{"kind": "worker_kill", "chunk": 2}],
                      "log": log})
    first = faults.FaultPlan.from_json(raw)
    assert first.check("worker_kill", chunk=2) is not None  # fires+logs
    respawn = faults.FaultPlan.from_json(raw)  # fresh process simulated
    assert respawn.check("worker_kill", chunk=2) is None
    assert faults.log_counts(log) == {"worker_kill": 1}


def test_corrupt_and_truncate_are_deterministic(tmp_path):
    a, b = tmp_path / "a.bin", tmp_path / "b.bin"
    payload = bytes(range(256)) * 64
    a.write_bytes(payload)
    b.write_bytes(payload)
    faults.corrupt_file(str(a), seed=7)
    faults.corrupt_file(str(b), seed=7)
    assert a.read_bytes() == b.read_bytes() != payload
    faults.truncate_file(str(a))
    assert a.stat().st_size == len(payload) // 2


# ---------------------------------------------------------------------------
# Store integrity: checksums, quarantine, crash-safe writes
# ---------------------------------------------------------------------------

def _store_files(d):
    # chunk records only: per-chunk cache-effect records
    # (``<key>.eNNNNN.npz``) commit alongside them and would skew the
    # committed-prefix counts these drills assert on
    return sorted(f for f in os.listdir(d) if rc._CHUNK_RE.match(f))


def _one_record(store):
    """Resolve once through the store and return a record's path."""
    n = 1500
    simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()}, n,
                           fifo_depths=(8,))
    files = _store_files(store)
    assert files
    return os.path.join(store, files[0])


def test_checksum_detects_bitflips_and_quarantines(store):
    path = _one_record(store)
    key, cidx = os.path.basename(path).split(".")[0], 0
    assert rc.get_chunk(key, cidx, refresh=True) is not None
    faults.corrupt_file(path, seed=3)
    rc.clear()  # drop the memory tier so the disk record is re-read
    rc.configure(enabled=True, directory=store)
    assert rc.get_chunk(key, cidx, refresh=True) is None
    assert rc.stats()["quarantined"] == 1
    assert not os.path.exists(path)  # moved aside, never served again
    cen = rc.census()
    assert cen["quarantined"] == 1 and cen["quarantine_files"] == 1


def test_truncated_record_quarantined(store):
    path = _one_record(store)
    key = os.path.basename(path).split(".")[0]
    faults.truncate_file(path)
    rc.clear()
    rc.configure(enabled=True, directory=store)
    assert rc.get_chunk(key, 0, refresh=True) is None
    assert rc.stats()["quarantined"] == 1
    assert rc.chunk_len(key, 0) is None  # header path quarantines too


@pytest.mark.parametrize("kind", ["corrupt_chunk", "truncate_chunk"])
def test_chaos_store_damage_end_to_end(store, monkeypatch, tmp_path,
                                       kind):
    """A record damaged at write time is detected on read, quarantined,
    re-resolved, and the rerun is bit-identical to the clean baseline —
    with exactly one committed record per chunk at the end."""
    n = 2500  # 5 chunks
    ref = _ref(n)
    _arm_env(monkeypatch, tmp_path, [{"kind": kind, "chunk": 2}])
    first = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()},
                                   n, fifo_depths=(8,))
    for k in ref:  # the writer's own run folded live ops: still clean
        assert _key(first[k]) == _key(ref[k]), k
    assert faults.stats().get(kind) == 1
    monkeypatch.delenv(faults.ENV)
    faults.reset()
    rc.clear()  # drop memory tier: force the damaged disk read
    rc.configure(enabled=True, directory=store)
    again = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()},
                                   n, fifo_depths=(8,))
    for k in ref:
        assert _key(again[k]) == _key(ref[k]), k
    assert rc.stats()["quarantined"] >= 1
    # exactly-once: the re-resolve healed the store — 5 clean records,
    # and one more pass serves fully warm with zero cold chunks
    assert len(_store_files(store)) == 5
    rc.clear()
    rc.configure(enabled=True, directory=store)
    warm = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()},
                                  n, fifo_depths=(8,))
    for k in ref:
        assert _key(warm[k]) == _key(ref[k]), k
    assert rc.stats()["cold_chunks"] == 0
    assert rc.stats()["quarantined"] == 0


# ---------------------------------------------------------------------------
# Chaos matrix: the serving stack under injected faults
# ---------------------------------------------------------------------------

def test_chaos_worker_sigkill_mid_chunk(store, monkeypatch, tmp_path):
    """A pool worker SIGKILLed mid-chunk: the daemon respawns the slot,
    replays its in-flight chunks, and the served result is
    bit-identical; the kill is visible in worker_restarts and the fault
    log (the killed process cannot report itself)."""
    n = 5000
    ref = _ref(n)
    log = _arm_env(monkeypatch, tmp_path,
                   [{"kind": "worker_kill", "chunk": 3}])
    from repro.serve.client import simulate_dataflow_served
    with daemon() as d:
        got = simulate_dataflow_served(pipeline(n),
                                       {"ACPC": acp_cache()}, n,
                                       fifo_depths=(8,),
                                       address=d.address)
        st = d.stats()
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k
    assert faults.log_counts(log) == {"worker_kill": 1}
    assert st["failures"]["worker_restarts"] >= 1
    assert st["failures"]["chunk_retries"] >= 1
    assert st["jobs_completed"] == 1


def test_chaos_straggler_speculative_dispatch(store, monkeypatch,
                                              tmp_path):
    """A worker straggling in the heavy phase earns a speculative
    duplicate dispatch; the first commit wins, the loser is discarded,
    and the result is bit-identical.  The firing registry keeps the
    duplicate worker from re-injecting the same straggle."""
    n = 5000  # 10 chunks; straggle the last so the test stays fast
    ref = _ref(n)
    _arm_env(monkeypatch, tmp_path,
             [{"kind": "straggler", "chunk": 9, "delay_s": 8.0}])
    from repro.serve.client import simulate_dataflow_served
    with daemon(speculate_after_s=0.5) as d:
        got = simulate_dataflow_served(pipeline(n),
                                       {"ACPC": acp_cache()}, n,
                                       fifo_depths=(8,),
                                       address=d.address)
        st = d.stats()
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k
    assert st["speculation"]["issued"] >= 1
    assert st["speculation"]["wins"] >= 1
    assert st["jobs_completed"] == 1


def test_chaos_chunkgraph_straggler_speculation(store, monkeypatch,
                                                tmp_path):
    """The same bounded-staleness speculation in the chunk-graph
    executor: the master re-dispatches the straggling phase-C chunk to
    an idle peer and the sharded result stays bit-identical."""
    n = 5000
    rc.configure(enabled=False)  # pure-compute path, no store writes
    ref = _ref(n)
    _arm_env(monkeypatch, tmp_path,
             [{"kind": "straggler", "chunk": 9, "delay_s": 6.0}])
    monkeypatch.setenv("REPRO_SPECULATE_AFTER_S", "0.5")
    got = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()}, n,
                                 fifo_depths=(8,), use_rescache=False,
                                 workers=2)
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k
    assert rc.stats()["speculated"] >= 1


def test_chaos_socket_drop_fails_over_to_library(store):
    """The daemon link dropped mid-stream: the client raises
    ServeUnavailable, ``simulate_dataflow_many`` falls back to library
    mode, resumes from the committed store prefix, and the result is
    bit-identical; the failover is counted, never silent."""
    n = 5000
    ref = _ref(n)
    faults.install(faults.FaultPlan([{"kind": "drop_socket", "at": 4}]))
    with daemon() as d:
        got = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()},
                                     n, fifo_depths=(8,),
                                     server=d.address)
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k
    assert faults.stats() == {"drop_socket": 1}
    assert rc.stats()["serve_failovers"] == 1
    assert rc.census()["serve_failovers"] == 1


def test_chaos_socket_delay_is_absorbed(store):
    """A delayed stream is not a failure: the run just waits it out."""
    n = 1500
    ref = _ref(n)
    faults.install(faults.FaultPlan(
        [{"kind": "delay_socket", "at": 2, "delay_s": 0.4}]))
    with daemon() as d:
        got = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()},
                                     n, fifo_depths=(8,),
                                     server=d.address)
    for k in ref:
        assert _key(got[k]) == _key(ref[k]), k
    assert faults.stats() == {"delay_socket": 1}
    assert rc.stats()["serve_failovers"] == 0


def _spawn_daemon_proc(sock, store, extra_env=None):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.update(extra_env or {})
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "daemon",
         "--socket", sock, "--workers", "2", "--store-dir", store,
         "--speculate-after", "0"],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    from repro.serve.client import ping
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline and not ping(sock):
        if proc.poll() is not None:
            break
        time.sleep(0.2)
    return proc


def test_chaos_daemon_sigkill_and_journal_restart(store, monkeypatch,
                                                  tmp_path):
    """The centerpiece scenario: the daemon SIGKILLs itself after
    committing chunk 4 mid-stream.  (a) The client fails over to
    library mode and finishes bit-identically from the committed
    prefix.  (b) A *restarted* daemon replays its journal, re-attaches
    the half-finished job as an orphan, finishes the remainder into the
    store with no client attached, and reports monotone counters
    (restarts, resumed jobs) — after which a cold client is served the
    whole artifact with zero cold chunks."""
    n = 5000  # 10 chunks
    ref = _ref(n)
    log = str(tmp_path / "dk.log")
    plan = json.dumps({"faults": [{"kind": "daemon_kill", "chunk": 4}],
                       "log": log})
    sdir = tempfile.mkdtemp(prefix="serve-")
    sock = os.path.join(sdir, "d.sock")
    proc = _spawn_daemon_proc(sock, store,
                              extra_env={faults.ENV: plan})
    from repro.serve.client import (ServeUnavailable, get_stats, ping,
                                    shutdown, simulate_dataflow_served)
    try:
        assert ping(sock), "daemon never came up"
        # (a) serve-only attempt dies mid-stream at the kill point
        with pytest.raises(ServeUnavailable):
            simulate_dataflow_served(pipeline(n), {"ACPC": acp_cache()},
                                     n, fifo_depths=(8,), address=sock)
        assert rc.stats()["serve_failovers"] == 1
        assert faults.log_counts(log) == {"daemon_kill": 1}
        committed = len(_store_files(store))
        assert 1 <= committed < 10  # a prefix, not the whole job
        # library fallback path is what simulate_dataflow_many does:
        got = simulate_dataflow_many(pipeline(n), {"ACPC": acp_cache()},
                                     n, fifo_depths=(8,), server=sock)
        for k in ref:
            assert _key(got[k]) == _key(ref[k]), k

        # (b) journal re-attach: reap the killed daemon first (its
        # zombie pid would trip the restarted daemon's pidfile guard),
        # wipe the fallback's local completions so the restarted daemon
        # has a remainder to finish, then restart with no fault plan
        proc.wait(timeout=30)
        for f in _store_files(store)[committed:]:
            os.unlink(os.path.join(store, f))
        rc.clear()
        rc.configure(enabled=True, directory=store)
        proc2 = _spawn_daemon_proc(sock, store)
        try:
            assert ping(sock), "restarted daemon never came up"
            deadline = time.monotonic() + 120
            while time.monotonic() < deadline \
                    and len(_store_files(store)) < 10:
                time.sleep(0.5)
            assert len(_store_files(store)) == 10, \
                "restarted daemon did not finish the journaled job"
            st = get_stats(sock)
            assert st["journal"]["enabled"]
            assert st["journal"]["restarts"] >= 1
            assert st["journal"]["resumed_jobs"] >= 1
            shutdown(sock)
        finally:
            proc2.terminate()
            proc2.wait(timeout=10)
        rc.clear()
        rc.configure(enabled=True, directory=store)
        warm = simulate_dataflow_many(pipeline(n),
                                      {"ACPC": acp_cache()}, n,
                                      fifo_depths=(8,))
        for k in ref:
            assert _key(warm[k]) == _key(ref[k]), k
        assert rc.stats()["cold_chunks"] == 0
    finally:
        proc.terminate()
        proc.wait(timeout=10)


# ---------------------------------------------------------------------------
# Client resilience: timeouts, backoff, spawn guards
# ---------------------------------------------------------------------------

def test_serve_timeouts_env_and_configure(monkeypatch):
    from repro.serve import client
    monkeypatch.setenv("REPRO_SERVE_CONNECT_TIMEOUT_S", "3.5")
    monkeypatch.setenv("REPRO_SERVE_MAX_WAIT_S", "7")
    monkeypatch.setenv("REPRO_SERVE_DEADLINE_S", "42")
    t = client.ServeTimeouts.from_env()
    assert t.connect_timeout_s == 3.5
    assert t.max_wait_s == 7.0 and t.deadline_s == 42.0
    try:
        client.configure_timeouts(max_wait_s=1.25)
        assert client._cfg(None).max_wait_s == 1.25
        explicit = client.ServeTimeouts(max_wait_s=9.0)
        assert client._cfg(explicit).max_wait_s == 9.0  # arg wins
    finally:
        client.configure_timeouts(None)


def test_backoff_is_deterministic_and_capped():
    from repro.serve import client
    cfg = client.ServeTimeouts(backoff_base_s=0.05, backoff_cap_s=0.4)
    a = [client._backoff(cfg, i) for i in range(12)]
    b = [client._backoff(cfg, i) for i in range(12)]
    assert a == b  # same pid, same attempt -> same jitter
    assert all(d <= 0.4 * 2.0 for d in a)  # cap (+jitter<=cap)
    assert a[0] < a[5] or a[5] == pytest.approx(0.4, abs=0.4)


def test_connect_honors_cumulative_deadline(tmp_path):
    from repro.serve import client
    cfg = client.ServeTimeouts(max_wait_s=1.0, backoff_base_s=0.02,
                               backoff_cap_s=0.1)
    t0 = time.monotonic()
    with pytest.raises(client.ServeUnavailable):
        client._connect(str(tmp_path / "nobody.sock"), cfg,
                        time.monotonic() + cfg.max_wait_s)
    assert time.monotonic() - t0 < 5.0  # bounded, not 600s


def test_options_serve_block_configures_client(store):
    """CompileOptions.serve plumbs timeouts into the client and
    defaults server= for Compiled.simulate."""
    import jax.numpy as jnp
    from repro.dataflow import ServeOptions, compile as dfc
    from repro.serve import client

    def f(x):
        return jnp.cumsum(x * 2.0)

    c = dfc(f, jnp.arange(64, dtype=jnp.float32),
            serve=ServeOptions(max_wait_s=0.5, backoff_cap_s=0.1))
    try:
        rep = c.simulate(n_iters=256)  # no daemon: falls back locally
        assert rep is not None
        assert client._cfg(None).max_wait_s == 0.5
    finally:
        client.configure_timeouts(None)


def test_stale_socket_cleared_and_spawn_race(store, monkeypatch):
    """A dead socket file is unlinked under the spawn lock, and two
    racing ensure_daemon calls yield exactly one daemon."""
    import socket as _socket
    from repro.serve import client
    sdir = tempfile.mkdtemp(prefix="serve-")
    sock = os.path.join(sdir, "stale.sock")
    s = _socket.socket(_socket.AF_UNIX)
    s.bind(sock)
    s.close()  # bound but never listening: the crashed-daemon husk
    assert os.path.exists(sock) and not client.ping(sock)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    monkeypatch.setenv("PYTHONPATH", os.path.join(repo, "src")
                       + os.pathsep + os.environ.get("PYTHONPATH", ""))
    results, errs = [], []

    def race():
        try:
            results.append(client.ensure_daemon(sock, workers=1))
        except Exception as e:  # noqa: BLE001 — surfaced below
            errs.append(e)

    ts = [threading.Thread(target=race) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=90)
    try:
        assert not errs, errs
        assert results == [sock, sock]
        st = client.get_stats(sock)
        assert st["workers"] == 1
    finally:
        client.shutdown(sock)


def test_pidfile_guard_rejects_second_daemon(store):
    from repro.serve.daemon import ResolutionDaemon
    sdir = tempfile.mkdtemp(prefix="serve-")
    sock = os.path.join(sdir, "d.sock")
    d1 = ResolutionDaemon(address=sock, workers=1)
    d1.start()
    try:
        d2 = ResolutionDaemon(address=sock, workers=1)
        with pytest.raises(RuntimeError, match="already"):
            d2.start()
    finally:
        d1.stop()
    assert not os.path.exists(sock + ".pid")  # clean stop removes it
    # and after a clean stop the address is reusable
    d3 = ResolutionDaemon(address=sock, workers=1)
    d3.start()
    d3.stop()


# ---------------------------------------------------------------------------
# runtime.fault_tolerance policies
# ---------------------------------------------------------------------------

def test_step_guard_retries_from_checkpoint():
    from repro.runtime.fault_tolerance import GuardConfig, StepGuard
    calls = {"restores": 0}

    def restore():
        calls["restores"] += 1
        return {"w": 0.0}, 0

    guard = StepGuard(lambda s, b: (s, {"loss": 1.0}),
                      GuardConfig(max_retries=3, restore_fn=restore,
                                  fail_at=lambda step: step == 2))
    for step in range(4):
        state, m = guard.run({"w": 0.0}, {}, step)
        assert m["loss"] == 1.0
    assert guard.failures == 1 and guard.restores == 1
    assert calls["restores"] == 1


def test_step_guard_budget_exhausted():
    from repro.runtime.fault_tolerance import (GuardConfig, StepFailure,
                                               StepGuard)

    def always_fail(s, b):
        raise StepFailure("boom")

    guard = StepGuard(always_fail, GuardConfig(max_retries=2))
    with pytest.raises(StepFailure):
        guard.run({}, {}, 0)
    assert guard.failures == 3  # initial + 2 retries


def test_straggler_policy_bounded_staleness():
    from repro.runtime.fault_tolerance import StragglerPolicy

    class Source:
        _SENTINEL = object()

        def __init__(self):
            self._q = _queue.Queue()

        def __next__(self):
            return self._q.get()

    src = Source()
    pol = StragglerPolicy(deadline_s=0.05, max_consecutive_reuse=2)
    src._q.put({"x": 1})
    assert pol.next_batch(src) == {"x": 1}
    # producer stalls: reuse the last batch, bounded
    assert pol.next_batch(src) == {"x": 1}
    assert pol.next_batch(src) == {"x": 1}
    assert pol.reused == 2
    # past the bound it must block for real
    src._q.put({"x": 2})
    assert pol.next_batch(src) == {"x": 2}


def test_speculation_policy_overdue_logic():
    from repro.runtime.fault_tolerance import SpeculationPolicy
    pol = SpeculationPolicy(min_wait_s=2.0, latency_factor=4.0)
    assert not pol.overdue(1e9)  # no samples: no baseline, never fire
    for w in (0.1, 0.2, 0.3):
        pol.observe(w)
    assert pol.median_wall() == 0.2
    assert not pol.overdue(1.9)   # floored at min_wait_s
    assert pol.overdue(2.1)
    pol2 = SpeculationPolicy(min_wait_s=0.1, latency_factor=4.0)
    for w in (1.0, 1.0, 1.0):
        pol2.observe(w)
    assert not pol2.overdue(3.9)  # 4 x median governs
    assert pol2.overdue(4.1)
    snap = pol2.snapshot()
    assert snap["median_wall_s"] == 1.0 and snap["issued"] == 0


# ---------------------------------------------------------------------------
# Observability plumbing
# ---------------------------------------------------------------------------

def test_census_carries_resilience_counters(store):
    cen = rc.census()
    for key in ("quarantined", "quarantine_files", "serve_failovers",
                "speculated", "faults_injected", "worker_retries"):
        assert key in cen, key
    faults.install(faults.FaultPlan([{"kind": "delay_socket"}]))
    faults.plan().check("delay_socket")
    assert rc.census()["faults_injected"] == {"delay_socket": 1}


def test_sweep_rows_carry_resilience_record(store):
    from repro.dataflow.schedule import sweep_schedule

    class _Sched:
        channel_bytes = 4

        def sim_stages(self, traces=None, **kw):
            return pipeline(2000)

    res = sweep_schedule(_Sched(), n_iters=2000, mems={"ACP": acp},
                         fifo_depths=(8,))
    for row in res.rows:
        assert row["resilience"] == {"worker_retries": 0,
                                     "quarantined": 0,
                                     "serve_failovers": 0}


def test_daemon_stats_report_faults_and_journal(store):
    with daemon(journal=True) as d:
        st = d.stats()
    assert st["journal"]["enabled"] is True
    assert st["journal"]["restarts"] == 0
    assert "faults_injected" in st
    assert st["speculation"] is not None  # default policy armed
    with daemon(journal=False, speculate_after_s=0) as d:
        st = d.stats()
    assert st["journal"]["enabled"] is False
    assert st["speculation"] is None

"""Shared test fixtures."""

import pytest


@pytest.fixture(autouse=True)
def _isolated_rescache(tmp_path_factory, monkeypatch):
    """Point the resolution cache at a per-session temp directory so test
    runs never read stale artifacts from (or write into) the repo's
    ``experiments/.rescache``.  Tests that need specific cache behaviour
    (tests/test_rescache.py) reconfigure it themselves."""
    from repro.core import rescache as rc
    d = tmp_path_factory.getbasetemp() / "rescache"
    monkeypatch.setattr(rc._cfg, "directory", str(d))
    yield

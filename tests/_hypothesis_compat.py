"""Optional-hypothesis shim: property tests degrade to skips, not errors.

``hypothesis`` is listed in requirements.txt but is not guaranteed to be
present (the hermetic test container installs nothing).  Importing this
module instead of ``hypothesis`` directly keeps the deterministic tests in a
module runnable: when hypothesis is missing, ``@given`` turns the test into
a ``pytest.importorskip("hypothesis")`` skip and the strategy combinators
become inert stubs so module-level ``st.*`` expressions still evaluate.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any strategy construction (st.lists(st.integers(...)))."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            # deliberately NOT functools.wraps: the runner must expose a
            # zero-arg signature or pytest treats @given params as fixtures
            def runner():
                pytest.importorskip("hypothesis")
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py.

Tolerances: fp32 kernels accumulate in fp32 but tile order differs from the
oracle's single contraction, so rtol ~1e-4; bf16 inputs get looser bounds.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # noqa: E402 — skips when hypothesis is missing

from repro.kernels import (csr_to_bsr, decode_attention, flash_attention,
                           matmul, ref, rmsnorm, spmv)

_RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = _RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [
    (128, 256, 128),      # aligned
    (100, 300, 200),      # unaligned → padding path
    (8, 128, 128),        # minimal tile
    (257, 129, 511),      # prime-ish everything
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(M, K, N, dtype):
    x = _rand((M, K), dtype)
    w = _rand((K, N), dtype)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    rtol, atol = (2e-5, 3e-4) if dtype == jnp.float32 else (2e-2, 2e-1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


def test_matmul_out_dtype():
    x = _rand((64, 128), jnp.bfloat16)
    w = _rand((128, 64), jnp.bfloat16)
    out = matmul(x, w, out_dtype=jnp.float32)
    assert out.dtype == jnp.float32


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (1, 2, 2, 64, 32),    # MHA
    (2, 4, 2, 64, 32),    # GQA group 2
    (1, 8, 1, 128, 64),   # MQA
    (1, 2, 2, 100, 32),   # ragged seq → padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(B, Hq, Hkv, S, d, dtype):
    q = _rand((B, Hq, S, d), dtype)
    k = _rand((B, Hkv, S, d), dtype)
    v = _rand((B, Hkv, S, d), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    kr = jnp.repeat(k, Hq // Hkv, axis=1)
    vr = jnp.repeat(v, Hq // Hkv, axis=1)
    want = ref.flash_attention_ref(q, kr, vr, causal=True)
    rtol, atol = (3e-5, 3e-5) if dtype == jnp.float32 else (2e-2, 2e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


def test_flash_noncausal():
    q = _rand((1, 2, 64, 32), jnp.float32)
    k = _rand((1, 2, 64, 32), jnp.float32)
    v = _rand((1, 2, 64, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention (ragged lengths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (2, 4, 2, 128, 32),
    (1, 8, 8, 256, 64),
    (3, 4, 1, 96, 32),    # unaligned cache length
])
def test_decode_sweep(B, Hq, Hkv, S, d):
    q = _rand((B, Hq, d), jnp.float32)
    kc = _rand((B, Hkv, S, d), jnp.float32)
    vc = _rand((B, Hkv, S, d), jnp.float32)
    lengths = jnp.asarray(_RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    got = decode_attention(q, kc, vc, lengths, block_s=32)
    want = ref.decode_attention_ref(
        q, jnp.repeat(kc, Hq // Hkv, 1), jnp.repeat(vc, Hq // Hkv, 1),
        lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_decode_matches_prefill_last_token():
    """Cross-validation: decode(q_last) == prefill(full)[:, :, -1]."""
    B, H, S, d = 1, 2, 64, 32
    q = _rand((B, H, S, d), jnp.float32)
    k = _rand((B, H, S, d), jnp.float32)
    v = _rand((B, H, S, d), jnp.float32)
    full = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    lengths = jnp.full((B,), S, jnp.int32)
    dec = decode_attention(q[:, :, -1, :], k, v, lengths, block_s=16)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, :, -1, :]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 128), (1, 1, 1, 256),
                                   (5, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(shape, dtype)
    w = _rand(shape[-1:], jnp.float32)
    got = rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


# ---------------------------------------------------------------------------
# spmv
# ---------------------------------------------------------------------------

def _random_csr(M, K, density, seed=0):
    rng = np.random.default_rng(seed)
    dense = ((rng.random((M, K)) < density)
             * rng.normal(size=(M, K))).astype(np.float32)
    indptr = [0]
    indices: list[int] = []
    data: list[float] = []
    for r in range(M):
        nz = np.nonzero(dense[r])[0]
        indices.extend(nz.tolist())
        data.extend(dense[r, nz].tolist())
        indptr.append(len(indices))
    return (dense, np.asarray(indptr), np.asarray(indices),
            np.asarray(data, np.float32))


@pytest.mark.parametrize("M,K,density", [
    (64, 256, 0.25),     # the paper's density
    (64, 256, 0.02),     # very sparse
    (16, 128, 0.9),      # nearly dense
])
def test_spmv_sweep(M, K, density):
    dense, indptr, indices, data = _random_csr(M, K, density)
    vals, cols = csr_to_bsr(indptr, indices, data, (M, K), bm=8, bk=128)
    x = jnp.asarray(_RNG.normal(size=(K,)).astype(np.float32))
    got = spmv(jnp.asarray(vals), jnp.asarray(cols), x)
    np.testing.assert_allclose(np.asarray(got)[:M],
                               dense @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)
    # kernel == oracle
    want = ref.spmv_bsr_ref(jnp.asarray(vals), jnp.asarray(cols), x, M)
    np.testing.assert_allclose(np.asarray(got)[:M], np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=10, deadline=None)
def test_spmv_property_blocked(nbr, nnz, seed):
    """Property: for any BSR structure, kernel == einsum oracle."""
    rng = np.random.default_rng(seed)
    bm, bk = 8, 128
    nbc = nnz + 1
    vals = rng.normal(size=(nbr, nnz, bm, bk)).astype(np.float32)
    cols = rng.integers(-1, nbc, size=(nbr, nnz)).astype(np.int32)
    x = jnp.asarray(rng.normal(size=(nbc * bk,)).astype(np.float32))
    got = spmv(jnp.asarray(vals), jnp.asarray(cols), x)
    want = ref.spmv_bsr_ref(jnp.asarray(vals), jnp.asarray(cols), x,
                            nbr * bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decoupled_gather — the explicit access/execute kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,R,D", [(8, 32, 128), (16, 64, 128),
                                   (5, 7, 256)])
def test_decoupled_gather_sweep(N, R, D):
    from repro.kernels.decoupled_gather import (decoupled_gather,
                                                decoupled_gather_ref)
    table = _rand((R, D), jnp.float32)
    idx = jnp.asarray(_RNG.integers(0, R, N), jnp.int32)
    got = decoupled_gather(idx, table, interpret=True)
    want = decoupled_gather_ref(idx, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_decoupled_gather_repeated_indices():
    """Ring-buffer correctness when the same row is fetched back-to-back."""
    from repro.kernels.decoupled_gather import (decoupled_gather,
                                                decoupled_gather_ref)
    table = _rand((16, 128), jnp.float32)
    idx = jnp.asarray([3, 3, 3, 5, 3, 5, 5, 0], jnp.int32)
    got = decoupled_gather(idx, table, interpret=True)
    want = decoupled_gather_ref(idx, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)

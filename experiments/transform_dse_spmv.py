"""Acceptance run for the transformation catalog: on spmv at the full
Table-I iteration count (4 194 304), the transform-widened
``Compiled.explore`` front must contain a transformed candidate that
strictly dominates the best untransformed point (fewer cycles at
equal-or-lower FIFO bits), with its cycle count verified bit-identical
to a fresh cold per-candidate simulation and cycle-exact against the
scalar ``reference=True`` engine.

Writes ``experiments/transform_dse_spmv.json``.  ``--quick`` truncates
the scalar-reference check (O(tokens) Python loop) to 65 536 tokens;
the default verifies the reference at the candidate's full token
count.

Run:  PYTHONPATH=src python -m experiments.transform_dse_spmv [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.paper_fig5 import MAX_OUTSTANDING, _make_kernel
from repro.core.simulator import simulate_dataflow, standard_memory_models
from repro.dataflow import TransformConfig, compile as dataflow_compile
from repro.dataflow.dse import (compiled_with_plan, sim_stages_for_partition,
                                traces_by_node)
from repro.dataflow.schedule import _cyclic_nodes
from repro.dataflow.transforms import transform_node_traces

OUT = os.path.join(os.path.dirname(__file__), "transform_dse_spmv.json")
FIFO_DEPTH = 256


def main() -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="truncate the scalar-reference check to 65536 "
                         "tokens")
    ap.add_argument("--max-candidates", type=int, default=6)
    a, _ = ap.parse_known_args()

    k = _make_kernel("spmv")
    n = k.n_iters_full
    compiled = dataflow_compile(
        k.loop_body, k.carry_example, *k.body_args, loop=True,
        nonaliasing_carries=getattr(k, "nonaliasing_carries", ()))
    models = standard_memory_models()
    mem = models["ACP"]()
    mem.max_outstanding = MAX_OUTSTANDING
    mem64 = models["ACP+64KB"]()
    mem64.max_outstanding = MAX_OUTSTANDING

    t0 = time.perf_counter()
    res = compiled.explore(
        n_iters=n, traces=list(k.full_traces.values()), mem=mem,
        mems=[mem, mem64],
        fifo_depth=FIFO_DEPTH,
        fifo_depths=[FIFO_DEPTH, FIFO_DEPTH // 2],
        transforms=[TransformConfig(unroll=2),
                    TransformConfig(unroll=2, coalesce=True)],
        max_candidates=a.max_candidates)
    explore_s = time.perf_counter() - t0
    print(res.summary())
    assert res.transformed_dominates(), \
        "no transformed candidate dominates the untransformed front"

    # locate, per memory model, the dominating pair the probe found
    payload: dict = {"n_iters": n, "fifo_depths": [FIFO_DEPTH,
                                                   FIFO_DEPTH // 2],
                     "max_candidates": a.max_candidates,
                     "explore_wall_s": explore_s,
                     "transforms": list(res.transforms),
                     "transformed_dominates": True,
                     "dse": res.to_json(), "verification": {}}
    nt = traces_by_node(compiled.cdfg, compiled.partition,
                        list(k.full_traces.values()), n_iters=n)
    cyc_mem = {x for x in _cyclic_nodes(compiled.cdfg)
               if compiled.cdfg.node(x).is_memory}
    mems = {m.name: m for m in (mem, mem64)}

    for mn in res.mem_names:
        ev = [c for c in res.candidates if c.mem_name == mn
              and c.cycles is not None and c.pruned is None]
        base_sig = res.baseline.transform
        untf = [c for c in ev if c.transform == base_sig]
        u = min(untf, key=lambda c: (c.cycles, c.fifo_bits))
        doms = [c for c in ev if c.transform != base_sig
                and c.cycles < u.cycles and c.fifo_bits <= u.fifo_bits]
        if not doms:
            continue
        t = min(doms, key=lambda c: (c.cycles, c.fifo_bits))
        print(f"[{mn}] best untransformed: {u.cycles} cycles @ "
              f"{u.fifo_bits} bits ({'/'.join(u.moves) or 'base'})")
        print(f"[{mn}] dominating transformed: {t.cycles} cycles @ "
              f"{t.fifo_bits} bits ({'/'.join(t.moves)}), "
              f"{u.cycles / t.cycles:.2f}x fewer cycles")

        # fresh cold per-candidate simulation — bit-identity
        if t.compiled is None:   # off-front dominator: rebuild artifact
            t.compiled = compiled_with_plan(compiled, t.plan,
                                            t.duplicate, t.tf)
        tf_nt = transform_node_traces(nt, t.tf, serialized_nodes=cyc_mem)
        stages = sim_stages_for_partition(t.compiled.partition, tf_nt,
                                          cyc_mem)
        cold = simulate_dataflow(stages, mems[mn], t.n_tokens,
                                 fifo_depth=t.fifo_depth,
                                 use_rescache=False)
        assert cold.cycles == t.cycles, (cold.cycles, t.cycles)

        # scalar reference — cycle-exact (O(tokens) Python loop)
        n_ref = min(t.n_tokens, 1 << 16) if a.quick else t.n_tokens
        tr0 = time.perf_counter()
        ref = simulate_dataflow(stages, mems[mn], n_ref,
                                fifo_depth=t.fifo_depth, reference=True)
        ref_s = time.perf_counter() - tr0
        if n_ref == t.n_tokens:
            assert ref.cycles == t.cycles, (ref.cycles, t.cycles)
        else:
            vec = simulate_dataflow(stages, mems[mn], n_ref,
                                    fifo_depth=t.fifo_depth,
                                    use_rescache=False)
            assert ref.cycles == vec.cycles, (ref.cycles, vec.cycles)
        print(f"[{mn}] verified: cold bit-identical at {t.n_tokens} "
              f"tokens; scalar reference cycle-exact at {n_ref} tokens "
              f"({ref_s:.1f}s)")
        payload["verification"][mn] = {
            "best_untransformed": u.to_json(),
            "dominating_transformed": t.to_json(),
            "cycles_ratio": u.cycles / t.cycles,
            "cold_bit_identical": True,
            "reference_tokens": n_ref,
            "reference_cycle_exact": True,
            "reference_wall_s": ref_s,
        }

    assert payload["verification"], "dominating pair not reconstructed"
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"wrote {OUT}")
    return payload


if __name__ == "__main__":
    main()

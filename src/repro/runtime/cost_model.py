"""Analytic per-step cost model: FLOPs, HBM bytes, collective bytes.

Why analytic: XLA's ``cost_analysis()`` counts a ``scan``/``while`` body
ONCE, not × trip-count (verified empirically — a 10-step scanned matmul
reports exactly 1/10th of the unrolled flops).  Every production model here
scans its layer stack, so HLO-derived totals undercount by ~num_layers.
The roofline therefore uses this analytic model (the same napkin math the
§Perf methodology demands), with the HLO numbers kept as a structural
cross-check (collective op *kinds/counts* are still read from the HLO).

All quantities are PER CHIP per step.  Ring-collective wire cost:
``2·(n−1)/n·size`` for all-reduce, ``(n−1)/n·size`` for all-gather /
reduce-scatter / all-to-all (uniform).
"""

from __future__ import annotations

import dataclasses

from ..configs.base import InputShape, LayerSpec, ModelConfig, SHAPES

BF16 = 2
F32 = 4


@dataclasses.dataclass
class ShardingAssumptions:
    """The layout the framework's rules produce (see runtime/sharding.py).

    The optional fields are the §Perf hillclimb knobs; each corresponds to
    a code-level feature (see EXPERIMENTS.md §Perf):
      weight_bytes      — serving weight quantization (2 = bf16, 1 = int8)
      kv_bytes          — KV-cache quantization
      a2a_bytes         — MoE dispatch payload dtype (2 = bf16, 1 = fp8)
      k_eff             — device-limited routing: expected distinct target
                          devices per token (DeepSeek node-limited routing);
                          0 = use top_k
      seq_parallel      — sequence-parallel norms: TP all-reduce becomes
                          reduce-scatter + all-gather (≈ half wire bytes)
      ep_serve          — decode-time expert placement over ALL chips:
                          weights stay resident, only activations move
    """
    dp: int                  # batch/FSDP ways (pod × data)
    tp: int                  # tensor/expert-parallel ways (model axis)
    fsdp_params: bool = True      # ZeRO-3 over dp (train) / 2-D serve
    remat: bool = False           # activation checkpointing (off: store all)
    dtype_bytes: int = BF16
    weight_bytes: int = BF16
    kv_bytes: int = BF16
    a2a_bytes: int = BF16
    k_eff: float = 0.0
    seq_parallel: bool = False
    ep_serve: bool = False


@dataclasses.dataclass
class StepCost:
    flops: float             # per chip
    hbm_bytes: float         # per chip
    coll_bytes: float        # per chip, on-wire
    breakdown: dict

    def roofline(self, peak=197e12, bw=819e9, link=50e9) -> dict:
        t_c = self.flops / peak
        t_m = self.hbm_bytes / bw
        t_l = self.coll_bytes / link
        dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))[1]
        return {"t_compute_s": t_c, "t_memory_s": t_m,
                "t_collective_s": t_l, "dominant": dom,
                "bound_s": max(t_c, t_m, t_l)}


def _layer_param_count(cfg: ModelConfig, spec: LayerSpec,
                       active_only: bool) -> int:
    d = cfg.d_model
    p = 0
    if spec.mixer == "attn":
        p += d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        p += cfg.num_heads * cfg.head_dim * d
    elif spec.mixer == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p += d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
        p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        p += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim
                                               + m.v_head_dim)
        p += cfg.num_heads * m.v_head_dim * d
    elif spec.mixer == "mamba":
        s = cfg.ssm
        p += d * 2 * s.d_inner + s.d_inner * (s.dt_rank + 2 * s.d_state)
        p += s.dt_rank * s.d_inner + s.d_inner * d
    elif spec.mixer == "rwkv":
        p += 5 * d * d + 2 * d * cfg.rwkv_decay_lora
    if spec.mlp == "dense":
        p += (3 if cfg.act == "silu" else 2) * d * cfg.d_ff
    elif spec.mlp == "moe":
        m = cfg.moe
        n_e = m.top_k if active_only else m.num_experts
        p += d * m.num_experts  # router
        p += (n_e + m.num_shared) * 3 * d * m.d_ff
    elif spec.mlp == "rwkv_cmix":
        p += 2 * d * int(3.5 * d) + d * d
    return p


def _iter_layers(cfg: ModelConfig):
    for seg in cfg.segments:
        for _ in range(seg.repeats):
            for spec in seg.unit:
                yield spec


def step_cost(cfg: ModelConfig, shape: InputShape | str,
              sh: ShardingAssumptions) -> StepCost:
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    T = B * (1 if decode else S)          # tokens this step (global)
    T_c = T / sh.dp                        # per-chip tokens
    ctx = S if decode else S               # attention context length
    d = cfg.d_model
    dt = sh.dtype_bytes
    fwd_bwd = 3.0 if train else 1.0        # bwd ≈ 2× fwd matmul flops

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    bd: dict = {}

    # ---- per-layer projection flops (≈ 2·tokens·params_active) ------------
    proj_params = sum(_layer_param_count(cfg, spec, active_only=True)
                      for spec in _iter_layers(cfg))
    flops += fwd_bwd * 2 * T_c * (proj_params / sh.tp)
    bd["proj_flops"] = flops

    # ---- attention quadratic + SSM scan flops ------------------------------
    attn_layers = sum(1 for s in _iter_layers(cfg) if s.mixer in
                      ("attn", "mla"))
    ssm_layers = sum(1 for s in _iter_layers(cfg) if s.mixer in
                     ("mamba", "rwkv"))
    hd_qk = (cfg.head_dim if cfg.mla is None
             else cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim)
    hd_v = cfg.head_dim if cfg.mla is None else cfg.mla.v_head_dim
    causal_frac = 0.5 if not decode else 1.0
    qd_flops = (2 * T_c * ctx * causal_frac * cfg.num_heads
                * (hd_qk + hd_v) * attn_layers / sh.tp)
    flops += fwd_bwd * qd_flops
    bd["attn_quadratic_flops"] = fwd_bwd * qd_flops
    if ssm_layers and cfg.ssm is not None:
        s = cfg.ssm
        ssm_flops = 6 * T_c * s.d_inner * s.d_state * ssm_layers / sh.tp
        flops += fwd_bwd * ssm_flops
    if ssm_layers and cfg.rwkv_heads:
        hd = d // cfg.rwkv_heads
        flops += fwd_bwd * 4 * T_c * d * hd * ssm_layers / sh.tp

    # ---- logits -------------------------------------------------------------
    logit_flops = 2 * T_c * d * cfg.vocab_size / sh.tp
    flops += fwd_bwd * logit_flops
    bd["logit_flops"] = fwd_bwd * logit_flops

    # ---- HBM bytes ----------------------------------------------------------
    n_params = cfg.param_count()
    param_shards = sh.dp * sh.tp if sh.fsdp_params else sh.tp
    params_chip = n_params * sh.weight_bytes / param_shards
    if train:
        # fwd read + bwd read + grad write (bf16) + m/v read+write (f32×2×2)
        hbm += params_chip * 3 + (n_params / (sh.dp * sh.tp)) * F32 * 4
        hbm += params_chip  # param write
    elif sh.ep_serve:
        # experts resident on their home chip (sharded over ALL chips);
        # only the touched expert rows + non-expert shard stream per step
        hbm += n_params * sh.weight_bytes / (sh.dp * sh.tp)
    elif sh.fsdp_params:
        # 2-D serve: read own shard + write/read the gathered remainder
        gathered = n_params * sh.weight_bytes / sh.tp - params_chip
        hbm += params_chip + 2 * gathered
    else:
        hbm += params_chip  # stream the TP shard once
    bd["param_bytes"] = hbm

    n_layers = cfg.num_layers
    act_traffic = T_c * d * dt * n_layers * (4 if not sh.remat else 6)
    hbm += act_traffic * (2 if train else 1)
    bd["act_bytes"] = act_traffic

    if decode:
        # KV-cache read per step (the dominant stream)
        cache_bytes = 0.0
        for spec in _iter_layers(cfg):
            if spec.mixer == "attn":
                cache_bytes += (2 * cfg.num_kv_heads * cfg.head_dim
                                * ctx * B * sh.kv_bytes)
            elif spec.mixer == "mla":
                m = cfg.mla
                cache_bytes += ((m.kv_lora_rank + m.qk_rope_head_dim)
                                * ctx * B * sh.kv_bytes)
            elif spec.mixer == "mamba":
                cache_bytes += (cfg.ssm.d_inner * cfg.ssm.d_state * B * F32)
            elif spec.mixer == "rwkv":
                hd = d // cfg.rwkv_heads
                cache_bytes += cfg.rwkv_heads * hd * hd * B * F32
        hbm += cache_bytes / (sh.dp * sh.tp)
        bd["cache_bytes_chip"] = cache_bytes / (sh.dp * sh.tp)
    elif cfg.moe is None or True:
        # prefill/train logits materialization
        hbm += T_c * cfg.vocab_size * F32 / sh.tp
        bd["logit_bytes"] = T_c * cfg.vocab_size * F32 / sh.tp

    # ---- collectives --------------------------------------------------------
    tp, dp = sh.tp, sh.dp
    if tp > 1:
        # Megatron: 2 activation all-reduces per layer fwd (+2 bwd);
        # sequence-parallel replaces each AR with RS+AG (half wire bytes)
        ar = 2 * (tp - 1) / tp * (T_c * d * dt)
        if sh.seq_parallel:
            ar = ar / 2
        n_ar = 2 * n_layers * (2 if train else 1)
        coll += n_ar * ar
        bd["tp_allreduce_bytes"] = n_ar * ar
    if cfg.moe is not None:
        # EP all-to-all dispatch+combine (fwd [+bwd]).  Payload dtype and
        # device-limited routing both shrink wire bytes.
        k_wire = sh.k_eff if sh.k_eff > 0 else float(cfg.moe.top_k)
        ep_ways = (dp * tp) if sh.ep_serve else tp
        a2a = 2 * (ep_ways - 1) / ep_ways * (T_c * d * sh.a2a_bytes) * k_wire
        n_moe = sum(1 for s in _iter_layers(cfg) if s.mlp == "moe")
        coll += n_moe * a2a * (2 if train else 1)
        bd["moe_a2a_bytes"] = n_moe * a2a * (2 if train else 1)
    if train and dp > 1:
        # ZeRO-3: AG params fwd + AG bwd + RS grads; ring wire bytes per
        # chip = 3 · (dp−1)/dp · local_shard, local_shard = params·dt/(dp·tp)
        fsdp = 3 * (dp - 1) / dp * (n_params * dt / (tp * dp))
        coll += fsdp
        bd["fsdp_bytes"] = fsdp
    if (not train) and sh.fsdp_params and not sh.ep_serve:
        # 2-D serve layout must gather the dp-sharded weights each step
        ag = (dp - 1) / dp * (n_params * sh.weight_bytes / (dp * tp)) * dp
        coll += ag
        bd["serve_weight_ag_bytes"] = ag

    return StepCost(flops, hbm, coll, bd)


def cost_for_cell(cfg: ModelConfig, shape: InputShape | str,
                  *, n_pods: int = 1, remat: bool = False,
                  serve_policy: str | None = None) -> StepCost:
    """Cost under the framework's default sharding for the standard mesh."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    dp = 16 * n_pods
    tp = 16
    train = shape.kind == "train"
    if serve_policy is None:
        pbytes = cfg.param_count() * BF16
        serve_policy = ("2d" if pbytes / tp > 0.5 * 16 * 2**30 else "tp")
    # batch must actually shard dp ways; clamp for tiny batches (long_500k)
    eff_dp = min(dp, shape.global_batch) if shape.kind != "train" else dp
    eff_dp = max(1, eff_dp)
    sh = ShardingAssumptions(
        dp=eff_dp, tp=tp,
        fsdp_params=(True if train else serve_policy == "2d"),
        remat=remat)
    return step_cost(cfg, shape, sh)

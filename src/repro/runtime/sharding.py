"""Sharding rules: logical param/activation axes → PartitionSpecs.

The production mesh is fixed — ``(data, model)`` in-pod, ``(pod, data,
model)`` across pods — and ten very different architectures must lower on
it.  Rules are therefore *adaptive*: each rule states a preference list of
mesh axes per tensor dimension, and :func:`safe_spec` keeps an axis only if
it divides the dimension (and is not already used), falling back to
replication otherwise.  This is what lets smollm's 9 heads, DeepSeek's 256
experts and Command-R's 256k vocab share one code path.

Layout summary (train):
  * 2-D weight sharding: FSDP over ``data`` on one dim + Megatron TP over
    ``model`` on the other (column-parallel in-proj, row-parallel out-proj).
  * experts: EP over ``model`` on the expert dim + FSDP over ``data``.
  * activations: batch over (``pod``, ``data``); MoE/FFN internals over
    ``model``; gradients psum over (``pod``, ``data``) automatically.
Serve:
  * weights TP-only when a model-shard fits HBM, 2-D otherwise
    (:func:`serve_weight_policy`); KV caches shard over batch + heads (or
    sequence when head count doesn't divide the axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# v5e hardware constants (also used by the roofline)
HBM_BYTES_PER_CHIP = 16 * 2**30
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9


import contextvars

#: when set (by the launcher) to the data-parallel axis names, model code
#: applies sequence-parallel activation constraints (§Perf B3): residual
#: activations shard (batch→dp, seq→model) between blocks, so GSPMD turns
#: each TP all-reduce into reduce-scatter + all-gather (≈half wire bytes).
_SP_AXES: contextvars.ContextVar = contextvars.ContextVar(
    "sp_axes", default=None)


def sequence_parallel_axes():
    return _SP_AXES.get()


class sequence_parallel:
    """Context manager enabling SP constraints during tracing/lowering."""

    def __init__(self, dp_axes=("data",), tp_axis="model"):
        self.value = (tuple(dp_axes), tp_axis)

    def __enter__(self):
        self._token = _SP_AXES.set(self.value)
        return self

    def __exit__(self, *exc):
        _SP_AXES.reset(self._token)
        return False


def sp_constrain(x):
    """Apply the sequence-parallel residual constraint if enabled."""
    axes = _SP_AXES.get()
    if axes is None or x.ndim != 3:
        return x
    dp_axes, tp = axes
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return jax.lax.with_sharding_constraint(
        x, P(dp, tp, None))


def axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def safe_spec(mesh: Mesh, shape: Sequence[int],
              prefs: Sequence[Any]) -> P:
    """Build a PartitionSpec keeping only divisible, unused axes.

    ``prefs[i]`` is an axis name, a tuple of axis names, a list of
    *candidate* axes (first that fits wins), or None.
    """
    used: set[str] = set()
    out: list[Any] = []
    for dim, pref in zip(shape, list(prefs) + [None] * (len(shape)
                                                        - len(prefs))):
        cands = pref if isinstance(pref, list) else [pref]
        chosen = None
        for cand in cands:
            if cand is None:
                continue
            names = cand if isinstance(cand, tuple) else (cand,)
            if any(n in used for n in names):
                continue
            if all(n in mesh.shape for n in names) and dim % axis_size(
                    mesh, cand) == 0 and axis_size(mesh, cand) > 1:
                chosen = cand
                used.update(names)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical roles of the physical mesh axes."""
    dp: Any = ("data",)          # batch / FSDP axes (may include "pod")
    tp: str = "model"            # tensor/expert-parallel axis

    @property
    def dp_spec(self):
        return tuple(self.dp) if len(self.dp) > 1 else self.dp[0]


def mesh_axes_for(mesh: Mesh) -> MeshAxes:
    if "pod" in mesh.shape:
        return MeshAxes(dp=("pod", "data"), tp="model")
    return MeshAxes(dp=("data",), tp="model")


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# name-keyed rules: map the LAST path component to (dim prefs), where
# "IN" = FSDP axis (data), "OUT" = TP axis (model).  Stacked segment params
# get a leading None (the scan/repeats dim) automatically.
_COL = ("IN", "OUT")     # column-parallel: (d_in, d_out·TP)
_ROW = ("OUT", "IN")     # row-parallel:    (d_in·TP, d_out)

_PARAM_RULES: dict[str, tuple] = {
    # embeddings: vocab over TP, features over FSDP
    "table": ("OUT", "IN"),
    # attention
    "w_q": _COL, "w_k": _COL, "w_v": _COL, "w_o": _ROW,
    "b_q": ("OUT",), "b_k": ("OUT",), "b_v": ("OUT",),
    # MLA
    "w_dq": _COL, "w_uq": _COL, "w_dkv": _COL, "w_ukv": _COL,
    # MLP
    "w_up": _COL, "w_gate": _COL, "w_down": _ROW,
    # MoE (leading expert dim handled by shape: 3-D tensors)
    "router": ("IN", None),
    # Mamba
    "w_in": _COL, "w_x": _COL, "w_dt": ("IN", "OUT"), "w_out": _ROW,
    "conv_w": (None, "OUT"), "conv_b": ("OUT",),
    "A_log": ("OUT", None), "D": ("OUT",), "dt_bias": ("OUT",),
    # RWKV
    "w_r": _COL, "w_g": _COL, "decay_A": _COL, "decay_B": _ROW,
    "decay_w0": ("OUT",), "bonus_u": (None, None),
    "mu_r": (), "mu_k": (), "mu_v": (), "mu_w": (), "mu_g": (),
    # misc
    "proj": _COL,
    "scale": (), "bias": (),
}


def _resolve(pref, axes: MeshAxes):
    if pref == "IN":
        return [axes.dp_spec, None]
    if pref == "OUT":
        return [axes.tp, None]
    return [pref]


def param_pspec(mesh: Mesh, path: tuple, leaf: Any,
                axes: MeshAxes | None = None) -> P:
    """PartitionSpec for one parameter leaf given its tree path."""
    axes = axes or mesh_axes_for(mesh)
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    last = names[-1] if names else ""
    shape = tuple(leaf.shape)
    stacked = any(n.startswith("segment_") for n in names)

    rule = _PARAM_RULES.get(last)
    if rule is None:
        return P()  # replicate unknowns (safe default)

    shape_core = shape[1:] if stacked else shape
    # MoE expert tensors: 3-D (E, in, out) — expert-parallel on dim 0
    if len(shape_core) == 3 and last in ("w_gate", "w_up", "w_down"):
        prefs = [[axes.tp, None], [axes.dp_spec, None], [None]]
    else:
        prefs = [_resolve(p, axes) for p in rule[:len(shape_core)]]
    spec = safe_spec(mesh, shape_core, prefs)
    if stacked:
        spec = P(None, *spec)
    return spec


def params_shardings(mesh: Mesh, params: Any,
                     axes: MeshAxes | None = None) -> Any:
    axes = axes or mesh_axes_for(mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat
    out = [NamedSharding(mesh, param_pspec(mesh, path, leaf, axes))
           for path, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, shape: Sequence[int],
                axes: MeshAxes | None = None) -> P:
    """Token batches: batch dim over (pod, data); seq dim over model if the
    batch doesn't shard (long-context, batch=1)."""
    axes = axes or mesh_axes_for(mesh)
    ndim = len(shape)
    if ndim == 0:
        return P()
    prefs: list = [[axes.dp_spec, axes.dp[-1], None]]
    if ndim >= 2:
        prefs.append([None])
    return safe_spec(mesh, shape, prefs)


def cache_pspec(mesh: Mesh, path: tuple, leaf: Any,
                axes: MeshAxes | None = None) -> P:
    """KV/state caches.  Dim heuristics by tensor rank and name."""
    axes = axes or mesh_axes_for(mesh)
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    last = names[-1] if names else ""
    shape = tuple(leaf.shape)
    stacked = any(n.startswith("segment_") for n in names)
    core = shape[1:] if stacked else shape
    dp = [axes.dp_spec, axes.dp[-1], None]

    if last in ("k", "v") and len(core) == 4:        # (B, Hkv, S, hd)
        prefs = [dp, [axes.tp, None], [axes.tp, None], [None]]
    elif last in ("c_kv", "k_pe") and len(core) == 3:  # (B, S, r)
        prefs = [dp, [axes.tp, None], [None]]
    elif last == "h" and len(core) == 3:             # (B, dI, N)
        prefs = [dp, [axes.tp, None], [None]]
    elif last == "conv" and len(core) == 3:          # (B, K-1, dI)
        prefs = [dp, [None], [axes.tp, None]]
    elif last == "S" and len(core) == 4:             # (B, H, hd, hd)
        prefs = [dp, [axes.tp, None], [None], [None]]
    else:
        prefs = [dp] + [[None]] * (len(core) - 1)
    spec = safe_spec(mesh, core, prefs)
    if stacked:
        spec = P(None, *spec)
    return spec


def tree_shardings(mesh: Mesh, tree: Any, spec_fn) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [NamedSharding(mesh, spec_fn(mesh, path, leaf))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Serving weight policy
# ---------------------------------------------------------------------------

def serve_weight_policy(param_bytes: int, mesh: Mesh,
                        *, budget_frac: float = 0.5) -> str:
    """"tp" when one TP shard of the weights fits comfortably in HBM
    (no per-step weight gathering at decode), else "2d" (FSDP+TP)."""
    tp = mesh.shape.get("model", 1)
    if param_bytes / tp <= budget_frac * HBM_BYTES_PER_CHIP:
        return "tp"
    return "2d"


def params_shardings_serve(mesh: Mesh, params: Any, param_bytes: int,
                           *, ep_serve: bool = False) -> Any:
    """Serving layouts.

    * ``tp``  — weights sharded over ``model`` only (small models): no
      per-step weight movement.
    * ``2d``  — FSDP+TP (big models): fits, but gathers weights each step.
    * ``ep_serve`` (§Perf) — expert tensors sharded over ALL chips
      (``data × model`` on the expert dim): weights stay resident and only
      token activations cross the wire — the paper's "customize the memory
      interface per region" applied to expert weights.
    """
    policy = serve_weight_policy(param_bytes, mesh)
    axes = mesh_axes_for(mesh)
    tp_axes = MeshAxes(dp=("_none_",), tp=axes.tp)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        names = [str(getattr(p, "key", getattr(p, "name", p)))
                 for p in path]
        last = names[-1] if names else ""
        stacked = any(n.startswith("segment_") for n in names)
        is_expert = (last in ("w_gate", "w_up", "w_down")
                     and leaf.ndim - (1 if stacked else 0) == 3)
        if ep_serve and is_expert:
            all_axes = tuple(a for a in ("pod", "data", "model")
                             if a in mesh.shape)
            core = leaf.shape[1:] if stacked else leaf.shape
            spec = safe_spec(mesh, core,
                             [[all_axes, axes.tp], [None], [None]])
            if stacked:
                spec = P(None, *spec)
            out.append(NamedSharding(mesh, spec))
            continue
        if policy == "2d" and not (ep_serve and is_expert):
            spec = param_pspec(mesh, path, leaf, axes)
        else:
            spec = param_pspec(mesh, path, leaf, tp_axes)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)

"""Distributed runtime: sharding rules + fault tolerance."""

from . import fault_tolerance, sharding

__all__ = ["fault_tolerance", "sharding"]

"""Fault tolerance: retrying step guard, straggler policy, elastic restore.

At thousands of nodes, the framework must assume: (a) steps fail
(preemption, ICI link flap, host OOM) — recover from the last checkpoint
without operator action; (b) data hosts straggle — never let one slow
producer stall the whole step (bounded staleness); (c) the incoming pod
count can change — restore onto a different mesh (the checkpointer re-shards).

The guards are deliberately framework-level (pure Python around the jitted
step): device-side failures surface as exceptions from the runtime, which is
exactly the boundary where recovery must happen.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator

log = logging.getLogger("repro.ft")


class StepFailure(RuntimeError):
    """Raised by failure-injection hooks in tests."""


@dataclasses.dataclass
class GuardConfig:
    max_retries: int = 3
    #: called as restore() -> (state, step) after a failure
    restore_fn: Callable | None = None
    #: test hook: fail_at(step) -> bool injects a failure before the step
    fail_at: Callable[[int], bool] | None = None


class StepGuard:
    """Runs the train step with retry-from-checkpoint semantics."""

    def __init__(self, step_fn: Callable, cfg: GuardConfig):
        self.step_fn = step_fn
        self.cfg = cfg
        self.failures = 0
        self.restores = 0

    def run(self, state: Any, batch: dict, step: int) -> tuple[Any, dict]:
        attempts = 0
        while True:
            try:
                if (self.cfg.fail_at is not None
                        and self.cfg.fail_at(step)
                        and attempts == 0):
                    raise StepFailure(f"injected failure at step {step}")
                return self.step_fn(state, batch)
            except (StepFailure, RuntimeError) as e:
                self.failures += 1
                attempts += 1
                if attempts > self.cfg.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring (%d/%d)",
                            step, e, attempts, self.cfg.max_retries)
                if self.cfg.restore_fn is not None:
                    state, _ = self.cfg.restore_fn()
                    self.restores += 1


@dataclasses.dataclass
class StragglerPolicy:
    """Bounded-staleness batch fetch: if the producer exceeds the deadline,
    reuse the previous batch rather than stalling the step (the template's
    backpressure rule applied to the host boundary).  Reuse is counted —
    a persistently slow producer shows up in metrics, not in step time."""

    deadline_s: float = 5.0
    max_consecutive_reuse: int = 3

    def __post_init__(self):
        self.reused = 0
        self._consecutive = 0
        self._last: dict | None = None

    def next_batch(self, source: Iterator[dict]) -> dict:
        t0 = time.monotonic()
        try:
            batch = self._fetch(source, self.deadline_s)
            self._last = batch
            self._consecutive = 0
            return batch
        except TimeoutError:
            if (self._last is None
                    or self._consecutive >= self.max_consecutive_reuse):
                # stalling is now unavoidable — block for real
                batch = next(source)
                self._last = batch
                self._consecutive = 0
                return batch
            self.reused += 1
            self._consecutive += 1
            log.warning("data straggler (> %.1fs); reusing last batch "
                        "(%d consecutive)", time.monotonic() - t0,
                        self._consecutive)
            return self._last

    @staticmethod
    def _fetch(source: Iterator[dict], deadline: float) -> dict:
        """Fetch with a deadline.  HostFIFO exposes occupancy; for plain
        iterators we just call next() (cannot time out portably) unless the
        source provides a non-blocking path."""
        q = getattr(source, "_q", None)
        if q is None:
            return next(source)
        import queue as _queue

        try:
            item = q.get(timeout=deadline)
        except _queue.Empty as e:
            raise TimeoutError from e
        if item is getattr(source, "_SENTINEL", object()):
            raise StopIteration
        return item


@dataclasses.dataclass
class SpeculationPolicy:
    """When to speculatively re-dispatch a straggling chunk — the
    :class:`StragglerPolicy` bounded-staleness rule applied to chunk
    *dispatch* instead of batch *fetch*: rather than reusing stale
    data, a chunk whose wall exceeds ``latency_factor ×`` the observed
    median (floored at ``min_wait_s``) earns a duplicate dispatch on
    another worker.  Resolution is deterministic, so both copies
    produce the same bits; the first commit wins and the loser's
    result is discarded by the master's ordinary duplicate guards —
    speculation can only ever cost wasted work, never correctness.

    ``max_inflight`` bounds concurrent speculative copies (a cluster of
    stragglers must not double the cluster).  ``observe`` feeds
    completed chunk walls; with no samples yet nothing is overdue
    (there is no baseline to call anything slow against)."""

    min_wait_s: float = 5.0
    latency_factor: float = 4.0
    max_inflight: int = 2

    def __post_init__(self):
        self._walls: list[float] = []
        self.issued = 0
        self.wins = 0

    def observe(self, wall_s: float) -> None:
        self._walls.append(float(wall_s))
        del self._walls[:-64]

    def median_wall(self) -> float | None:
        if not self._walls:
            return None
        s = sorted(self._walls)
        return s[len(s) // 2]

    def overdue(self, elapsed_s: float) -> bool:
        med = self.median_wall()
        if med is None:
            return False
        return elapsed_s > max(self.min_wait_s,
                               self.latency_factor * med)

    def snapshot(self) -> dict:
        return {"issued": self.issued, "wins": self.wins,
                "min_wait_s": self.min_wait_s,
                "latency_factor": self.latency_factor,
                "median_wall_s": self.median_wall()}

"""Architecture configs (exact public numbers) + shape registry."""

from .base import (ARCH_IDS, SHAPES, InputShape, LayerSpec, MLAConfig,
                   ModelConfig, MoEConfig, Segment, SSMConfig,
                   cell_is_applicable, load_config, reduced)

__all__ = ["ARCH_IDS", "SHAPES", "InputShape", "LayerSpec", "MLAConfig",
           "ModelConfig", "MoEConfig", "Segment", "SSMConfig",
           "cell_is_applicable", "load_config", "reduced"]

"""Config system: one frozen dataclass per architecture, explicit segments.

A model is a stack of *segments*; each segment is a repeating unit of
layer specs scanned ``repeats`` times (keeps the HLO small and compile
times bounded for 61–72 layer models).  ``LayerSpec`` picks the sequence
mixer (attn / mla / mamba / rwkv) and the MLP kind (dense / moe /
rwkv_cmix) per layer — this is how Jamba's 1:7 interleave, DeepSeek's
first-3-dense and uniform dense archs are all expressed in one model
builder.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

import jax.numpy as jnp

Mixer = Literal["attn", "mla", "mamba", "rwkv"]
MLPKind = Literal["dense", "moe", "rwkv_cmix"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    mlp: MLPKind = "dense"


@dataclasses.dataclass(frozen=True)
class Segment:
    unit: tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.unit) * self.repeats


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_fn: str = "softmax"        # "softmax" | "sigmoid" (DeepSeek-V3)
    normalize_weights: bool = True
    #: §Perf knob: dispatch payload dtype ("bf16" | "int8") — int8 halves
    #: the expert-parallel all-to-all wire bytes
    dispatch_dtype: str = "bf16"
    #: §Perf knob: DeepSeek-style device-limited routing — restrict each
    #: token's experts to the top ``route_device_limit`` expert groups
    #: (groups = EP devices), bounding all-to-all fan-out.  0 = unlimited.
    route_groups: int = 0
    route_device_limit: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_inner: int = 0
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0
    scan_impl: str = "sequential"     # "sequential" | "chunked"
    chunk: int = 16


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // num_heads
    segments: tuple[Segment, ...] = ()
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attention: str = "gqa"            # "gqa" | "mla"
    attn_impl: str = "auto"           # "auto" | "full" | "chunked" | "pallas"
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    act: str = "silu"
    rope_theta: float = 1e4
    parallel_block: bool = False      # Cohere-style attn ∥ mlp
    tie_embeddings: bool = False
    frontend_stub: bool = False       # audio/vlm: inputs are embeddings
    rwkv_heads: int = 0
    rwkv_decay_lora: int = 64
    dtype: str = "bfloat16"
    mtp_depth: int = 0                # DeepSeek multi-token-prediction heads
    source: str = ""                  # citation tag
    # ---- §Perf hillclimb knobs (see EXPERIMENTS.md) -----------------------
    mla_absorbed: bool = False        # absorbed MLA decode (latent-space)
    kv_cache_dtype: str = "bf16"      # "bf16" | "int8" quantized KV cache
    remat: bool = False               # activation checkpointing per layer

    # ---- derived -----------------------------------------------------------

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if not self.segments:
            object.__setattr__(
                self, "segments",
                (Segment(unit=(LayerSpec(),), repeats=self.num_layers),))
        total = sum(s.num_layers for s in self.segments)
        assert total == self.num_layers, (
            f"{self.name}: segments cover {total} != {self.num_layers}")

    @property
    def np_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def subquadratic(self) -> bool:
        """True if decode state does not grow with context (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline math)."""
        return self._param_count_exact()

    def _param_count_exact(self) -> int:
        d = self.d_model
        n = self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d

        def layer_params(spec: LayerSpec) -> int:
            p = 0
            if spec.mixer == "attn":
                p += d * self.num_heads * self.head_dim
                p += 2 * d * self.num_kv_heads * self.head_dim
                p += self.num_heads * self.head_dim * d
            elif spec.mixer == "mla":
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                p += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                p += self.num_heads * m.v_head_dim * d
            elif spec.mixer == "mamba":
                s = self.ssm
                p += d * 2 * s.d_inner
                p += s.d_inner * (s.dt_rank + 2 * s.d_state)
                p += s.dt_rank * s.d_inner + s.d_inner * d
            elif spec.mixer == "rwkv":
                p += 5 * d * d + 2 * d * self.rwkv_decay_lora
            if spec.mlp == "dense":
                p += (3 if self.act == "silu" else 2) * d * self.d_ff
            elif spec.mlp == "moe":
                m = self.moe
                p += d * m.num_experts
                p += m.num_experts * 3 * d * m.d_ff
                p += m.num_shared * 3 * d * m.d_ff
            elif spec.mlp == "rwkv_cmix":
                p += 2 * d * int(3.5 * d) + d * d
            return p

        for seg in self.segments:
            n += seg.repeats * sum(layer_params(s) for s in seg.unit)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self._param_count_exact()
        d = self.d_model
        m = self.moe
        full_expert = m.num_experts * 3 * d * m.d_ff
        active_expert = m.top_k * 3 * d * m.d_ff
        n_moe_layers = sum(
            seg.repeats * sum(1 for s in seg.unit if s.mlp == "moe")
            for seg in self.segments)
        return (self._param_count_exact()
                - n_moe_layers * (full_expert - active_expert))


# ---------------------------------------------------------------------------
# Input shapes (assigned to every LM arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


ARCH_IDS = [
    "jamba-1.5-large-398b",
    "qwen2.5-14b",
    "olmo-1b",
    "smollm-135m",
    "command-r-plus-104b",
    "rwkv6-1.6b",
    "deepseek-v3-671b",
    "llama4-scout-17b-a16e",
    "musicgen-large",
    "chameleon-34b",
]


def load_config(arch: str) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py``'s CONFIG (dashes → underscores)."""
    mod = importlib.import_module(
        f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def cell_is_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k requires sub-quadratic decode state (SSM/hybrid)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def reduced(cfg: ModelConfig, *, d_model: int = 64,
            max_repeats: int = 2) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving the layer structure
    (same segment/unit pattern, same mixer/MLP kinds, fewer repeats and
    tiny widths).  The FULL configs are exercised only via the dry-run."""
    heads = 4
    kv = max(1, heads * cfg.num_kv_heads // cfg.num_heads)
    if cfg.num_kv_heads == cfg.num_heads:
        kv = heads
    new_segments = tuple(
        dataclasses.replace(s, repeats=min(s.repeats, max_repeats))
        for s in cfg.segments)
    num_layers = sum(s.num_layers for s in new_segments)
    changes: dict = dict(
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=2 * d_model,
        vocab_size=256,
        segments=new_segments,
        dtype="float32",
        attn_impl="full",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff=2 * d_model)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16)
        changes["head_dim"] = 16
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_inner=2 * d_model, d_state=8, dt_rank=8)
    if cfg.rwkv_heads:
        changes["rwkv_heads"] = heads
        changes["num_heads"] = heads
        changes["num_kv_heads"] = heads
        changes["rwkv_decay_lora"] = 16
    return dataclasses.replace(cfg, **changes)

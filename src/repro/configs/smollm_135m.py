"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M].

Llama-architecture small model: GQA 9/3, tied embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=1e4,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)

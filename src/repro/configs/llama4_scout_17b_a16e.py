"""Llama-4 Scout 17B-active/16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE 16 experts top-1 + shared expert every layer, GQA 40/8, early-fusion
multimodal (text path only here; vision frontend is out of backbone scope).
"""

from .base import LayerSpec, ModelConfig, MoEConfig, Segment

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    segments=(Segment(unit=(LayerSpec(mixer="attn", mlp="moe"),),
                      repeats=48),),
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192, num_shared=1),
    rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

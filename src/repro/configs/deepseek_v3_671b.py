"""DeepSeek-V3 671B [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

MLA attention (q_lora 1536, kv_lora 512, rope 64), 61 layers with the first
3 dense (d_ff 18432), then MoE: 1 shared + 256 routed experts (d_ff 2048),
top-8, sigmoid router; MTP head depth 1.
"""

from .base import (LayerSpec, MLAConfig, ModelConfig, MoEConfig, Segment)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,            # dense layers
    vocab_size=129280,
    attention="mla",
    segments=(
        Segment(unit=(LayerSpec(mixer="mla", mlp="dense"),), repeats=3),
        Segment(unit=(LayerSpec(mixer="mla", mlp="moe"),), repeats=58),
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff=2048, num_shared=1,
                  router_fn="sigmoid", normalize_weights=True),
    mtp_depth=1,
    rope_theta=1e4,
    source="arXiv:2412.19437; hf",
)

"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus; unverified].

Dense, GQA 96/8, parallel attention+FFN blocks, no bias, 256k vocab.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    parallel_block=True,
    norm="layernorm",
    rope_theta=75e4,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)

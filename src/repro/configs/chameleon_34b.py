"""Chameleon-34B [arXiv:2405.09818; unverified].

Early-fusion mixed-modal decoder; VQ image tokens share the 65536 vocab.
The VQ-GAN image tokenizer is a STUB per the assignment: input_specs
provides precomputed patch/token embeddings for train/prefill.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    frontend_stub=True,
    rope_theta=1e4,
    source="arXiv:2405.09818; unverified",
)

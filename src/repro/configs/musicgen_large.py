"""MusicGen-Large [arXiv:2306.05284; hf:facebook/musicgen-large].

Decoder-only transformer over EnCodec tokens (vocab 2048).  The EnCodec
frontend is a STUB per the assignment: input_specs provides precomputed
frame embeddings for train/prefill; decode operates on codebook token ids.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend_stub=True,
    norm="layernorm",
    act="gelu",
    rope_theta=1e4,
    source="arXiv:2306.05284; hf",
)

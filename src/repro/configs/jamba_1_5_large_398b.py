"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887, 2408.12570; hf].

Hybrid Mamba+attention, 1:7 attn:mamba interleave, MoE every other layer
(16 experts, top-2).  72 layers = 9 repeats of an 8-layer unit with the
attention layer at unit position 4 (the published Jamba block layout).
"""

from .base import LayerSpec, ModelConfig, MoEConfig, Segment, SSMConfig

_D = 8192

_UNIT = tuple(
    LayerSpec(mixer=("attn" if i == 4 else "mamba"),
              mlp=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=_D,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    segments=(Segment(unit=_UNIT, repeats=9),),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    ssm=SSMConfig(d_inner=2 * _D, d_state=16, d_conv=4, dt_rank=_D // 16),
    rope_theta=1e4,
    source="arXiv:2403.19887; hf",
)

"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892; unverified].

Attention-free: data-dependent decay WKV recurrence + channel mix.
d_ff=7168 corresponds to the 3.5x channel-mix hidden size.
"""

from .base import LayerSpec, ModelConfig, Segment

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # wkv heads (head_dim 64)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    segments=(Segment(unit=(LayerSpec(mixer="rwkv", mlp="rwkv_cmix"),),
                      repeats=24),),
    rwkv_heads=32,
    rwkv_decay_lora=64,
    norm="layernorm",
    source="arXiv:2404.05892; unverified",
)

"""OLMo-1B [arXiv:2402.00838; hf:allenai/OLMo-1B].

Dense, MHA (16 heads, kv=16), non-parametric LayerNorm, SwiGLU, no biases.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric_ln",
    rope_theta=1e4,
    source="arXiv:2402.00838; hf",
)

"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (interpret=True on CPU, real lowering on TPU).  No Pallas imports
here — these must stay trivially correct.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(x: jax.Array, w: jax.Array,
               out_dtype: jnp.dtype | None = None) -> jax.Array:
    """Plain matmul with fp32 accumulation."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def spmv_bsr_ref(values: jax.Array, col_ids: jax.Array, x: jax.Array,
                 nrows: int) -> jax.Array:
    """Block-sparse-row SpMV.

    values : (n_block_rows, nnz_blocks, bm, bk) stored blocks
    col_ids: (n_block_rows, nnz_blocks) int32 — block-column of each stored
             block; −1 marks padding blocks (contribute zero).
    x      : (K,) dense vector; K = n_block_cols * bk
    returns: (nrows,) = A @ x with fp32 accumulation.
    """
    nbr, nnz, bm, bk = values.shape
    xb = x.reshape(-1, bk)  # (n_block_cols, bk)
    valid = (col_ids >= 0)
    cols = jnp.where(valid, col_ids, 0)
    gathered = xb[cols]                              # (nbr, nnz, bk)
    gathered = jnp.where(valid[..., None], gathered, 0)
    y = jnp.einsum("rnmk,rnk->rm", values.astype(jnp.float32),
                   gathered.astype(jnp.float32))
    return y.reshape(-1)[:nrows].astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """Multi-head attention oracle.  q,k,v: (B, H, S, d) (same H — GQA
    expansion happens in the wrapper)."""
    *_, Sq, d = q.shape
    Sk = k.shape[-2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, lengths: jax.Array,
                         *, scale: float | None = None) -> jax.Array:
    """Single-token decode attention oracle.

    q       : (B, H, d) — one new query token per sequence
    k_cache : (B, H, S, d), v_cache: (B, H, S, d)
    lengths : (B,) int32 — valid cache length per sequence
    """
    B, H, S, d = k_cache.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", w, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jax.Array, weight: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
            ).astype(x.dtype)

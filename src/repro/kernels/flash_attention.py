"""Streaming attention kernels (prefill + decode) with online softmax.

Attention is the framework's dominant "memory operation" in the paper's
sense: at decode time the KV cache read is a huge, latency-bound HBM stream
feeding a tiny amount of compute.  The template's decoupling maps onto the
Pallas grid pipeline: KV tiles stream HBM→VMEM (access stage, double
buffered) while the VPU/MXU consume the previous tile (execute stage), with
the online-softmax running state (m, l, acc) living in VMEM scratch — the
template's in-stage registers.

GQA is handled in the index maps (kv head = q head // group) so KV tiles
are fetched once per group, not repeated — the paper's "burst" optimization
(§III-B2) applied to head-sharing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_MASK = -0.7 * float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# Prefill (causal, GQA)
# ---------------------------------------------------------------------------

def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                    *, scale: float, causal: bool,
                    block_q: int, block_k: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASK)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qi = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            ki = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(ki <= qi, s, _MASK)
        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked KV blocks: kv block start beyond q block end
        @pl.when(ik * block_k <= iq * block_q + block_q - 1)
        def _():
            _body()
    else:
        _body()

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,     # (B, Hq, Sq, d)
    k: jax.Array,     # (B, Hkv, Sk, d)
    v: jax.Array,     # (B, Hkv, Sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    scale_v = scale if scale is not None else 1.0 / float(np.sqrt(d))

    grid = (B, Hq, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _prefill_kernel, scale=scale_v, causal=causal,
        block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode (one new token against a long KV cache, GQA, ragged lengths)
# ---------------------------------------------------------------------------

def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_s: int):
    b, s = pl.program_id(0), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASK)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    # skip cache blocks entirely beyond the valid length (ragged batch):
    @pl.when(s * block_s < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bs, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bs, d)
        logits = jnp.dot(q, k.T,
                         preferred_element_type=jnp.float32) * scale
        pos = s * block_s + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_s), 1)
        logits = jnp.where(pos < length, logits, _MASK)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention(
    q: jax.Array,        # (B, Hq, d)
    k_cache: jax.Array,  # (B, Hkv, S, d)
    v_cache: jax.Array,  # (B, Hkv, S, d)
    lengths: jax.Array,  # (B,) int32
    *,
    scale: float | None = None,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, d = q.shape
    _, Hkv, S, _ = k_cache.shape
    assert Hq % Hkv == 0 and S % block_s == 0
    group = Hq // Hkv
    scale_v = scale if scale is not None else 1.0 / float(np.sqrt(d))
    q4 = q[:, :, None, :]  # (B, Hq, 1, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, S // block_s),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b, h, s, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b, h, s, L: (b, h // group, s, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b, h, s, L: (b, h // group, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b, h, s, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, scale=scale_v,
                               block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q4, k_cache, v_cache)
    return out[:, :, 0, :]

"""Decoupled gather — the paper's template made EXPLICIT inside one kernel.

Where ``dataflow_matmul`` relies on Pallas's automatic grid pipelining,
this kernel writes the three template roles out by hand, one per §II
concept:

* **access stage**: at grid step *i* the kernel *issues* the async HBM→VMEM
  copy for row ``idx[i+1]`` (the paper's memory stage running ahead,
  "multiple outstanding requests pipelined into the memory subsystem");
* **FIFO channel**: a 2-slot VMEM ring buffer + per-slot DMA semaphores —
  the bounded BRAM queue between the stages (depth 2 = double buffering);
* **execute stage**: waits on *this* slot's semaphore and runs the compute
  on the resident row while the next row is in flight.

The gather row index comes from a scalar-prefetched index array (SMEM), so
the address stream is available ahead of the data stream — exactly the
paper's SpMV structure (index array drives the value fetch).

``fn`` is the per-row compute; the default (tanh scale) stands in for any
long-latency stage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUMemorySpace -> MemorySpace around 0.5; support both.
_ANY = getattr(pltpu, "ANY", None)
if _ANY is None:  # pragma: no cover - newer jax
    _ANY = pltpu.MemorySpace.ANY


def _make_kernel(fn):
    def kernel(idx_ref, table_ref, o_ref, buf_ref, sem_ref):
        i = pl.program_id(0)
        n = pl.num_programs(0)
        slot = i % 2
        nxt = (i + 1) % 2

        # prime the pipeline: first row's DMA issued at step 0
        @pl.when(i == 0)
        def _prime():
            pltpu.make_async_copy(
                table_ref.at[idx_ref[0]], buf_ref.at[0],
                sem_ref.at[0]).start()

        # ACCESS stage: issue next row's DMA (runs ahead of compute)
        @pl.when(i + 1 < n)
        def _prefetch():
            pltpu.make_async_copy(
                table_ref.at[idx_ref[i + 1]], buf_ref.at[nxt],
                sem_ref.at[nxt]).start()

        # FIFO pop: wait for this slot's data
        pltpu.make_async_copy(
            table_ref.at[idx_ref[i]], buf_ref.at[slot],
            sem_ref.at[slot]).wait()

        # EXECUTE stage
        o_ref[...] = fn(buf_ref[slot])[None, :]

    return kernel


@functools.partial(jax.jit, static_argnames=("fn", "interpret"))
def decoupled_gather(
    idx: jax.Array,     # (N,) int32 row indices (the address stream)
    table: jax.Array,   # (R, D) rows in HBM
    *,
    fn=None,
    interpret: bool = False,
) -> jax.Array:
    """out[i] = fn(table[idx[i]]) with explicit access/execute decoupling."""
    if fn is None:
        fn = lambda row: jnp.tanh(row * 2.0)
    N = idx.shape[0]
    D = table.shape[1]
    return pl.pallas_call(
        _make_kernel(fn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(N,),
            in_specs=[pl.BlockSpec(memory_space=_ANY)],
            out_specs=pl.BlockSpec((1, D), lambda i, idx: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((2, D), table.dtype),      # the 2-slot FIFO
                pltpu.SemaphoreType.DMA((2,)),         # per-slot tokens
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def decoupled_gather_ref(idx: jax.Array, table: jax.Array,
                         fn=None) -> jax.Array:
    """Pure-jnp oracle."""
    if fn is None:
        fn = lambda row: jnp.tanh(row * 2.0)
    return jax.vmap(fn)(table[idx])


def _default_row_fn(row):
    return jnp.tanh(row * 2.0)


@functools.lru_cache(maxsize=None)
def _staged_gather(fn, backend):
    from repro.dataflow import dataflow_jit

    def gather_fn(idx, table):
        return jax.vmap(fn)(table[idx])

    return dataflow_jit(gather_fn, stream_argnums=(0,), backend=backend)


def decoupled_gather_staged(idx: jax.Array, table: jax.Array, *,
                            fn=None, backend: str = "sequential"
                            ) -> jax.Array:
    """The same decoupling, derived by the compiler driver instead of
    hand-written Pallas: ``repro.dataflow`` partitions the reference
    computation at the gather (Algorithm 1) and executes it on the chosen
    backend.  Portable fallback for hosts where the TPU kernel can't run;
    bit-identical to :func:`decoupled_gather_ref`.

    The driver wrapper is memoized per (fn, backend) so repeated calls
    skip retracing (``fn`` must therefore be a stable function object)."""
    return _staged_gather(fn or _default_row_fn, backend)(idx, table)

"""Decoupled access/execute matmul — the template inside one TPU kernel.

The paper's pipeline template maps 1:1 onto a Pallas grid pipeline:

* **access stage**: the ``BlockSpec`` index maps describe the HBM→VMEM tile
  streams; Pallas's grid pipeliner issues the DMA for tile *(i, j, k+1)*
  while tile *(i, j, k)* is being consumed — the double-buffered VMEM slots
  are the FIFO channel between the access and execute stages.
* **execute stage**: the MXU contraction over the resident tiles, with an
  fp32 VMEM accumulator (the long-latency stage whose steady consumption
  rate shadows HBM latency — Fig. 2's schedule).

Block shapes are chosen so the working set fits VMEM and the contraction
dims are MXU-aligned (multiples of 128 on the minor axes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    # k == 0: reset the accumulator (new output tile begins)
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # execute stage: MXU contraction of the resident VMEM tiles
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    # last k: write back the fp32 accumulator in the output dtype
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                     "interpret"))
def dataflow_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    """``x @ w`` with fp32 accumulation.  x: (M, K), w: (K, N).

    Shapes must be divisible by the block sizes (the ops.py wrapper pads).
    VMEM working set: bm*bk + bk*bn (inputs, double-buffered by the
    pipeliner) + bm*bn fp32 (accumulator); defaults keep this ≈ 1.2 MB for
    bf16 inputs — well inside the ~16 MB v5e VMEM even with multi-slot
    buffering.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        (M, K, N), (block_m, block_k, block_n))
    out_dtype = out_dtype or x.dtype
    grid = (M // block_m, N // block_n, K // block_k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)

"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU the Pallas lowering runs natively; on any other
backend the kernels execute under ``interpret=True`` (the kernel body is
evaluated in Python/XLA-CPU — bit-accurate semantics, no TPU required).
Wrappers also handle padding to hardware-aligned block shapes and GQA
head-group plumbing so models never see alignment constraints.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import dataflow_matmul as _mm
from . import flash_attention as _fa
from . import rmsnorm as _rn
from . import spmv as _spmv
from . import ref as ref  # re-exported for tests/benchmarks


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def matmul(x: jax.Array, w: jax.Array, *,
           block_m: int = 128, block_n: int = 128, block_k: int = 512,
           out_dtype: jnp.dtype | None = None) -> jax.Array:
    """Padded, decoupled-pipeline matmul; accepts any (M, K) × (K, N)."""
    M, K = x.shape
    _, N = w.shape
    bm = min(block_m, _ceil_mult(M, 8))
    bn = min(block_n, _ceil_mult(N, 128))
    bk = min(block_k, _ceil_mult(K, 128))
    xp, _ = _pad_to(x, bm, 0)
    xp, _ = _pad_to(xp, bk, 1)
    wp, _ = _pad_to(w, bk, 0)
    wp, _ = _pad_to(wp, bn, 1)
    out = _mm.dataflow_matmul(xp, wp, block_m=bm, block_n=bn, block_k=bk,
                              out_dtype=out_dtype, interpret=_interpret())
    return out[:M, :N]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """(B, Hq, Sq, d) × (B, Hkv, Sk, d)² → (B, Hq, Sq, d), GQA-aware."""
    B, Hq, Sq, d = q.shape
    Sk = k.shape[2]
    bq = min(block_q, _ceil_mult(Sq, 8))
    bk = min(block_k, _ceil_mult(Sk, 8))
    qp, _ = _pad_to(q, bq, 2)
    kp, _ = _pad_to(k, bk, 2)
    vp, _ = _pad_to(v, bk, 2)
    if not causal and kp.shape[2] != Sk:
        raise ValueError("non-causal padding unsupported; pad upstream")
    # padded queries attend causally to real keys only (pad rows discarded);
    # padded keys sit in the causal future of every real query.
    out = _fa.flash_attention(qp, kp, vp, causal=causal, scale=scale,
                              block_q=bq, block_k=bk,
                              interpret=_interpret())
    return out[:, :, :Sq, :]


def decode_attention(q, k_cache, v_cache, lengths, *,
                     scale: float | None = None,
                     block_s: int = 256) -> jax.Array:
    """(B, Hq, d) against (B, Hkv, S, d) caches with ragged lengths."""
    S = k_cache.shape[2]
    bs = min(block_s, _ceil_mult(S, 8))
    kp, _ = _pad_to(k_cache, bs, 2)
    vp, _ = _pad_to(v_cache, bs, 2)
    return _fa.decode_attention(q, kp, vp, lengths, scale=scale,
                                block_s=bs, interpret=_interpret())


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256) -> jax.Array:
    """RMSNorm over the last axis; any leading shape."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    R = x2.shape[0]
    br = min(block_rows, R) if R % min(block_rows, R) == 0 else 1
    # choose the largest divisor of R that is <= block_rows
    br = max(b for b in range(1, min(block_rows, R) + 1) if R % b == 0)
    out = _rn.rmsnorm(x2, weight, eps=eps, block_rows=br,
                      interpret=_interpret())
    return out.reshape(shape)


def spmv(values, col_ids, x) -> jax.Array:
    """BSR SpMV (see kernels/spmv.py for the layout)."""
    return _spmv.spmv_bsr(values, col_ids, x, interpret=_interpret())


csr_to_bsr = _spmv.csr_to_bsr

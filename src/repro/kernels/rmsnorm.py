"""Fused RMSNorm kernel — one HBM round trip instead of three.

Not a paper-specific kernel, but the template's "burst access" rule
(§III-B2) applied to normalization: the unfused jnp version streams the
activation row from HBM once for the mean-square reduction and again for
the scale; the fused kernel reads each VMEM-resident tile once and writes
once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,       # (R, D) — callers flatten leading dims
    weight: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    R, D = x.shape
    assert R % block_rows == 0, (R, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, weight[None, :])

"""Pallas TPU kernels for the compute hot-spots, with pure-jnp oracles.

Each kernel follows the package contract: <name>.py holds the
``pl.pallas_call`` + BlockSpec implementation, ``ops.py`` the jit'd public
wrapper (padding, GQA plumbing, interpret fallback off-TPU), ``ref.py`` the
pure-jnp oracle used by the allclose test sweeps.
"""

from .ops import (matmul, flash_attention, decode_attention, rmsnorm, spmv,
                  csr_to_bsr)
from .decoupled_gather import (decoupled_gather, decoupled_gather_ref,
                               decoupled_gather_staged)
from . import ref

__all__ = ["matmul", "flash_attention", "decode_attention", "rmsnorm",
           "spmv", "csr_to_bsr", "decoupled_gather",
           "decoupled_gather_ref", "decoupled_gather_staged", "ref"]

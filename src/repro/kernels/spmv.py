"""SpMV — the paper's first benchmark kernel, TPU-native.

The paper's CSR SpMV is the canonical irregular-access workload: loads of
the floating-point values depend on data in an index array (§V).  The HLS
flow decouples it into: (1) index fetch → (2) value/x gather → (3) FMA.

The GPU/CPU CSR layout is hostile to the MXU, so per the hardware-adaptation
mandate we *re-block* the matrix into BSR (block-sparse rows) and realize
the same three decoupled stages with TPU mechanisms:

1. **index fetch** — the block-column ids are *scalar-prefetched*
   (``PrefetchScalarGridSpec``): they land in SMEM before the grid step
   runs, exactly the paper's "stage issuing the memory request" running
   ahead.
2. **gather** — the ``x`` tile's ``BlockSpec`` index map reads the
   prefetched ids, so the DMA engine performs the data-dependent gather of
   ``x[col]`` while the previous block is still being multiplied (the FIFO
   between stages is the double-buffered VMEM slot).
3. **FMA** — MXU block dot, fp32 accumulation in VMEM scratch.

Padding blocks (col_id == −1) are mapped to block 0 and masked in-kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(col_ref, val_ref, x_ref, y_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(0)
    valid = col_ref[i, j] >= 0
    xblk = jnp.where(valid, x_ref[0], jnp.zeros_like(x_ref[0]))  # (bk,)
    # (bm, bk) @ (bk, 1) on the MXU; accumulator tile is (1, bm)
    prod = jnp.dot(val_ref[0, 0], xblk[:, None],
                   preferred_element_type=jnp.float32)           # (bm, 1)
    acc_ref[...] += prod[:, 0][None, :]

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_bsr(
    values: jax.Array,
    col_ids: jax.Array,
    x: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Block-sparse-row SpMV.

    values : (n_block_rows, nnz_blocks, bm, bk)
    col_ids: (n_block_rows, nnz_blocks) int32, −1 = padding
    x      : (K,) with K divisible by bk
    returns (n_block_rows * bm,)
    """
    nbr, nnz, bm, bk = values.shape
    K = x.shape[0]
    assert K % bk == 0
    xb = x.reshape(K // bk, bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr, nnz),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk), lambda i, j, cols: (i, j, 0, 0)),
            # the data-dependent gather: x's tile address comes from the
            # prefetched index array (stage 1 feeding stage 2); padding
            # blocks (−1) clamp to 0 and are masked in-kernel.
            pl.BlockSpec((1, bk),
                         lambda i, j, cols: (jnp.maximum(cols[i, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda i, j, cols: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, bm), jnp.float32)],
    )
    y = pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nbr, bm), x.dtype),
        interpret=interpret,
    )(col_ids.astype(jnp.int32), values, xb)
    return y.reshape(-1)


def csr_to_bsr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               shape: tuple[int, int], bm: int = 8, bk: int = 128
               ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side re-blocking of CSR into the kernel's BSR layout.

    Returns (values, col_ids) with values (nbr, nnz_max, bm, bk) and
    col_ids (nbr, nnz_max) int32 (−1 padding).  This is the analogue of the
    paper's memory-space partitioning step: restructure the irregular
    structure once, off the critical path, so the steady-state pipeline
    sees only block-granular traffic.
    """
    M, K = shape
    nbr = (M + bm - 1) // bm
    nbc = (K + bk - 1) // bk
    # collect the set of touched block columns per block row
    block_cols: list[set[int]] = [set() for _ in range(nbr)]
    for r in range(M):
        for p in range(indptr[r], indptr[r + 1]):
            block_cols[r // bm].add(int(indices[p]) // bk)
    nnz_max = max(1, max((len(s) for s in block_cols), default=1))
    values = np.zeros((nbr, nnz_max, bm, bk), dtype=data.dtype)
    col_ids = np.full((nbr, nnz_max), -1, dtype=np.int32)
    slot_of: list[dict[int, int]] = []
    for br in range(nbr):
        slots = {c: s for s, c in enumerate(sorted(block_cols[br]))}
        slot_of.append(slots)
        for c, s in slots.items():
            col_ids[br, s] = c
    for r in range(M):
        br, rr = divmod(r, bm)
        for p in range(indptr[r], indptr[r + 1]):
            c = int(indices[p])
            bc, cc = divmod(c, bk)
            values[br, slot_of[br][bc], rr, cc] = data[p]
    return values, col_ids

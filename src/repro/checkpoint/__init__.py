"""Atomic, async, keep-N checkpoints with mesh-resharding restore."""

from .checkpointer import Checkpointer

__all__ = ["Checkpointer"]

"""Checkpointing: atomic, async, keep-N, mesh-resharding restore.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py):

* **atomic** — writes go to ``step_XXXX.tmp`` then ``os.replace`` to the
  final name; a crash mid-write never corrupts the latest checkpoint.
* **async** — ``save()`` snapshots to host memory synchronously (cheap)
  and writes to disk on a background thread; ``wait()`` joins.
* **keep-N** — older checkpoints garbage-collected after a successful
  write (never before).
* **resharding restore** — arrays are saved with their global shape; on
  restore they are ``device_put`` against the *current* mesh's sharding,
  so a job can come back on a different data-parallel size (elastic
  scaling after losing a slice).

Format: one ``.npz`` per checkpoint plus a JSON manifest (step, pytree
structure, dtypes).  No orbax dependency in the image — this is a complete
self-contained implementation.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name, leaf))
    return out


@dataclasses.dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        """Snapshot now, write in the background."""
        self.wait()  # one in-flight write at a time
        named = _flatten_with_names(state)
        host = {name: np.asarray(leaf) for name, leaf in named}
        treedef = jax.tree_util.tree_structure(state)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "names": [n for n, _ in named],
        }

        def write():
            try:
                tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
                final = os.path.join(self.directory, f"step_{step:08d}.npz")
                with open(tmp, "wb") as f:
                    np.savez(f, **host)
                os.replace(tmp, final)
                mtmp = os.path.join(self.directory,
                                    f"step_{step:08d}.json.tmp")
                mfinal = os.path.join(self.directory,
                                      f"step_{step:08d}.json")
                with open(mtmp, "w") as f:
                    json.dump(manifest, f)
                os.replace(mtmp, mfinal)
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for ext in (".npz", ".json"):
                p = os.path.join(self.directory, f"step_{s:08d}{ext}")
                if os.path.exists(p):
                    os.remove(p)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for fn in os.listdir(self.directory):
            m = re.match(r"step_(\d+)\.npz$", fn)
            if m and os.path.exists(os.path.join(
                    self.directory, f"step_{int(m.group(1)):08d}.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, example_state: Any, step: int | None = None,
                *, shardings: Any | None = None) -> tuple[Any, int]:
        """Restore into the structure of ``example_state``.

        ``shardings``: optional pytree of NamedSharding congruent with the
        state — arrays are placed per the *current* mesh (elastic restore).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}.npz")
        data = np.load(path)
        named = _flatten_with_names(example_state)
        flat_shardings = (jax.tree_util.tree_leaves(shardings)
                          if shardings is not None else [None] * len(named))
        leaves = []
        for (name, example), shard in zip(named, flat_shardings):
            arr = data[name]
            want = tuple(np.shape(example))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {want}")
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jnp.asarray(arr,
                                          dtype=np.asarray(example).dtype))
        treedef = jax.tree_util.tree_structure(example_state)
        return jax.tree_util.tree_unflatten(treedef, leaves), step

"""Deterministic fault injection for the resolution/serving stack.

A :class:`FaultPlan` is a seeded list of :class:`FaultSpec` entries,
each naming a fault *kind*, a 1-based occurrence index ``at`` (fire on
the Nth matching event), and optional filters (worker id, chunk index,
artifact key).  The plan is installed either programmatically
(:func:`install`, in-process tests) or through the environment
(``REPRO_FAULT_PLAN`` holding JSON or a path to JSON), which is how it
reaches spawned daemon and worker processes — the env var is inherited,
so one setting arms every process of the serving stack.

Hook sites are sprinkled through the stack and are **no-ops when no
plan is armed** (a cached module check, no I/O):

========================  =====================================================
kind                      site / effect
========================  =====================================================
``worker_kill``           pool worker, start of a chunk task: SIGKILL itself
``straggler``             pool worker, start of phase C: sleep ``delay_s``
``daemon_kill``           daemon, after committing chunk N: SIGKILL itself
``corrupt_chunk``         rescache ``put_chunk``: bit-flip bytes of the
                          just-written record (detected later by checksum)
``truncate_chunk``        rescache ``put_chunk``: truncate the record file
``drop_socket``           serve client, after the Nth streamed message:
                          close the connection mid-stream
``delay_socket``          serve client, before the Nth recv: sleep ``delay_s``
========================  =====================================================

Every fault is **deterministic**: the same plan against the same
workload fires at the same event, so chaos scenarios replay exactly.
Fired faults are counted per process (:func:`stats`) and, when the plan
names a ``log`` file, appended there *before* the fault is enacted —
the only way a self-SIGKILL can be observed from outside.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any

KINDS = ("worker_kill", "daemon_kill", "corrupt_chunk", "truncate_chunk",
         "drop_socket", "delay_socket", "straggler")

ENV = "REPRO_FAULT_PLAN"


@dataclasses.dataclass
class FaultSpec:
    """One fault: fire on occurrences ``at .. at+count-1`` of matching
    events at the ``kind`` hook site.  ``target`` filters on worker id,
    ``chunk`` on chunk index, ``key`` on an artifact-key prefix; an
    unset filter matches everything."""

    kind: str
    at: int = 1
    count: int = 1
    target: int | None = None
    chunk: int | None = None
    key: str | None = None
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")

    def matches(self, ctx: dict[str, Any]) -> bool:
        if self.target is not None and ctx.get("worker") != self.target:
            return False
        if self.chunk is not None and ctx.get("chunk") != self.chunk:
            return False
        if self.key is not None and \
                not str(ctx.get("key", "")).startswith(self.key):
            return False
        return True


class FaultPlan:
    """A seeded, replayable set of faults plus per-process accounting."""

    def __init__(self, faults: Any = (), seed: int = 0,
                 log: str | None = None):
        self.faults = [f if isinstance(f, FaultSpec) else FaultSpec(**f)
                       for f in faults]
        self.seed = int(seed)
        self.log = log
        # per-spec event counters: spec index -> matching events seen
        self._seen = [0] * len(self.faults)
        self.injected: dict[str, int] = {}

    @classmethod
    def from_json(cls, raw: str) -> "FaultPlan":
        d = json.loads(raw)
        if isinstance(d, list):
            d = {"faults": d}
        return cls(d.get("faults", ()), seed=d.get("seed", 0),
                   log=d.get("log"))

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed, "log": self.log,
            "faults": [dataclasses.asdict(f) for f in self.faults]})

    def rng_byte(self, n: int) -> int:
        """Deterministic pseudo-random byte for corruption payloads."""
        import hashlib
        h = hashlib.blake2b(f"{self.seed}:{n}".encode(), digest_size=1)
        return h.digest()[0] or 0xFF

    def check(self, kind: str, **ctx: Any) -> FaultSpec | None:
        """Count this event against every matching spec; return the
        first spec whose firing window covers it, else ``None``.

        When the plan carries a ``log``, it is also the cross-process
        firing registry: a spec fires at most ``count`` times *across
        all processes of the plan* — without this, a respawned worker
        (fresh process, same env plan) would re-kill itself at the same
        chunk forever, and the crash loop would eat the retry budget
        instead of proving recovery."""
        hit = None
        for i, f in enumerate(self.faults):
            if f.kind != kind or not f.matches(ctx):
                continue
            self._seen[i] += 1
            if hit is None and f.at <= self._seen[i] < f.at + f.count:
                hit = f
        if hit is not None and self.log and \
                log_counts(self.log).get(kind, 0) >= hit.count:
            return None
        if hit is not None:
            self.injected[kind] = self.injected.get(kind, 0) + 1
            self._note(kind, ctx)
        return hit

    def _note(self, kind: str, ctx: dict[str, Any]) -> None:
        if not self.log:
            return
        try:
            with open(self.log, "a") as f:
                f.write(json.dumps({"kind": kind, "pid": os.getpid(),
                                    **{k: v for k, v in ctx.items()
                                       if isinstance(v, (int, str))}})
                        + "\n")
                f.flush()
        except OSError:
            pass


_plan: FaultPlan | None = None
_env_loaded = False


def install(plan: FaultPlan | None) -> None:
    """Arm (or with ``None`` disarm) a plan in this process; overrides
    any environment plan."""
    global _plan, _env_loaded
    _plan = plan
    _env_loaded = True


def reset() -> None:
    """Disarm and forget, re-reading the environment on next use."""
    global _plan, _env_loaded
    _plan = None
    _env_loaded = False


def plan() -> FaultPlan | None:
    global _plan, _env_loaded
    if not _env_loaded:
        _env_loaded = True
        raw = os.environ.get(ENV)
        if raw:
            if os.path.isfile(raw):
                with open(raw) as f:
                    raw = f.read()
            try:
                _plan = FaultPlan.from_json(raw)
            except (ValueError, TypeError, KeyError):
                _plan = None
    return _plan


def active() -> bool:
    return plan() is not None


def stats() -> dict[str, int]:
    """Faults injected *by this process* (kind -> count)."""
    p = _plan if _env_loaded else plan()
    return dict(p.injected) if p is not None else {}


def log_counts(path: str) -> dict[str, int]:
    """Merge a plan's cross-process fault log (kind -> count) — the
    harness-side view that survives self-SIGKILLed processes."""
    out: dict[str, int] = {}
    try:
        with open(path) as f:
            for line in f:
                try:
                    kind = json.loads(line).get("kind")
                except ValueError:
                    continue
                if kind:
                    out[kind] = out.get(kind, 0) + 1
    except OSError:
        pass
    return out


# ---------------------------------------------------------------------------
# Hook helpers — each is a no-op unless a plan is armed and fires.
# ---------------------------------------------------------------------------

def maybe_kill(kind: str, **ctx: Any) -> None:
    """SIGKILL the current process if a ``kind`` spec fires (worker- and
    daemon-crash injection; the log line lands before the kill)."""
    p = plan()
    if p is None:
        return
    if p.check(kind, **ctx) is not None:
        os.kill(os.getpid(), signal.SIGKILL)


def maybe_sleep(kind: str, **ctx: Any) -> float:
    """Sleep ``delay_s`` if a spec fires (straggler / socket delay);
    returns the injected delay."""
    p = plan()
    if p is None:
        return 0.0
    f = p.check(kind, **ctx)
    if f is None or f.delay_s <= 0:
        return 0.0
    time.sleep(f.delay_s)
    return f.delay_s


def maybe_drop(conn: Any, **ctx: Any) -> bool:
    """Hard-close a client connection mid-stream if ``drop_socket``
    fires; returns True when it did."""
    p = plan()
    if p is None:
        return False
    if p.check("drop_socket", **ctx) is None:
        return False
    try:
        conn.shutdown(2)  # socket.SHUT_RDWR without importing socket
    except OSError:
        pass
    try:
        conn.close()
    except OSError:
        pass
    return True


def maybe_corrupt(path: str, **ctx: Any) -> str | None:
    """Bit-flip (``corrupt_chunk``) or truncate (``truncate_chunk``) a
    just-written store record if a spec fires.  Returns the kind fired,
    else ``None``.  The damage is deliberately *silent* — detection is
    the store's job (checksums), not the injector's."""
    p = plan()
    if p is None:
        return None
    f = p.check("corrupt_chunk", **ctx)
    if f is not None:
        corrupt_file(path, seed=p.seed)
        return "corrupt_chunk"
    f = p.check("truncate_chunk", **ctx)
    if f is not None:
        truncate_file(path)
        return "truncate_chunk"
    return None


def corrupt_file(path: str, seed: int = 0, n_bytes: int = 8) -> None:
    """Flip bytes in the middle of ``path`` (payload region of an npz,
    past the zip local-file header) deterministically."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            for i in range(n_bytes):
                pos = (size // 3 + i * max(1, size // (3 * n_bytes))) \
                    % max(1, size)
                f.seek(pos)
                b = f.read(1)
                if not b:
                    break
                f.seek(pos)
                import hashlib
                x = hashlib.blake2b(f"{seed}:{i}".encode(),
                                    digest_size=1).digest()[0] | 1
                f.write(bytes([b[0] ^ x]))
    except OSError:
        pass


def truncate_file(path: str) -> None:
    """Cut ``path`` to half its size — a torn write / crashed writer."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
    except OSError:
        pass

"""The resolution daemon: one global scheduler, many tenants.

``ResolutionDaemon`` owns a spawn-pool of :mod:`repro.serve.worker`
processes and a single work-stealing scheduler feeding them.  Clients
(:mod:`repro.serve.client`) submit *resolution requests* — the live
(un-served) models of one ``simulate_dataflow_many`` grid — over a
local socket; the daemon answers with a stream of per-chunk completion
records the client folds and solves incrementally.

Requests dedup three ways, in order:

* **store** — chunks inside the v3 rescache's stored prefix are never
  scheduled; the client folds them straight from the records
  (prefix-serving included).
* **in-flight** — requests are keyed by their per-op content keys; a
  request whose key set matches a running **job** attaches to it and
  receives the same stream (N clients asking for overlapping grids pay
  for one resolution).  A request needing *more* chunks of the same
  artifact extends the job in place — chunks always resolve on the
  canonical full-chunk grid, so extension is seamless.
* **cold** — only the residue becomes chunk tasks, scheduled globally
  across all jobs: the long tail of one client's Floyd–Warshall run
  backfills workers another client just freed (work stealing by
  construction — chunks go wherever capacity is).

Fairness and admission control: each job earns credits at the summed
weight of its attached clients (weighted deficit round-robin) and pays
one credit per dispatched chunk; a request whose residue would push the
global queue or its client's outstanding-chunks budget past the caps is
rejected with a ``busy``/retry-after instead of queueing unboundedly.

Failure semantics: a dead worker is respawned and its in-flight chunks'
phase messages are replayed verbatim (resolution is deterministic, so
the retry is bit-identical) under a per-job retry budget — beyond it
the job fails loudly.  A disconnected client's requests detach; chunks
no other client needs are cancelled (never dispatched), chunks already
in flight or shared keep running, and the job's results remain
attachable until the daemon retires it.

The daemon is a *scheduling* layer only: workers run the same resolver,
the same cache-effect monoid composition, and the same PCG64 draw
positioning as the library engines, so results are bit-identical by
construction.
"""

from __future__ import annotations

import multiprocessing
import os
import queue
import threading
import time
import traceback
import itertools
from collections import OrderedDict

from . import faults, protocol
from .journal import Journal
from .worker import worker_main

#: per-process daemon-instance counter for the pidfile token (two
#: daemon objects in one process must still conflict on one socket)
_INSTANCE_IDS = itertools.count(1)

#: Outstanding chunks per worker (matches the chunk-graph executor).
_WINDOW = 2


def _mk_rescache_cfg():
    from ..core import rescache as _rc
    return {
        "enabled": _rc._cfg.enabled,
        "directory": _rc._dir(),
        "memory_mb": _rc._cfg.memory_mb,
        "artifact_mb": _rc._cfg.artifact_mb,
        "disk_mb": _rc._cfg.disk_mb,
    }


class _Request:
    """One client's view of one job: which chunks it still needs and
    the walls the stats endpoint reports."""

    __slots__ = ("conn", "req", "n_chunks", "n_iters", "names",
                 "t_admit", "queue_s", "next_notify", "done", "record",
                 "deadline")

    def __init__(self, conn, req, n_chunks, n_iters, names,
                 deadline_s=None):
        self.conn = conn
        self.req = req
        self.n_chunks = n_chunks
        self.n_iters = n_iters
        #: request model name -> job model name (content keys match)
        self.names = names
        self.t_admit = time.monotonic()
        self.queue_s: float | None = None
        self.next_notify = 0  # set to job.first_live at attach
        self.done = False
        self.record: dict | None = None
        #: absolute monotonic deadline (per-request, layered on WDRR:
        #: admission is unchanged, but an expired request is failed at
        #: the next health tick and its client falls back locally)
        self.deadline = (self.t_admit + deadline_s) \
            if deadline_s else None


class _Job:
    """One in-flight resolution: the chunk-graph master state for one
    content-key set, shared by every attached request."""

    def __init__(self, jid, keyset, payload, keys, mems, seed, n_iters):
        from ..core.simulator import _cache_group_key
        self.jid = jid
        self.keyset = keyset
        self.payload = payload
        self.keys = keys          # job model name -> v3 key
        self.mems = mems
        self.seed = seed
        self.n_iters_hint = n_iters
        self.geos = {mn: _cache_group_key(m) for mn, m in mems.items()}
        self.first_live = 0
        self.sched_upto = 0       # chunks demanded so far
        self.next_k = 0           # dispatch pointer
        self.state_sent = 0
        self.draws_sent = 0
        self.committed = 0        # in-order commit watermark
        self.state_at: dict[int, dict | None] = {}
        self.effects: dict[int, dict] = {}
        self.n_addrs: dict[int, int] = {}
        self.deltas: dict[int, dict] = {}
        self.done_buf: dict[int, tuple] = {}
        self.sent_state: dict[int, dict] = {}
        self.sent_draws: dict[int, dict] = {}
        self.cum_draws: dict[str, int] = {}
        self.geo_cum: dict[tuple, tuple[int, int]] = {}
        self.cums_hist: dict[int, dict] = {}
        self.inline_hist: OrderedDict[int, tuple[int, dict]] = \
            OrderedDict()         # k -> (nbytes, inline)
        self.inline_bytes = 0
        self.inline_dropped: set[int] = set()
        self.requests: list[_Request] = []
        self.retries = 0
        self.completions = 0  # sched_upto high-water at last retire
        self.failed = False
        self.first_dispatch_t: float | None = None
        #: journal-resumed orphan: dispatchable with no client attached
        #: (a restarted daemon finishing what its predecessor promised)
        self.keep_alive = False

    def weight(self, clients) -> float:
        conns = {r.conn for r in self.requests if not r.done}
        return max(0.001, sum(clients[c]["weight"] for c in conns
                              if c in clients))

    def live(self) -> bool:
        return not self.failed and self.next_k < self.sched_upto


class ResolutionDaemon:
    """See the module docstring.  ``throttle_s`` sleeps before each
    chunk dispatch — a test/debug knob that widens the in-flight window
    so racing clients deterministically overlap."""

    def __init__(self, address: str | None = None,
                 workers: int | None = None, *,
                 max_queued_chunks: int = 4096,
                 max_client_chunks: int = 4096,
                 retry_budget: int | None = None,
                 throttle_s: float = 0.0,
                 inline_history_mb: int = 64,
                 journal: bool = True,
                 speculate_after_s: float | None = None,
                 speculate_factor: float = 4.0):
        from ..core import rescache as _rc
        from ..core.chunkgraph import RETRY_BUDGET
        from ..runtime.fault_tolerance import SpeculationPolicy
        if not _rc.enabled(None) or not _rc._dir():
            raise RuntimeError(
                "the resolution daemon requires an enabled rescache "
                "with a disk store (repro.core.rescache.configure)")
        self.address = address or protocol.default_address()
        self.workers = workers if workers is not None \
            else max(2, multiprocessing.cpu_count() - 1)
        self.C = _rc.CHUNK_ITERS
        self.store_dir = os.path.realpath(_rc._dir())
        self.max_queued_chunks = max_queued_chunks
        self.max_client_chunks = max_client_chunks
        self.retry_budget = RETRY_BUDGET if retry_budget is None \
            else retry_budget
        self.throttle_s = throttle_s
        self.inline_cap = inline_history_mb * (1 << 20)
        self._rc = _rc
        self._events: queue.Queue = queue.Queue()
        self._stop_evt = threading.Event()
        self._jobs: dict[int, _Job] = {}
        self._by_keyset: dict[frozenset, int] = {}
        self._clients: dict = {}          # conn -> {weight, reqs}
        self._reqs: dict = {}             # (conn id, req) -> _Request
        self._req_log: list[dict] = []    # last completed requests
        self._jid = 0
        self._t0 = time.monotonic()
        self._pid_token = f"{os.getpid()}.{next(_INSTANCE_IDS)}"
        self._stats = {"accepted": 0, "rejected": 0, "jobs_completed": 0,
                       "jobs_failed": 0, "cancelled_chunks": 0,
                       "worker_restarts": 0, "chunk_retries": 0,
                       "dedup_store": 0, "dedup_inflight": 0,
                       "dedup_cold": 0,
                       "deadline_failures": 0, "resumed_jobs": 0,
                       "speculative_dispatches": 0,
                       "speculative_wins": 0}
        self._threads: list[threading.Thread] = []
        self._journal = Journal(self.store_dir, enabled=journal)
        self._base: dict[str, int] = {}   # journaled pre-restart totals
        self._restarts = 0
        if speculate_after_s is None:
            try:
                speculate_after_s = float(
                    os.environ.get("REPRO_SPECULATE_AFTER_S", "30"))
            except ValueError:
                speculate_after_s = 30.0
        self._spec_policy = None if speculate_after_s <= 0 else \
            SpeculationPolicy(min_wait_s=speculate_after_s,
                              latency_factor=speculate_factor,
                              max_inflight=max(1, self.workers // 2))

    # -- lifecycle -----------------------------------------------------------

    def _pidfile(self) -> str | None:
        return None if protocol.is_inet(self.address) \
            else self.address + ".pid"

    def _guard_pidfile(self) -> None:
        """Refuse to start over a *live* daemon on the same socket —
        binding an AF_UNIX path unlinks whatever is there, so without
        this check the loser of a spawn race would silently steal the
        winner's socket.  The pidfile holds a per-instance token (two
        daemon objects in one process must conflict too); a stale
        entry (dead pid) is overwritten."""
        pf = self._pidfile()
        if pf is None:
            return
        try:
            with open(pf) as f:
                token = f.read().strip()
            pid = int(token.split(".", 1)[0] or 0)
            if token and token != self._pid_token:
                os.kill(pid, 0)  # raises if the process is gone
                raise RuntimeError(
                    f"daemon pid {pid} already serves {self.address} "
                    f"(pidfile {pf})")
        except (OSError, ValueError):
            pass  # no pidfile / unreadable / dead pid: ours to take
        try:
            with open(pf, "w") as f:
                f.write(self._pid_token)
        except OSError:
            pass

    def start(self) -> None:
        self._guard_pidfile()
        ctx = multiprocessing.get_context("spawn")
        self._ctx = ctx
        self._result_q = ctx.Queue()
        cfg = _mk_rescache_cfg()
        self._cfg = cfg
        self._task_qs = [ctx.Queue() for _ in range(self.workers)]
        self._procs = [ctx.Process(
            target=worker_main,
            args=(w, self.C, self._task_qs[w], self._result_q, cfg),
            daemon=True) for w in range(self.workers)]
        for p in self._procs:
            p.start()
        self._known = [set() for _ in range(self.workers)]
        self._load = [0] * self.workers
        self._busy_s = [0.0] * self.workers
        self._inflight: dict[tuple[int, int], int] = {}
        #: chunk -> speculative (second) owner; first commit wins
        self._spec: dict[tuple[int, int], int] = {}
        self._dispatch_t: dict[tuple[int, int], float] = {}
        self._recover_journal()
        self._sock = protocol.listen(self.address)
        self._threads = [
            threading.Thread(target=self._listen_loop, daemon=True),
            threading.Thread(target=self._run, daemon=True)]
        for t in self._threads:
            t.start()

    def _recover_journal(self) -> None:
        """Load the previous lifetime's state: counter totals, the
        request log, and — the durability contract — every job that was
        admitted but never completed, re-created from its journaled
        payload with its demand restored.  The store prefix says which
        chunks survived the crash; the remainder resolves with no
        client attached, so a client that failed over mid-stream finds
        the full artifact on its next run."""
        import pickle
        rep = self._journal.replay()
        self._base = rep["base_stats"]
        self._restarts = rep["starts"]
        self._req_log = list(rep["req_log"])
        self._jid = rep["max_jid"]
        self._journal.compact()
        self._journal.append({"ev": "start", "pid": os.getpid()},
                             sync=True)
        for jid, ev in sorted(rep["open_jobs"].items()):
            payload = self._journal.load_payload(jid)
            if payload is None:
                continue
            try:
                d = pickle.loads(payload)
            except Exception:  # noqa: BLE001 — torn payload blob
                self._journal.drop_payload(jid)
                continue
            msg = {"payload": payload, "mems": d["mems"],
                   "seed": ev.get("seed", d.get("seed", 0)),
                   "n_iters": ev.get("n_iters", d.get("n_iters", 0))}
            j = self._new_job(msg, dict(ev["keys"]))
            # the resumed job gets a fresh jid; close the old journal
            # entry either way and re-open under the new one if work
            # remains (committed < demanded)
            self._journal.append({"ev": "job_done", "jid": jid})
            self._journal.drop_payload(jid)
            if j is None:
                continue
            n_chunks = int(ev.get("n_chunks", 0))
            if j.committed >= n_chunks:
                continue  # store prefix already covers the demand
            j.sched_upto = n_chunks
            j.keep_alive = True
            self._stats["resumed_jobs"] += 1
            self._journal_job(j)

    def _journal_job(self, j: _Job) -> None:
        self._journal.save_payload(j.jid, j.payload)
        self._journal.append(
            {"ev": "job", "jid": j.jid, "keys": dict(j.keys),
             "seed": j.seed, "n_iters": j.n_iters_hint,
             "n_chunks": j.sched_upto}, sync=True)

    def _journal_stats(self) -> None:
        merged = {k: v + self._base.get(k, 0)
                  for k, v in self._stats.items()}
        self._journal.append({"ev": "stats", "stats": merged})

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stop_evt.wait(0.5):
                pass
        except KeyboardInterrupt:
            pass
        self.stop()

    def stop(self) -> None:
        self._stop_evt.set()
        self._journal_stats()
        pf = self._pidfile()
        if pf is not None:
            try:
                os.unlink(pf)
            except OSError:
                pass
        try:
            self._sock.close()
        except OSError:
            pass
        if not protocol.is_inet(self.address):
            try:
                os.unlink(self.address)
            except OSError:
                pass
        for q in getattr(self, "_task_qs", []):
            try:
                q.put(("stop",))
            except Exception:
                pass
        for p in getattr(self, "_procs", []):
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
        for q in getattr(self, "_task_qs", []):
            # a worker that died without draining leaves the feeder
            # blocked; don't let its exit finalizer hang the process
            q.cancel_join_thread()
            q.close()

    # -- socket side ---------------------------------------------------------

    def _listen_loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._read_loop, args=(conn,),
                             daemon=True).start()

    def _read_loop(self, conn) -> None:
        self._events.put(("hello", conn))
        try:
            while True:
                msg = protocol.recv_msg(conn)
                self._events.put(("msg", conn, msg))
                if msg.get("type") == "shutdown":
                    return
        except (protocol.ProtocolError, OSError, EOFError):
            self._events.put(("bye", conn))

    def _send(self, conn, obj) -> None:
        """All sends happen on the scheduler thread (single writer); a
        failed send is a disconnect."""
        try:
            protocol.send_msg(conn, obj)
        except (OSError, ValueError):
            self._drop_client(conn)

    # -- scheduler thread ----------------------------------------------------

    def _run(self) -> None:
        last_health = time.monotonic()
        while not self._stop_evt.is_set():
            busy = any(j.live() for j in self._jobs.values()) \
                or self._inflight
            try:
                msg = self._result_q.get(timeout=0.05 if busy else 0.25)
            except queue.Empty:
                msg = None
            if msg is not None:
                self._on_worker_msg(msg)
            while True:
                try:
                    self._on_worker_msg(self._result_q.get_nowait())
                except queue.Empty:
                    break
            while True:
                try:
                    ev = self._events.get_nowait()
                except queue.Empty:
                    break
                self._on_event(ev)
            self._dispatch()
            now = time.monotonic()
            if now - last_health > 1.0:
                last_health = now
                self._check_workers()
                self._check_deadlines(now)
                self._check_stragglers(now)

    # -- client events -------------------------------------------------------

    def _on_event(self, ev) -> None:
        kind = ev[0]
        if kind == "hello":
            self._clients[ev[1]] = {"weight": 1.0, "reqs": set()}
            return
        if kind == "bye":
            self._drop_client(ev[1])
            return
        conn, msg = ev[1], ev[2]
        t = msg.get("type")
        if t == "ping":
            self._send(conn, {"type": "pong"})
        elif t == "stats":
            self._send(conn, {"type": "stats", "stats": self.stats()})
        elif t == "shutdown":
            self._send(conn, {"type": "ok"})
            self._stop_evt.set()
        elif t == "resolve":
            try:
                self._admit(conn, msg)
            except Exception:  # noqa: BLE001 — bad request, not a crash
                self._send(conn, {"type": "error",
                                  "req": msg.get("req"),
                                  "reason": traceback.format_exc()})
        elif t == "solved":
            rec = self._reqs.get((id(conn), msg.get("req")))
            if rec is not None and rec.record is not None:
                rec.record["solve_s"] = float(msg.get("solve_wall_s", 0))
        elif t == "cancel":
            r = self._reqs.get((id(conn), msg.get("req")))
            if r is not None and not r.done:
                self._detach(r)

    def _drop_client(self, conn) -> None:
        cl = self._clients.pop(conn, None)
        if cl is None:
            return
        for rid in list(cl["reqs"]):
            r = self._reqs.get(rid)
            if r is not None and not r.done:
                self._detach(r)
        try:
            conn.close()
        except OSError:
            pass

    def _detach(self, r: _Request) -> None:
        """Remove a request from its job; cancel chunks nobody else
        needs (never-dispatched ones only — in-flight chunks finish and
        commit, keeping the job attachable)."""
        r.done = True
        j = next((j for j in self._jobs.values()
                  if r in j.requests), None)
        if j is None:
            return
        j.requests.remove(r)
        self._cancel_unneeded(j)

    def _cancel_unneeded(self, j: _Job) -> None:
        """Cancel never-dispatched chunks no live request needs — except
        on journal-resumed orphans, whose whole point is finishing with
        nobody attached."""
        if j.keep_alive:
            return
        if not any(not q.done for q in j.requests):
            cancelled = max(0, j.sched_upto - j.next_k)
            if cancelled:
                self._stats["cancelled_chunks"] += cancelled
                j.sched_upto = j.next_k
            self._maybe_retire(j)

    # -- admission -----------------------------------------------------------

    def _admit(self, conn, msg) -> None:
        req_id = msg["req"]
        if os.path.realpath(msg["store_dir"]) != self.store_dir:
            self._send(conn, {
                "type": "error", "req": req_id,
                "reason": f"daemon serves store {self.store_dir}, "
                          f"client uses {msg['store_dir']}"})
            return
        if int(msg["chunk_iters"]) != self.C:
            self._send(conn, {
                "type": "error", "req": req_id,
                "reason": f"daemon chunk_iters={self.C}, "
                          f"client={msg['chunk_iters']}"})
            return
        keys = dict(msg["keys"])      # request model name -> v3 key
        n_iters = int(msg["n_iters"])
        n_chunks = -(-n_iters // self.C)
        cl = self._clients[conn]
        cl["weight"] = min(100.0, max(0.1,
                                      float(msg.get("weight", 1.0))))
        j = self._find_job(keys)
        if j is None:
            j = self._new_job(msg, keys)
            if j is None:  # store raced away mid-probe: client retries
                self._send(conn, {"type": "error", "req": req_id,
                                  "reason": "resume record vanished"})
                return
        names = {rmn: self._by_key(j, k) for rmn, k in keys.items()}
        # dedup accounting relative to this job's current frontier
        store = min(n_chunks, j.first_live)
        inflight = max(0, min(n_chunks, j.sched_upto) - j.first_live)
        cold = max(0, n_chunks - max(j.first_live, j.sched_upto))
        # backpressure: reject rather than queue unboundedly
        queued = sum(max(0, q.sched_upto - q.next_k)
                     for q in self._jobs.values() if not q.failed)
        outstanding = sum(q.n_chunks - q.next_notify
                          for rid in cl["reqs"]
                          if (q := self._reqs.get(rid)) is not None
                          and not q.done)
        want = max(0, n_chunks - j.first_live)
        if (cold and queued + cold > self.max_queued_chunks) or \
                outstanding + want > self.max_client_chunks:
            self._stats["rejected"] += 1
            retry = min(30.0, 0.1 + 0.05 * (queued + cold)
                        / max(1, self.workers))
            self._send(conn, {"type": "busy", "req": req_id,
                              "retry_after_s": round(retry, 2)})
            return
        self._stats["accepted"] += 1
        self._stats["dedup_store"] += store
        self._stats["dedup_inflight"] += inflight
        self._stats["dedup_cold"] += cold
        demand_grew = n_chunks > j.sched_upto
        j.sched_upto = max(j.sched_upto, n_chunks)
        if demand_grew and j.sched_upto > j.first_live:
            # durability point: once accepted, a crash must not lose
            # the promise — the restarted daemon re-attaches this job
            # from the journal + store prefix and finishes it
            self._journal_job(j)
        dl = msg.get("deadline_s")
        r = _Request(conn, req_id, n_chunks, n_iters, names,
                     deadline_s=float(dl) if dl else None)
        r.next_notify = j.first_live
        r.record = {"req": str(req_id), "models": sorted(keys),
                    "chunks": n_chunks, "queue_s": None,
                    "resolve_s": None, "solve_s": None,
                    "dedup": {"store": store, "inflight": inflight,
                              "cold": cold}}
        j.requests.append(r)
        rid = (id(conn), req_id)
        self._reqs[rid] = r
        cl["reqs"].add(rid)
        if j.first_dispatch_t is not None:
            r.queue_s = 0.0
        self._send(conn, {
            "type": "accepted", "req": req_id,
            "first_live": j.first_live, "committed": j.committed,
            "dedup": {"store": store, "inflight": inflight,
                      "cold": cold}})
        # late attach: replay already-committed chunks from history
        while not r.done and r.next_notify < min(j.committed,
                                                 r.n_chunks):
            if not self._notify(j, r, r.next_notify):
                return
        self._finish_if_served(j, r)

    def _by_key(self, j: _Job, key: str) -> str:
        for jmn, k in j.keys.items():
            if k == key:
                return jmn
        raise KeyError(key)

    def _find_job(self, keys) -> _Job | None:
        ks = frozenset(keys.values())
        jid = self._by_keyset.get(ks)
        if jid is not None and not self._jobs[jid].failed:
            return self._jobs[jid]
        for j in self._jobs.values():  # subset attach
            if not j.failed and ks <= j.keyset:
                return j
        return None

    def _new_job(self, msg, keys) -> _Job | None:
        _rc = self._rc
        self._jid += 1
        j = _Job(self._jid, frozenset(keys.values()), msg["payload"],
                 keys, dict(msg["mems"]), int(msg["seed"]),
                 int(msg["n_iters"]))
        full = [(_rc.prefix(k, self.C))[0] for k in keys.values()]
        j.first_live = min(full) if full else 0
        if j.first_live > 0:
            recs = {mn: _rc.get_chunk(k, j.first_live - 1, refresh=True)
                    for mn, k in j.keys.items()}
            if any(rec is None for rec in recs.values()):
                j.first_live = 0
        if j.first_live > 0:
            state = {}
            for mn, rec in recs.items():
                j.cum_draws[mn] = int(rec.cum.get("draws", 0))
                geo = j.geos[mn]
                if geo is not None:
                    state[geo] = (rec.states["cache"],
                                  int(rec.cum.get("max_tag", -1)))
                    j.geo_cum[geo] = (int(rec.cum.get("hits", 0)),
                                      int(rec.cum.get("misses", 0)))
            j.state_at[j.first_live] = state
        else:
            j.state_at[0] = None
            j.cum_draws = {mn: 0 for mn in j.keys}
            j.geo_cum = {g: (0, 0) for g in j.geos.values()
                         if g is not None}
        j.next_k = j.state_sent = j.draws_sent = j.first_live
        j.committed = j.first_live
        self._jobs[j.jid] = j
        self._by_keyset[j.keyset] = j.jid
        return j

    # -- dispatch ------------------------------------------------------------

    def _dispatch(self) -> None:
        ready = [j for j in self._jobs.values()
                 if j.live() and (j.keep_alive
                                  or any(not r.done for r in j.requests))]
        if not ready:
            return
        while True:
            w = min(range(self.workers), key=lambda i: self._load[i])
            if self._load[w] >= _WINDOW:
                return
            ready = [j for j in ready if j.live()]
            if not ready:
                return
            # weighted deficit round-robin: refill credits at client
            # weight, pay one per chunk
            if all(getattr(j, "credit", 0.0) < 1.0 for j in ready):
                for j in ready:
                    j.credit = getattr(j, "credit", 0.0) \
                        + j.weight(self._clients)
            j = max(ready, key=lambda q: getattr(q, "credit", 0.0))
            j.credit = getattr(j, "credit", 0.0) - 1.0
            if self.throttle_s:
                time.sleep(self.throttle_s)
            k = j.next_k
            if j.jid not in self._known[w]:
                self._task_qs[w].put(("job", j.jid, j.payload))
                self._known[w].add(j.jid)
            # full canonical chunks always: traces pad past their end,
            # so records never need a partial tail (see worker module)
            self._task_qs[w].put(("task", j.jid, k, k * self.C,
                                  (k + 1) * self.C))
            self._inflight[(j.jid, k)] = w
            self._dispatch_t[(j.jid, k)] = time.monotonic()
            self._load[w] += 1
            j.next_k += 1
            now = time.monotonic()
            if j.first_dispatch_t is None:
                j.first_dispatch_t = now
            for r in j.requests:
                if r.queue_s is None:
                    r.queue_s = now - r.t_admit
            self._pump(j)

    def _pump(self, j: _Job) -> None:
        """Send composed states and draw offsets for chunks whose
        predecessors have reported — the serial scans of the chunk
        graph, identical to the chunk-graph master."""
        while j.state_sent < j.next_k and j.state_sent in j.state_at:
            k = j.state_sent
            w = self._inflight.get((j.jid, k))
            if w is None:
                break
            j.sent_state[k] = j.state_at[k] or {}
            self._task_qs[w].put(("state", j.jid, k, k * self.C,
                                  (k + 1) * self.C, j.sent_state[k]))
            j.state_sent += 1
        while j.draws_sent < j.next_k and j.draws_sent in j.deltas:
            k = j.draws_sent
            w = self._inflight.get((j.jid, k))
            if w is None:
                break
            msg = {}
            for mn, mem in j.mems.items():
                geo = j.geos[mn]
                entry = {"base": j.cum_draws[mn]}
                if mem.backing_hit_rate > 0.0:
                    j.cum_draws[mn] += j.deltas[k][geo][2] \
                        if geo is not None else j.n_addrs[k]
                if geo is not None:
                    h, m = j.geo_cum[geo]
                    entry["hits_after"] = h + j.deltas[k][geo][0]
                    entry["misses_after"] = m + j.deltas[k][geo][1]
                msg[mn] = entry
            for geo, d in j.deltas[k].items():
                h, m = j.geo_cum[geo]
                j.geo_cum[geo] = (h + d[0], m + d[1])
            j.sent_draws[k] = msg
            self._task_qs[w].put(("draws", j.jid, k, msg))
            del j.deltas[k]
            j.n_addrs.pop(k, None)
            j.effects.pop(k, None)
            j.draws_sent += 1
        for i in [i for i in j.state_at
                  if i < j.state_sent and i + 1 in j.state_at]:
            del j.state_at[i]

    # -- worker replies ------------------------------------------------------

    def _on_worker_msg(self, msg) -> None:
        kind = msg[0]
        if kind == "error":
            _, wid, jid, k, tb = msg
            self._busy_s[wid] += 0.0
            j = self._jobs.get(jid)
            if j is not None and not j.failed:
                self._fail_job(j, f"worker {wid} raised:\n{tb}")
            return
        _, wid, jid, k, *rest = msg
        self._busy_s[wid] += rest[-1]
        j = self._jobs.get(jid)
        if j is None or j.failed:
            if kind == "done":
                if wid == self._inflight.get((jid, k)):
                    self._inflight.pop((jid, k))
                    self._load[wid] = max(0, self._load[wid] - 1)
                elif wid == self._spec.get((jid, k)):
                    self._spec.pop((jid, k))
                    self._load[wid] = max(0, self._load[wid] - 1)
            return
        if kind == "effect":
            eff, na = rest[0], rest[1]
            if k + 1 in j.state_at or k < j.draws_sent:
                return  # duplicate from a retried chunk
            from ..core.chunkgraph import _compose_state
            j.effects[k] = eff
            j.n_addrs[k] = na
            while (k + 1 not in j.state_at) and k in j.state_at \
                    and k in j.effects:
                j.state_at[k + 1] = _compose_state(j.state_at[k],
                                                   j.effects.pop(k))
                k += 1
            self._pump(j)
        elif kind == "replay":
            if k >= j.draws_sent:
                j.deltas[k] = rest[0]
            self._pump(j)
        elif kind == "done":
            key = (j.jid, k)
            from_spec = False
            if wid == self._inflight.get(key):
                self._inflight.pop(key)
                self._load[wid] = max(0, self._load[wid] - 1)
                t0 = self._dispatch_t.pop(key, None)
                if self._spec_policy is not None and t0 is not None:
                    self._spec_policy.observe(time.monotonic() - t0)
            elif wid == self._spec.get(key):
                from_spec = True
                self._spec.pop(key)
                self._load[wid] = max(0, self._load[wid] - 1)
            if k >= j.committed and k not in j.done_buf:
                if from_spec and self._spec_policy is not None:
                    # the duplicate beat the straggler to the commit
                    self._spec_policy.wins += 1
                    self._stats["speculative_wins"] += 1
                j.done_buf[k] = (rest[0], rest[1])
                j.sent_state.pop(k, None)
                j.sent_draws.pop(k, None)
                self._commit(j)

    def _commit(self, j: _Job) -> None:
        while j.committed in j.done_buf:
            k = j.committed
            cums, inline = j.done_buf.pop(k)
            j.cums_hist[k] = cums
            if any(v is not None for v in inline.values()):
                nb = sum(v["ops"].nbytes
                         + (v["hits"].nbytes if v["hits"] is not None
                            else 0)
                         + (v["visits"].nbytes
                            if v["visits"] is not None else 0)
                         for v in inline.values() if v is not None)
                j.inline_hist[k] = (nb, inline)
                j.inline_bytes += nb
                while j.inline_bytes > self.inline_cap \
                        and len(j.inline_hist) > 1:
                    old, (ob, _) = j.inline_hist.popitem(last=False)
                    j.inline_bytes -= ob
                    j.inline_dropped.add(old)
            j.committed += 1
            self._rc.note_chunks(cold=1)
            for r in list(j.requests):
                if not r.done and r.next_notify == k \
                        and k < r.n_chunks:
                    if self._notify(j, r, k):
                        self._finish_if_served(j, r)
            if faults.active():
                # chaos: die mid-stream *after* committing chunk N —
                # the record is on disk, the journal holds the job, and
                # clients must fail over to the committed prefix
                faults.maybe_kill("daemon_kill", chunk=j.committed)
        self._maybe_retire(j)

    def _notify(self, j: _Job, r: _Request, k: int) -> bool:
        """Stream one committed chunk to one request (translated to the
        request's model names).  Returns False when the request had to
        be failed (evicted inline history)."""
        cums = j.cums_hist[k]
        if k in j.inline_dropped:
            self._fail_request(
                j, r, f"inline history for chunk {k} evicted "
                      f"(raise inline_history_mb)")
            return False
        entry = j.inline_hist.get(k)
        inline = entry[1] if entry is not None else {}
        self._send(r.conn, {
            "type": "chunk", "req": r.req, "idx": k,
            "cums": {rmn: cums[jmn] for rmn, jmn in r.names.items()},
            "inline": {rmn: inline.get(jmn)
                       for rmn, jmn in r.names.items()}})
        r.next_notify = k + 1
        return True

    def _finish_if_served(self, j: _Job, r: _Request) -> None:
        if r.done or r.next_notify < r.n_chunks:
            return
        r.done = True
        now = time.monotonic()
        r.record["queue_s"] = round(r.queue_s or 0.0, 4)
        r.record["resolve_s"] = round(now - r.t_admit, 4)
        self._req_log.append(r.record)
        del self._req_log[:-64]
        self._journal.append({"ev": "req", "record": dict(r.record)})
        self._send(r.conn, {"type": "done", "req": r.req})
        self._maybe_retire(j)

    def _maybe_retire(self, j: _Job) -> None:
        """A job with nothing left to dispatch or commit releases its
        worker-side resolvers; the daemon keeps its history so later
        identical requests still attach (and can extend it)."""
        if j.failed or j.next_k < j.sched_upto:
            return
        # completion is a property of the committed range alone — a
        # speculative loser still straggling in-flight must not delay
        # the job_done journal entry or the completion counter
        if j.committed >= j.sched_upto and \
                j.sched_upto > max(j.first_live, j.completions):
            j.completions = j.sched_upto
            j.keep_alive = False
            self._stats["jobs_completed"] += 1
            self._journal.append({"ev": "job_done", "jid": j.jid})
            self._journal.drop_payload(j.jid)
            self._journal_stats()
        if any(key[0] == j.jid for key in self._inflight) or \
                any(key[0] == j.jid for key in self._spec):
            return
        for w, known in enumerate(self._known):
            if j.jid in known:
                self._task_qs[w].put(("forget", j.jid))
                known.discard(j.jid)

    def _fail_request(self, j: _Job, r: _Request, reason: str) -> None:
        r.done = True
        self._send(r.conn, {"type": "failed", "req": r.req,
                            "reason": reason})
        if r in j.requests:
            j.requests.remove(r)

    def _fail_job(self, j: _Job, reason: str) -> None:
        j.failed = True
        j.keep_alive = False
        self._stats["jobs_failed"] += 1
        self._journal.append({"ev": "job_failed", "jid": j.jid})
        self._journal.drop_payload(j.jid)
        for r in list(j.requests):
            if not r.done:
                self._fail_request(j, r, reason)
        for key in [key for key in self._inflight if key[0] == j.jid]:
            w = self._inflight.pop(key)
            self._dispatch_t.pop(key, None)
            self._load[w] = max(0, self._load[w] - 1)
        for key in [key for key in self._spec if key[0] == j.jid]:
            w = self._spec.pop(key)
            self._load[w] = max(0, self._load[w] - 1)
        for w, known in enumerate(self._known):
            if j.jid in known:
                try:
                    self._task_qs[w].put(("forget", j.jid))
                except Exception:
                    pass
                known.discard(j.jid)
        self._by_keyset.pop(j.keyset, None)

    # -- worker health -------------------------------------------------------

    def _check_workers(self) -> None:
        dead = [w for w, p in enumerate(self._procs)
                if not p.is_alive()]
        if not dead or self._stop_evt.is_set():
            return
        self._stats["worker_restarts"] += len(dead)
        # a dead speculative copy just disappears (the primary is still
        # on it); a dead *primary* with a live speculative copy promotes
        # the copy instead of re-dispatching
        for key in [key for key, w in self._spec.items() if w in dead]:
            del self._spec[key]
        redo = []
        for key, w in sorted(self._inflight.items()):
            if w not in dead:
                continue
            sw = self._spec.pop(key, None)
            if sw is not None:
                self._inflight[key] = sw
                self._dispatch_t[key] = time.monotonic()
            else:
                redo.append(key + (w,))
        self._rc.note_worker_retries(len(redo))
        self._stats["chunk_retries"] += len(redo)
        for w in dead:
            # the old queue's feeder thread may be wedged on a pipe
            # whose reader died mid-write; never join it at exit
            old = self._task_qs[w]
            old.cancel_join_thread()
            old.close()
            self._task_qs[w] = self._ctx.Queue()
            self._procs[w] = self._ctx.Process(
                target=worker_main,
                args=(w, self.C, self._task_qs[w], self._result_q,
                      self._cfg),
                daemon=True)
            self._procs[w].start()
            self._known[w] = set()
            self._load[w] = 0
        over_budget = set()
        for jid, k, w in redo:
            j = self._jobs.get(jid)
            if j is None or j.failed or jid in over_budget:
                self._inflight.pop((jid, k), None)
                self._dispatch_t.pop((jid, k), None)
                continue
            if k < j.committed:
                # a speculative copy already committed this chunk; the
                # straggler died afterwards — nothing to redo
                self._inflight.pop((jid, k), None)
                self._dispatch_t.pop((jid, k), None)
                continue
            j.retries += 1
            if j.retries > self.retry_budget:
                over_budget.add(jid)
                self._fail_job(
                    j, f"worker(s) {dead} died; retry budget "
                       f"exhausted ({j.retries} > {self.retry_budget})")
                continue
            if jid not in self._known[w]:
                self._task_qs[w].put(("job", jid, j.payload))
                self._known[w].add(jid)
            self._task_qs[w].put(("task", jid, k, k * self.C,
                                  (k + 1) * self.C))
            if k < j.state_sent:
                self._task_qs[w].put(("state", jid, k, k * self.C,
                                      (k + 1) * self.C,
                                      j.sent_state[k]))
            if k < j.draws_sent:
                self._task_qs[w].put(("draws", jid, k,
                                      j.sent_draws[k]))
            self._dispatch_t[(jid, k)] = time.monotonic()
            self._load[w] += 1

    def _check_deadlines(self, now: float) -> None:
        """Fail requests past their deadline (1 Hz).  The request's
        chunks keep resolving if anyone else — or the journal's
        keep-alive — still wants them; otherwise the undispatched tail
        is cancelled, exactly like a client disconnect."""
        for j in list(self._jobs.values()):
            expired = [r for r in j.requests
                       if not r.done and r.deadline is not None
                       and now > r.deadline]
            for r in expired:
                self._stats["deadline_failures"] += 1
                self._fail_request(
                    j, r, f"deadline exceeded "
                          f"({now - r.t_admit:.1f}s elapsed)")
            if expired:
                self._cancel_unneeded(j)

    def _check_stragglers(self, now: float) -> None:
        """Speculative re-dispatch (1 Hz): a chunk whose wall exceeds
        the policy threshold gets a duplicate dispatch on another
        worker — task, state, and draws replayed verbatim, which is
        only possible once all three were sent (a phase-C straggler:
        the heavy phase).  Both copies compute identical bits; the
        first ``done`` commits, the loser's is discarded by the
        ordinary duplicate guards."""
        pol = self._spec_policy
        if pol is None:
            return
        for key, w in list(self._inflight.items()):
            if key in self._spec:
                continue
            jid, k = key
            j = self._jobs.get(jid)
            if j is None or j.failed:
                continue
            if k not in j.sent_state or k not in j.sent_draws:
                continue  # not yet in phase C: nothing to replay
            t0 = self._dispatch_t.get(key)
            if t0 is None or not pol.overdue(now - t0):
                continue
            if len(self._spec) >= pol.max_inflight:
                break
            cands = [i for i in range(self.workers)
                     if i != w and self._load[i] < _WINDOW
                     and self._procs[i].is_alive()]
            if not cands:
                break
            w2 = min(cands, key=lambda i: self._load[i])
            if jid not in self._known[w2]:
                self._task_qs[w2].put(("job", jid, j.payload))
                self._known[w2].add(jid)
            self._task_qs[w2].put(("task", jid, k, k * self.C,
                                   (k + 1) * self.C))
            self._task_qs[w2].put(("state", jid, k, k * self.C,
                                   (k + 1) * self.C, j.sent_state[k]))
            self._task_qs[w2].put(("draws", jid, k, j.sent_draws[k]))
            self._spec[key] = w2
            self._load[w2] += 1
            pol.issued += 1
            self._stats["speculative_dispatches"] += 1

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        up = max(1e-9, time.monotonic() - self._t0)
        # counters are reported as journal base + current lifetime, so
        # `serve stats` is monotone across daemon restarts
        s = {k: v + self._base.get(k, 0)
             for k, v in self._stats.items()}
        total = s["dedup_store"] + s["dedup_inflight"] + s["dedup_cold"]
        return {
            "address": self.address,
            "uptime_s": round(up, 3),
            "workers": self.workers,
            "chunk_iters": self.C,
            "clients": len(self._clients),
            "jobs_active": sum(1 for j in self._jobs.values()
                               if j.live()),
            "queued_chunks": sum(max(0, j.sched_upto - j.next_k)
                                 for j in self._jobs.values()
                                 if not j.failed),
            "inflight_chunks": len(self._inflight),
            "utilization": [round(b / up, 4) for b in self._busy_s],
            "dedup": {
                "store_chunks": s["dedup_store"],
                "inflight_chunks": s["dedup_inflight"],
                "cold_chunks": s["dedup_cold"],
                "hit_rate": round(
                    (s["dedup_store"] + s["dedup_inflight"])
                    / total, 4) if total else 0.0},
            "admission": {
                "accepted": s["accepted"], "rejected": s["rejected"],
                "max_queued_chunks": self.max_queued_chunks,
                "max_client_chunks": self.max_client_chunks},
            "failures": {
                "worker_restarts": s["worker_restarts"],
                "chunk_retries": s["chunk_retries"],
                "jobs_failed": s["jobs_failed"],
                "cancelled_chunks": s["cancelled_chunks"],
                "deadline_failures": s["deadline_failures"]},
            "speculation": (dict(self._spec_policy.snapshot(),
                                 issued=s["speculative_dispatches"],
                                 wins=s["speculative_wins"])
                            if self._spec_policy is not None else None),
            "journal": {
                "enabled": self._journal.enabled,
                "restarts": self._restarts,
                "resumed_jobs": s["resumed_jobs"]},
            "faults_injected": faults.stats(),
            "jobs_completed": s["jobs_completed"],
            "requests": list(self._req_log),
            "census": self._rc.census(),
        }

"""Append-only journal of the resolution daemon.

The daemon is a scheduling layer over a store that already holds every
*committed* result, so durability needs very little: enough to (a) keep
``serve stats`` counters monotone across restarts, and (b) let a
restarted daemon *finish* jobs that were in flight when it died —
"re-attach from store prefixes": the store's contiguous prefix says
which chunks survived, the journal says which jobs wanted how many.

Layout (under ``<store_dir>/.serve-journal/``):

* ``journal.jsonl`` — one JSON event per line:

  ===========  ============================================================
  ``start``    a daemon lifetime began (``pid``); the count of these is
               the restart counter
  ``job``      a job was admitted or extended: ``jid``, ``keys`` (model →
               v3 key), ``seed``, ``n_iters``, ``n_chunks`` (the demand
               high-water); fsynced — an un-journaled job is a lost job
  ``job_done`` the job committed every demanded chunk (its payload blob
               is deleted)
  ``job_failed``  the job failed permanently
  ``req``      one completed request record (the ``serve stats`` log)
  ``stats``    cumulative counter snapshot (base + current lifetime),
               so replay just takes the last one
  ===========  ============================================================

* ``job-<jid>.payload`` — the job's cloudpickled stage/model payload,
  exactly the bytes the client shipped; a restarted daemon re-creates
  the job from it and resolves the remainder with no client attached.

Replay is a single forward scan; a torn final line (the daemon died
mid-append) is skipped.  The journal never holds results — corrupting
it can lose *counters* and orphan *pending work*, never bits.
"""

from __future__ import annotations

import json
import os
import tempfile


class Journal:
    def __init__(self, store_dir: str, enabled: bool = True):
        self.enabled = enabled
        self.dir = os.path.join(store_dir, ".serve-journal")
        self.path = os.path.join(self.dir, "journal.jsonl")
        if enabled:
            os.makedirs(self.dir, exist_ok=True)

    # -- writing -------------------------------------------------------------

    def append(self, ev: dict, sync: bool = False) -> None:
        if not self.enabled:
            return
        try:
            with open(self.path, "a") as f:
                f.write(json.dumps(ev, sort_keys=True) + "\n")
                if sync:
                    f.flush()
                    os.fsync(f.fileno())
        except OSError:
            pass  # journaling is best-effort; serving never stops for it

    def payload_path(self, jid: int) -> str:
        return os.path.join(self.dir, f"job-{jid}.payload")

    def save_payload(self, jid: int, payload: bytes) -> None:
        if not self.enabled:
            return
        try:
            fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.payload_path(jid))
        except OSError:
            pass

    def load_payload(self, jid: int) -> bytes | None:
        try:
            with open(self.payload_path(jid), "rb") as f:
                return f.read()
        except OSError:
            return None

    def drop_payload(self, jid: int) -> None:
        try:
            os.unlink(self.payload_path(jid))
        except OSError:
            pass

    # -- replay --------------------------------------------------------------

    def replay(self) -> dict:
        """Scan the journal: ``{starts, base_stats, open_jobs, req_log,
        max_jid}``.  ``open_jobs`` maps jid → the latest ``job`` event
        of every job without a terminal event (the restarted daemon's
        re-attach worklist)."""
        starts = 0
        base: dict = {}
        open_jobs: dict[int, dict] = {}
        req_log: list[dict] = []
        max_jid = 0
        if not self.enabled or not os.path.exists(self.path):
            return {"starts": 0, "base_stats": {}, "open_jobs": {},
                    "req_log": [], "max_jid": 0}
        try:
            with open(self.path) as f:
                for line in f:
                    try:
                        ev = json.loads(line)
                    except ValueError:
                        continue  # torn tail line from a crash
                    t = ev.get("ev")
                    if t == "start":
                        starts += 1
                    elif t == "job":
                        jid = int(ev["jid"])
                        open_jobs[jid] = ev
                        max_jid = max(max_jid, jid)
                    elif t in ("job_done", "job_failed"):
                        open_jobs.pop(int(ev["jid"]), None)
                    elif t == "req":
                        req_log.append(ev.get("record", {}))
                        del req_log[:-64]
                    elif t == "stats":
                        base = dict(ev.get("stats", {}))
        except OSError:
            pass
        return {"starts": starts, "base_stats": base,
                "open_jobs": open_jobs, "req_log": req_log,
                "max_jid": max_jid}

    def compact(self) -> None:
        """Rewrite the journal to just the current replay state — called
        on clean startup so the file stays O(open jobs), not O(history).
        Counter snapshots and request history survive (re-serialized);
        per-lifetime ``start`` events collapse into a count carried by a
        synthetic stats snapshot's ``restarts`` key handled by the
        daemon, so this only rewrites events replay actually reads."""
        if not self.enabled:
            return
        rep = self.replay()
        try:
            fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                for _ in range(rep["starts"]):
                    f.write(json.dumps({"ev": "start"}) + "\n")
                if rep["base_stats"]:
                    f.write(json.dumps(
                        {"ev": "stats", "stats": rep["base_stats"]},
                        sort_keys=True) + "\n")
                for rec in rep["req_log"]:
                    f.write(json.dumps({"ev": "req", "record": rec},
                                       sort_keys=True) + "\n")
                for ev in rep["open_jobs"].values():
                    f.write(json.dumps(ev, sort_keys=True) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except OSError:
            pass

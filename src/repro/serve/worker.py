"""Pool worker of the resolution daemon.

A *serve* worker is the chunk-graph worker generalized from one run to
many: it multiplexes chunks of several concurrent **jobs** (one job per
distinct resolution key set) and processes each phase as a separate
message instead of blocking for the master's replies — the daemon's
scheduler interleaves phases of different jobs on one worker, so a long
Floyd–Warshall tail from one client backfills with another client's
chunks.

Phase messages (daemon → worker):

* ``("job", jid, payload)`` — install a job context: the cloudpickled
  stage list + live memory models + seed, a shared resolver, and the
  v3 chunk writers.
* ``("task", jid, k, lo, hi)`` — phases A+B fused: **one** empty-cache
  replay yields the chunk's own cache effect (state-free, freely
  parallel) plus its hit flags up to a small boundary-ambiguity table;
  the fused scratch is saved per ``(jid, k)`` so later phases survive
  interleaving with other chunks.  The effect is also persisted as a
  rescache effect record (``<key>.eNNNNN.npz``) when the job has a v3
  key.
* ``("state", jid, k, lo, hi, st)`` — finalize: patch the ambiguous
  verdicts against the composed incoming state (no second replay) and
  snapshot what phase C consumes (hit flags, flattened participation,
  *end-of-chunk* cache stacks).
* ``("draws", jid, k, msg)`` — phase C: position each model's PCG64
  stream at its absolute draw offset, materialize latencies, commit the
  v3 chunk record (or return the matrix inline past the artifact cap).
* ``("forget", jid)`` / ``("stop",)`` — drop a job / exit.

Chunks are resolved on the **canonical full-chunk grid** (``hi`` is
always a multiple of ``CHUNK_ITERS``; traces pad with −1 past their
end), so every committed record is a full chunk: any client's shorter
``n_iters`` is served as a prefix of the same bits, and a later client
extending the job never meets a poisoned partial tail.  Results are
draw-for-draw identical to the streaming engine.
"""

from __future__ import annotations

import time
import traceback

import numpy as np

from . import faults


def worker_main(wid: int, C: int, task_q, result_q,
                rescache_cfg: dict) -> None:
    jid = k = -1
    try:
        import cloudpickle
        from ..core import rescache as _rc
        from ..core.simulator import _SharedResolver, _lat_itemsize
        _rc.configure(**rescache_cfg)
        _rc.CHUNK_ITERS = C
    except Exception:  # noqa: BLE001 — forwarded verbatim
        result_q.put(("error", wid, jid, k, traceback.format_exc()))
        return
    jobs: dict[int, dict] = {}
    scratch: dict[tuple[int, int], dict] = {}
    while True:
        m = task_q.get()
        op = m[0]
        if op == "stop":
            return
        t0 = time.perf_counter()
        try:
            if op == "job":
                _, jid, payload = m
                p = cloudpickle.loads(payload)
                resolver = _SharedResolver(p["stages"], p["mems"],
                                           p["seed"], capture=True)
                writers = {mn: _rc.ChunkWriter(
                    key, resolver.K, p["n_iters"],
                    itemsize=_lat_itemsize(p["mems"][mn]))
                    for mn, key in p["keys"].items() if key is not None}
                jobs[jid] = {
                    "resolver": resolver,
                    "writers": {mn: w for mn, w in writers.items()
                                if not w.dead},
                    "mems": p["mems"],
                    "effect_keys": {
                        mn: key for mn, key in p["keys"].items()
                        if key is not None
                        and resolver.cache_keys[mn] is not None},
                }
            elif op == "forget":
                _, jid = m
                jobs.pop(jid, None)
                for sk in [sk for sk in scratch if sk[0] == jid]:
                    del scratch[sk]
            elif op == "task":
                _, jid, k, lo, hi = m
                if faults.active():  # chaos: die / straggle mid-chunk
                    faults.maybe_kill("worker_kill", worker=wid,
                                      chunk=k)
                j = jobs[jid]
                r = j["resolver"]
                effects, n_addrs = r.chunk_effects_fused(lo, hi)
                for mn, ekey in j["effect_keys"].items():
                    geo = r.cache_keys[mn]
                    if geo is not None and geo in effects:
                        _rc.put_effect(ekey, k, effects[geo], n_addrs)
                # the fused replay scratch, snapshotted before another
                # chunk's task overwrites the resolver
                scratch[(jid, k)] = {
                    "lo": lo, "hi": hi,
                    "fused": r._fused,
                    "store_flat": r._store_flat,
                    "n_addrs": r._n_addrs,
                    "flat_p": r._flat_p,
                    "burst_words": r._burst_words,
                }
                result_q.put(("effect", wid, jid, k, effects, n_addrs,
                              time.perf_counter() - t0))
            elif op == "state":
                _, jid, k, lo, hi, st = m
                r = jobs[jid]["resolver"]
                sc = scratch[(jid, k)]
                r._fused = sc["fused"]
                r._store_flat = sc["store_flat"]
                r._n_addrs = sc["n_addrs"]
                r._flat_p = sc["flat_p"]
                r._burst_words = sc["burst_words"]
                deltas = r.finalize_replay(st)
                # everything phase C consumes, completed with the
                # finalize outputs: the hit flags *and* the
                # end-of-chunk cache stacks (the record's resume state)
                sc["hits_by_key"] = r._hits_by_key
                sc["end"] = {geo: sim.export_stacks()
                             for geo, sim in r.caches.items()}
                sc.pop("fused", None)
                result_q.put(("replay", wid, jid, k, deltas,
                              time.perf_counter() - t0))
            elif op == "draws":
                _, jid, k, msg = m
                if faults.active():
                    # phase C is the heavy phase (draw materialization
                    # + record write): a straggler here stalls the
                    # commit watermark — exactly what the daemon's
                    # speculative re-dispatch exists to absorb
                    faults.maybe_sleep("straggler", worker=wid,
                                       chunk=k)
                j = jobs[jid]
                r = j["resolver"]
                sc = scratch.pop((jid, k))
                lo, hi = sc["lo"], sc["hi"]
                r._store_flat = sc["store_flat"]
                r._hits_by_key = sc["hits_by_key"]
                r._n_addrs = sc["n_addrs"]
                r._flat_p = sc["flat_p"]
                r._burst_words = sc["burst_words"]
                for mn, cum in msg.items():
                    r.import_resume(mn, {}, {"draws": cum["base"]})
                r.finish(lo, hi, fold=False)
                cums: dict[str, dict] = {}
                inline: dict[str, dict | None] = {}
                for mn in j["mems"]:
                    geo = r.cache_keys[mn]
                    cum = {"draws": r.draws[mn]}
                    if geo is not None:
                        cum["hits"] = msg[mn]["hits_after"]
                        cum["misses"] = msg[mn]["misses_after"]
                        cum["max_tag"] = sc["end"][geo][1]
                    cums[mn] = cum
                    hb = vb = None
                    if r.last_hits.get(mn) is not None:
                        hb = _rc.pack_flags(r.last_hits[mn])
                        vb = _rc.pack_flags(r.last_visits[mn])
                    w = j["writers"].get(mn)
                    if w is not None and k < w.max_chunks:
                        states = {}
                        if geo is not None:
                            states["cache"] = sc["end"][geo][0]
                        w.add(k, hi - lo,
                              np.ascontiguousarray(r.last_ops[mn]),
                              hb, vb, states, cum)
                        inline[mn] = None  # clients read the record
                    else:
                        # no writer / past the artifact cap: the matrix
                        # (and the planes, for mid-chunk cache stats)
                        # rides back inline through the daemon
                        inline[mn] = {
                            "ops": _rc.shrink_ops(r.last_ops[mn]),
                            "hits": hb, "visits": vb}
                result_q.put(("done", wid, jid, k, cums, inline,
                              time.perf_counter() - t0))
        except Exception:  # noqa: BLE001 — the daemon fails the job,
            result_q.put(  # the worker keeps serving its other jobs
                ("error", wid, jid, k, traceback.format_exc()))

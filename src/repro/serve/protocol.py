"""Wire protocol of the resolution daemon: length-prefixed pickled
dicts over a local socket.

The daemon and its clients are cooperating processes of one user on one
machine (the socket is a ``AF_UNIX`` path by default, mode 0700 next to
the store; ``host:port`` selects TCP on localhost for containers whose
filesystems do not share a socket path).  Frames are plain ``pickle``
payloads — numpy arrays (inline ops matrices, packed hit planes) ride
along without copies; the *worker payload* inside a resolve request is
additionally ``cloudpickle``-encoded by the client, because the paper
kernels' trace generators are closures (same convention as the
chunk-graph executor).

Message shapes (all dicts; ``type`` selects):

client → daemon
  ``resolve``   keys, mems, seed, n_iters, chunk_iters, store_dir,
                payload (cloudpickle bytes), weight, req (client id)
  ``solved``    req, solve_wall_s — fold+solve wall, for serve stats
  ``cancel``    req
  ``stats`` / ``ping`` / ``shutdown``

daemon → client
  ``accepted``  req, first_live, committed, dedup{store,inflight,cold}
  ``busy``      retry_after_s (admission control; never queues
                unboundedly)
  ``chunk``     req, idx, cums{model: {draws,hits,misses}},
                inline{model: {ops, hits, visits} | None}
  ``done`` / ``failed`` / ``error`` / ``stats`` / ``pong``
"""

from __future__ import annotations

import hashlib
import os
import pickle
import socket
import struct
import tempfile

#: Frames above this size indicate a protocol bug, not a real message
#: (a full Floyd–Warshall inline chunk is ~100 MB; 1 GiB is paranoia).
MAX_FRAME = 1 << 30

_LEN = struct.Struct("!Q")


class ProtocolError(RuntimeError):
    """Malformed frame or closed-mid-frame peer."""


def send_msg(sock: socket.socket, obj: dict) -> None:
    blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(blob)) + blob)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    parts = []
    while n:
        b = sock.recv(min(n, 1 << 20))
        if not b:
            raise ProtocolError("peer closed mid-frame")
        parts.append(b)
        n -= len(b)
    return b"".join(parts)


def recv_msg(sock: socket.socket) -> dict:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > MAX_FRAME:
        raise ProtocolError(f"frame of {n} bytes exceeds MAX_FRAME")
    return pickle.loads(_recv_exact(sock, n))


# -- addresses --------------------------------------------------------------

def is_inet(address: str) -> bool:
    """``host:port`` selects TCP; anything else is an AF_UNIX path."""
    host, sep, port = address.rpartition(":")
    return bool(sep) and port.isdigit() and "/" not in address


def default_address(store_dir: str | None = None) -> str:
    """The canonical daemon socket for one rescache store: a short
    ``AF_UNIX`` path in the temp dir keyed by the store directory (unix
    socket paths are limited to ~100 bytes, so the socket cannot live
    *inside* arbitrarily deep store paths) and the uid (sockets are
    per-user).  One store ⇒ one daemon ⇒ one global scheduler."""
    from ..core import rescache as _rc
    d = store_dir if store_dir is not None else (_rc._dir() or "")
    digest = hashlib.blake2b(os.path.abspath(d).encode(),
                             digest_size=8).hexdigest()
    uid = os.getuid() if hasattr(os, "getuid") else 0
    return os.path.join(tempfile.gettempdir(),
                        f"repro-serve-{uid}-{digest}.sock")


def connect(address: str, timeout: float | None = 30.0) -> socket.socket:
    if is_inet(address):
        host, _, port = address.rpartition(":")
        s = socket.create_connection((host or "127.0.0.1", int(port)),
                                     timeout=timeout)
    else:
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.settimeout(timeout)
        s.connect(address)
    s.settimeout(None)
    return s


def listen(address: str) -> socket.socket:
    if is_inet(address):
        host, _, port = address.rpartition(":")
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind((host or "127.0.0.1", int(port)))
    else:
        try:
            os.unlink(address)
        except OSError:
            pass
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.bind(address)
        os.chmod(address, 0o700)
    s.listen(64)
    return s

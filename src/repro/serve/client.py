"""Thin client of the resolution daemon.

:func:`simulate_dataflow_served` is the serve-mode twin of the library
engines: it probes the store, ships the *live* residue of a
``simulate_dataflow_many`` grid to the daemon as one resolution
request, and folds + solves the streamed per-chunk completion records
incrementally — the client does exactly the cheap work (fold, wavefront
solve) while resolution happens in the daemon's shared pool.  Cycle
counts, stall buckets, and cache statistics are bit-identical to
library mode: same records, same fold, same solver.

Everything that prevents serving raises :exc:`ServeUnavailable`
(daemon not running, store mismatch, unpicklable traces, a raced store
eviction mid-stream, …); callers catch it and fall back to the local
engines.  Because the daemon commits ordinary v3 records as it goes,
the fallback rerun is mostly store-served — failure costs latency, not
resolution work.

:func:`ensure_daemon` implements ``--server auto``: connect to the
store's canonical socket or spawn a detached daemon
(``python -m repro.launch.serve daemon``) and wait for it to answer
pings.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import subprocess
import sys
import time

import numpy as np

from . import faults, protocol


class ServeUnavailable(RuntimeError):
    """Serving is not possible / failed mid-run — run locally instead."""


_REQ_COUNTER = itertools.count()


def _env_f(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


@dataclasses.dataclass(frozen=True)
class ServeTimeouts:
    """Client-side timeout/backoff knobs.

    Resolution order: explicit argument > :func:`configure_timeouts` >
    ``REPRO_SERVE_*`` environment > defaults.  ``CompileOptions.serve``
    feeds the same knobs from the compile-options side (the driver
    converts a :class:`repro.dataflow.options.ServeOptions` into one of
    these).  ``max_wait_s`` is a **cumulative** budget across connect
    retries *and* busy-backpressure retries of one request — not
    per-attempt — so a client's worst-case patience is bounded.
    ``deadline_s`` (optional) rides the resolve request to the daemon,
    which fails the request server-side once exceeded (the client then
    falls back to library mode)."""

    connect_timeout_s: float = 10.0
    request_timeout_s: float = 600.0
    max_wait_s: float = 60.0
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    deadline_s: float | None = None

    @classmethod
    def from_env(cls) -> "ServeTimeouts":
        dl = _env_f("REPRO_SERVE_DEADLINE_S", 0.0)
        return cls(
            connect_timeout_s=_env_f("REPRO_SERVE_CONNECT_TIMEOUT_S",
                                     cls.connect_timeout_s),
            request_timeout_s=_env_f("REPRO_SERVE_TIMEOUT_S",
                                     cls.request_timeout_s),
            max_wait_s=_env_f("REPRO_SERVE_MAX_WAIT_S", cls.max_wait_s),
            backoff_base_s=_env_f("REPRO_SERVE_BACKOFF_BASE_S",
                                  cls.backoff_base_s),
            backoff_cap_s=_env_f("REPRO_SERVE_BACKOFF_CAP_S",
                                 cls.backoff_cap_s),
            deadline_s=dl if dl > 0 else None)


_timeouts: ServeTimeouts | None = None


def configure_timeouts(timeouts: ServeTimeouts | None = None,
                       **kw) -> ServeTimeouts:
    """Install process-wide client timeouts (the driver calls this when
    ``CompileOptions.serve`` is set; ``None`` + no kwargs resets to the
    environment).  Returns the effective config."""
    global _timeouts
    if timeouts is None and kw:
        timeouts = dataclasses.replace(ServeTimeouts.from_env(), **kw)
    _timeouts = timeouts
    return _timeouts or ServeTimeouts.from_env()


def _cfg(timeouts: ServeTimeouts | None) -> ServeTimeouts:
    return timeouts or _timeouts or ServeTimeouts.from_env()


def _backoff(cfg: ServeTimeouts, attempt: int) -> float:
    """Exponential backoff with deterministic jitter (keyed on pid and
    attempt — two racing clients desynchronize, one client replays)."""
    base = min(cfg.backoff_cap_s, cfg.backoff_base_s * (2 ** attempt))
    j = ((os.getpid() * 2654435761 + attempt * 40503) % 1000) / 1000.0
    return base * (0.5 + 0.5 * j)


def _connect(addr: str, cfg: ServeTimeouts, deadline: float):
    """Connect with backoff + jitter under the cumulative deadline.
    Transient refusals (daemon restarting, listen backlog burst) are
    retried; a hard failure at the deadline raises the last error."""
    attempt = 0
    while True:
        try:
            conn = protocol.connect(addr, timeout=cfg.connect_timeout_s)
            conn.settimeout(cfg.request_timeout_s)
            return conn
        except OSError as e:
            delay = _backoff(cfg, attempt)
            attempt += 1
            if time.monotonic() + delay >= deadline:
                raise ServeUnavailable(
                    f"no daemon at {addr} after {attempt} attempts: "
                    f"{e}") from e
            time.sleep(delay)


def _request(conn, msg, *, cfg: ServeTimeouts | None = None,
             deadline: float | None = None) -> dict:
    """Submit one resolve and honor admission control: ``busy`` replies
    carry a retry-after; give up (→ local fallback) once the cumulative
    deadline would be exceeded."""
    cfg = _cfg(cfg)
    if deadline is None:
        deadline = time.monotonic() + cfg.max_wait_s
    attempt = 0
    while True:
        protocol.send_msg(conn, msg)
        resp = protocol.recv_msg(conn)
        t = resp.get("type")
        if t == "accepted":
            return resp
        if t == "busy":
            delay = max(float(resp.get("retry_after_s", 1.0)),
                        _backoff(cfg, attempt))
            attempt += 1
            if time.monotonic() + delay >= deadline:
                raise ServeUnavailable(
                    f"daemon busy past the {cfg.max_wait_s:.0f}s "
                    f"cumulative wait budget (backpressure)")
            time.sleep(delay)
            continue
        raise ServeUnavailable(
            f"daemon rejected request: {resp.get('reason', resp)}")


def simulate_dataflow_served(
    stages, mems, n_iters, *,
    fifo_depths=(8,), freq_mhz=150.0, seed=0,
    collect_stalls=True, depth_incremental=True,
    address: str | None = None, weight: float = 1.0,
    timeouts: ServeTimeouts | None = None,
):
    """``simulate_dataflow_many`` with resolution delegated to the
    daemon at ``address`` (default: the store's canonical socket)."""
    from ..core import rescache as _rc
    from ..core.simulator import (SimResult, _LaneSolver, _OpFolder,
                                  _ServedOps, _ServeLost)
    if not _rc.enabled(None) or not _rc._dir():
        raise ServeUnavailable("serving requires an enabled rescache "
                               "with a disk store")
    C = _rc.CHUNK_ITERS
    mems = dict(mems)
    stages = list(stages)
    keys: dict[str, str] = {}
    served: dict[str, _ServedOps] = {}
    live: dict[str, object] = {}
    for mn, mem in mems.items():
        key = _rc.resolution_key("dataflow", stages, mem, seed)
        if key is None:
            raise ServeUnavailable(f"model {mn} is not keyable")
        keys[mn] = key
        _, avail = _rc.prefix(key, C)
        if avail >= n_iters:
            served[mn] = _ServedOps(key, n_iters)
        else:
            live[mn] = mem
    if not live:
        raise ServeUnavailable("fully served from the store")
    try:
        import cloudpickle
        payload = cloudpickle.dumps({
            "stages": stages, "mems": live, "seed": seed,
            "n_iters": n_iters,
            "keys": {mn: keys[mn] for mn in live}})
    except Exception as e:  # noqa: BLE001 — unpicklable traces
        raise ServeUnavailable(f"stages will not serialize: {e}") \
            from e

    cfg = _cfg(timeouts)
    wait_deadline = time.monotonic() + cfg.max_wait_s
    addr = address or protocol.default_address()
    conn = _connect(addr, cfg, wait_deadline)
    try:
        req = f"{os.getpid()}.{next(_REQ_COUNTER)}"
        resp = _request(conn, {
            "type": "resolve", "req": req,
            "keys": {mn: keys[mn] for mn in live}, "mems": live,
            "seed": seed, "n_iters": n_iters, "chunk_iters": C,
            "store_dir": _rc._dir(), "payload": payload,
            "weight": weight, "deadline_s": cfg.deadline_s},
            cfg=cfg, deadline=wait_deadline)
        first_live = int(resp["first_live"])
        n_chunks = -(-n_iters // C)
        live_view = {mn: _ServedOps(keys[mn],
                                    min(n_iters, first_live * C))
                     for mn in live} if first_live > 0 else {}

        folder = _OpFolder(stages)
        solvers = {(mn, d): _LaneSolver(stages, d, collect_stalls)
                   for mn in mems for d in fifo_depths}
        depth_order = sorted(set(fifo_depths), reverse=True)
        pending: dict[int, dict] = {}

        n_recv = itertools.count(1)

        def take(idx: int) -> dict:
            while idx not in pending:
                if faults.active():  # chaos harness: lossy client link
                    i = next(n_recv)
                    faults.maybe_sleep("delay_socket", msg=i)
                    faults.maybe_drop(conn, msg=i)
                m = protocol.recv_msg(conn)
                t = m.get("type")
                if t == "chunk":
                    pending[m["idx"]] = m
                elif t == "done":
                    continue
                elif t in ("failed", "error"):
                    raise ServeUnavailable(
                        f"daemon failed request: {m.get('reason')}")
            return pending.pop(idx)

        last_idx = n_chunks - 1
        prev_cum: dict[str, dict] = {}
        last_cum: dict[str, dict] = {}
        tail_planes: dict[str, tuple] = {}
        solve_wall = 0.0
        for k in range(n_chunks):
            lo, hi = k * C, min((k + 1) * C, n_iters)
            msg = take(k) if k >= first_live else None
            t0 = time.perf_counter()
            for mn in mems:
                if mn in served:
                    L = served[mn].chunk(lo, hi)
                    _rc.note_chunks(served=1)
                elif k < first_live:
                    L = live_view[mn].chunk(lo, hi)
                    _rc.note_chunks(served=1)
                else:
                    info = msg["inline"][mn]
                    if info is not None:
                        L = info["ops"][:hi - lo]
                        if k == last_idx:
                            tail_planes[mn] = (info["hits"],
                                               info["visits"])
                    else:
                        # (re)written by the pool just now: skip the
                        # in-process LRU's possibly-stale copy
                        rec = _rc.get_chunk(keys[mn], k, refresh=True)
                        if rec is None:
                            raise _ServeLost(
                                f"served chunk {keys[mn]}.c{k} "
                                f"vanished")
                        L = rec.ops[:hi - lo]
                        if k == last_idx:
                            tail_planes[mn] = (rec.hitbits,
                                               rec.hitbits2)
                    if k == last_idx - 1:
                        prev_cum[mn] = msg["cums"][mn]
                    if k == last_idx:
                        last_cum[mn] = msg["cums"][mn]
                if L.dtype != np.int32:
                    L = L.astype(np.int32)
                res = folder.fold(mems[mn], lo, hi, L)
                warm = None
                for d in depth_order:
                    warm = solvers[(mn, d)].solve_chunk(
                        res, warm=warm if depth_incremental else None)
            solve_wall += time.perf_counter() - t0

        def live_stats(mn: str) -> tuple[int, int]:
            """Exact (hits, misses) at ``n_iters`` for a live model —
            from the streamed cumulative counters when the run ends on
            the canonical grid, else counters + the tail chunk's
            hit/visit planes (the same reconstruction
            ``_ServedOps.stats_upto`` performs on records)."""
            from ..core.simulator import _cache_group_key
            if _cache_group_key(mems[mn]) is None:
                return 0, 0
            if last_idx < first_live:  # whole run inside the prefix
                return _ServedOps(keys[mn], n_iters).stats_upto(n_iters)
            cum = last_cum[mn]
            if n_iters == (last_idx + 1) * C:
                return int(cum["hits"]), int(cum["misses"])
            if last_idx == first_live and first_live > 0:
                rec = _rc.get_chunk(keys[mn], first_live - 1)
                if rec is None:
                    raise _ServeLost("resume record vanished")
                h0 = int(rec.cum.get("hits", 0))
                m0 = int(rec.cum.get("misses", 0))
            elif last_idx == 0:
                h0 = m0 = 0
            else:
                pc = prev_cum[mn]
                h0, m0 = int(pc["hits"]), int(pc["misses"])
            hb, vb = tail_planes[mn]
            if hb is None or vb is None:
                return h0, m0
            K = max(folder.K, 1)
            tail = n_iters - last_idx * C
            h = np.unpackbits(hb, count=C * K)[:tail * K]
            v = np.unpackbits(vb, count=C * K)[:tail * K]
            th, tv = int(h.sum()), int(v.sum())
            return h0 + th, m0 + (tv - th)

        stats = {mn: (served[mn].stats_upto(n_iters) if mn in served
                      else live_stats(mn)) for mn in mems}
        try:
            protocol.send_msg(conn, {"type": "solved", "req": req,
                                     "solve_wall_s": solve_wall})
        except OSError:
            pass  # stats-only ack: never fail a finished run over it
        return {(mn, d): SimResult("dataflow", solver.last_finish,
                                   n_iters, freq_mhz, solver.stall,
                                   *stats[mn])
                for (mn, d), solver in solvers.items()}
    except (_ServeLost, protocol.ProtocolError, OSError, EOFError,
            KeyError) as e:
        # mid-stream daemon death / dropped socket / raced eviction:
        # the caller falls back to library mode and — because every
        # already-streamed chunk was committed to the store — resumes
        # from the committed prefix rather than restarting cold.
        # Count it so fallback is visible, not folklore.
        _rc.note_failover()
        raise ServeUnavailable(f"serving failed mid-run: {e}") from e
    finally:
        try:
            conn.close()
        except OSError:
            pass


def prefetch(stages, mems, n_iters, *, seed=0,
             address: str | None = None, weight: float = 1.0) -> dict:
    """Resolve through the daemon *without* folding: drain the stream
    and return the dedup summary.  A following local run then serves
    from the store — the serve path for the scalar/DSE engines, which
    fold chunk-by-chunk internally.  Best-effort: artifacts past the
    store cap still resolve cold locally."""
    from ..core import rescache as _rc
    from ..core.simulator import _ServeLost
    if not _rc.enabled(None) or not _rc._dir():
        raise ServeUnavailable("serving requires an enabled rescache")
    C = _rc.CHUNK_ITERS
    stages = list(stages)
    keys, live = {}, {}
    for mn, mem in dict(mems).items():
        key = _rc.resolution_key("dataflow", stages, mem, seed)
        if key is None:
            raise ServeUnavailable(f"model {mn} is not keyable")
        _, avail = _rc.prefix(key, C)
        if avail < n_iters:
            keys[mn], live[mn] = key, mem
    if not live:
        return {"store": -(-n_iters // C), "inflight": 0, "cold": 0}
    try:
        import cloudpickle
        payload = cloudpickle.dumps({
            "stages": stages, "mems": live, "seed": seed,
            "n_iters": n_iters, "keys": keys})
    except Exception as e:  # noqa: BLE001
        raise ServeUnavailable(f"stages will not serialize: {e}") \
            from e
    cfg = _cfg(None)
    wait_deadline = time.monotonic() + cfg.max_wait_s
    addr = address or protocol.default_address()
    conn = _connect(addr, cfg, wait_deadline)
    try:
        req = f"{os.getpid()}.{next(_REQ_COUNTER)}"
        resp = _request(conn, {
            "type": "resolve", "req": req, "keys": keys, "mems": live,
            "seed": seed, "n_iters": n_iters, "chunk_iters": C,
            "store_dir": _rc._dir(), "payload": payload,
            "weight": weight}, cfg=cfg, deadline=wait_deadline)
        while True:
            m = protocol.recv_msg(conn)
            t = m.get("type")
            if t == "done":
                break
            if t in ("failed", "error"):
                raise ServeUnavailable(
                    f"daemon failed request: {m.get('reason')}")
        protocol.send_msg(conn, {"type": "solved", "req": req,
                                 "solve_wall_s": 0.0})
        return dict(resp.get("dedup", {}))
    except (_ServeLost, protocol.ProtocolError, OSError,
            EOFError) as e:
        raise ServeUnavailable(f"prefetch failed: {e}") from e
    finally:
        try:
            conn.close()
        except OSError:
            pass


# -- daemon control ----------------------------------------------------------

def ping(address: str | None = None, timeout: float = 2.0) -> bool:
    addr = address or protocol.default_address()
    try:
        s = protocol.connect(addr, timeout=timeout)
        protocol.send_msg(s, {"type": "ping"})
        ok = protocol.recv_msg(s).get("type") == "pong"
        s.close()
        return ok
    except (OSError, protocol.ProtocolError, EOFError):
        return False


def get_stats(address: str | None = None) -> dict:
    addr = address or protocol.default_address()
    try:
        s = protocol.connect(addr, timeout=10.0)
        protocol.send_msg(s, {"type": "stats"})
        out = protocol.recv_msg(s)["stats"]
        s.close()
        return out
    except (OSError, protocol.ProtocolError, EOFError, KeyError) as e:
        raise ServeUnavailable(f"no daemon at {addr}: {e}") from e


def shutdown(address: str | None = None) -> bool:
    addr = address or protocol.default_address()
    try:
        s = protocol.connect(addr, timeout=10.0)
        protocol.send_msg(s, {"type": "shutdown"})
        ok = protocol.recv_msg(s).get("type") == "ok"
        s.close()
        return ok
    except (OSError, protocol.ProtocolError, EOFError):
        return False


def _clear_stale_socket(addr: str) -> None:
    """A crashed daemon leaves its AF_UNIX socket file behind; connect
    then raises ``ECONNREFUSED`` forever.  Since :func:`ping` just said
    nobody answers, an existing path is stale — unlink it so the daemon
    we are about to spawn binds cleanly (its own bind would also clear
    it, but a half-spawned daemon must never unlink a *live* socket,
    which is why this runs only under the spawn lock)."""
    if protocol.is_inet(addr):
        return
    if os.path.exists(addr):
        try:
            os.unlink(addr)
        except OSError:
            pass


def ensure_daemon(address: str | None = None,
                  workers: int | None = None,
                  wait_s: float = 60.0) -> str:
    """``--server auto``: return a live daemon's address, spawning a
    detached one for this store (inheriting the current rescache
    configuration and chunk grid) when none answers.

    The probe-and-spawn sequence holds an ``flock`` on ``<addr>.lock``
    so two racing clients cannot both observe "no daemon" and spawn
    two: the loser blocks on the lock, re-pings, and finds the winner's
    daemon.  Stale socket files from a crashed daemon are unlinked
    under the same lock."""
    import fcntl
    import hashlib
    from ..core import rescache as _rc
    addr = address or protocol.default_address()
    if ping(addr):
        return addr
    if protocol.is_inet(addr):
        lock_path = os.path.join(
            tempfile_dir(), "repro-serve-"
            + hashlib.blake2b(addr.encode(), digest_size=8).hexdigest()
            + ".lock")
    else:
        lock_path = addr + ".lock"
    with open(lock_path, "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        # somebody else may have spawned while we waited for the lock
        if ping(addr):
            return addr
        _clear_stale_socket(addr)
        cmd = [sys.executable, "-m", "repro.launch.serve", "daemon",
               "--socket", addr, "--store-dir", _rc._dir() or ""]
        if workers is not None:
            cmd += ["--workers", str(workers)]
        env = dict(os.environ)
        env["REPRO_CHUNK_ITERS"] = str(_rc.CHUNK_ITERS)
        subprocess.Popen(cmd, stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL,
                         start_new_session=True, env=env)
        deadline = time.monotonic() + wait_s
        while time.monotonic() < deadline:
            if ping(addr, timeout=1.0):
                return addr
            time.sleep(0.2)
    raise ServeUnavailable(f"spawned daemon at {addr} never answered")


def tempfile_dir() -> str:
    import tempfile
    return tempfile.gettempdir()


class ResolutionClient:
    """Object handle over one daemon: the form `Compiled.simulate /
    sweep / explore` and the benchmark drivers plumb through
    ``server=``."""

    def __init__(self, address: str | None = None,
                 weight: float = 1.0):
        self.address = address or protocol.default_address()
        self.weight = weight

    def simulate_many(self, stages, mems, n_iters, **kw):
        return simulate_dataflow_served(stages, mems, n_iters,
                                        address=self.address,
                                        weight=self.weight, **kw)

    def prefetch(self, stages, mems, n_iters, *, seed=0):
        return prefetch(stages, mems, n_iters, seed=seed,
                        address=self.address, weight=self.weight)

    def ping(self) -> bool:
        return ping(self.address)

    def stats(self) -> dict:
        return get_stats(self.address)

    def shutdown(self) -> bool:
        return shutdown(self.address)

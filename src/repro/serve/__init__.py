"""Resolution-as-a-service: the persistent resolution daemon and its
thin client.

The daemon (:class:`~repro.serve.daemon.ResolutionDaemon`) promotes
the resolution layer from a per-process library into a serving tier:
one global work-stealing scheduler over a shared spawn-pool of
chunk-graph workers, with store / in-flight / cold request dedup,
streamed per-chunk results, weighted per-client fairness with
backpressure, and a stats endpoint.  The client
(:mod:`repro.serve.client`) plugs into ``simulate_dataflow_many(...,
server=...)`` — and through it ``Compiled.simulate/sweep/explore`` and
the benchmark drivers' ``--server auto|ADDR``.  See ``docs/serving.md``.
"""

from .client import (ResolutionClient, ServeUnavailable, ensure_daemon,
                     get_stats, ping, prefetch, shutdown,
                     simulate_dataflow_served)
from .daemon import ResolutionDaemon
from .protocol import default_address

__all__ = [
    "ResolutionClient", "ResolutionDaemon", "ServeUnavailable",
    "default_address", "ensure_daemon", "get_stats", "ping",
    "prefetch", "shutdown", "simulate_dataflow_served",
]

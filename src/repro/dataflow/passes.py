"""The pass pipeline of the dataflow compiler driver.

Each pass is a named object with a ``run(ctx)`` method that reads/writes
fields of a shared :class:`CompileContext`.  The default pipeline mirrors
the paper's flow —

    trace → memdep → transform → partition → rewrite → dse → decouple → schedule

(``transform`` is a no-op unless ``options.transforms`` activates the
HLS transformation catalog — see ``repro.dataflow.transforms`` — and
``dse`` is a no-op unless ``options.dse`` opts into partition-space
exploration) — with each step delegating to the corresponding
``repro.core`` function
(the paper-faithful implementations stay in core; this module only
orders and names them).  Pipelines are ordinary immutable value objects:
``default_pipeline().replace("partition", MyPartitionPass())`` swaps a
pass, ``.without("rewrite")`` drops one, ``.insert_after(...)`` adds one.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax

from ..core.cdfg import (CDFG, add_memory_order_edges,
                         annotate_memory_regions)
from ..core.decouple import decouple
from ..core.partition import (duplicate_cheap_rewrite,
                              materialize, merge_costly_boundaries,
                              stage_groups)
from .options import CompileOptions
from .schedule import Schedule


@dataclasses.dataclass
class CompileContext:
    """Mutable state threaded through the pass pipeline."""

    fn: Callable
    example_args: tuple
    options: CompileOptions
    closed_jaxpr: Any = None
    out_tree: Any = None        # treedef of fn's return value
    cdfg: CDFG | None = None
    plan: Any = None            # StagePlan from the partition pass
    partition: Any = None
    program: Any = None         # DecoupledProgram
    schedule: Schedule | None = None
    dse_result: Any = None      # DseResult when the dse pass explored
    timings: dict[str, float] = dataclasses.field(default_factory=dict)
    #: pass name -> verifier findings recorded by the inter-pass hook
    diagnostics: dict[str, list] = dataclasses.field(default_factory=dict)


class Pass:
    """Base class for driver passes; subclasses set ``name``."""

    name = "pass"

    def run(self, ctx: CompileContext) -> None:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{type(self).__name__} {self.name!r}>"


class TracePass(Pass):
    """Front end: jaxpr trace + raw CDFG (SSA data edges only).

    With ``options.loop`` the function is a loop body and carry back-edges
    are added per leaf of the carry example, minus ``nonaliasing_carries``
    (the §III-A user annotation) — the cyclic §III view.
    """

    name = "trace"

    def run(self, ctx: CompileContext) -> None:
        opts = ctx.options
        closed, out_shape = jax.make_jaxpr(
            ctx.fn, return_shape=True)(*ctx.example_args)
        ctx.closed_jaxpr = closed
        ctx.out_tree = jax.tree_util.tree_structure(out_shape)
        carry_pairs: Sequence[tuple[int, int]] = ()
        if opts.loop:
            carry_example = ctx.example_args[0] if ctx.example_args else None
            n_carry = len(jax.tree_util.tree_leaves(carry_example))
            skip = set(opts.nonaliasing_carries)
            carry_pairs = [(i, i) for i in range(n_carry) if i not in skip]
        ctx.cdfg = CDFG.from_jaxpr(
            closed,
            latency_model=opts.latency_model(),
            add_memory_edges=False,
            annotate_regions=False,
            carry_pairs=carry_pairs,
        )


class MemoryDepPass(Pass):
    """§III-A memory-dependence analysis: region discovery + ordering
    edges between memory ops of a shared region."""

    name = "memdep"

    def run(self, ctx: CompileContext) -> None:
        regions = ctx.options.regions_map() or None
        annotate_memory_regions(ctx.cdfg, regions)
        if ctx.options.add_memory_edges:
            add_memory_order_edges(ctx.cdfg)


class TransformPass(Pass):
    """The HLS transformation catalog (``repro.dataflow.transforms``):
    validate ``options.transforms`` against the analyzed CDFG (memdep
    has run, so regions and carry cycles are known) and annotate the
    CDFG with the active config — ``materialize`` / ``derive_channels``
    read it to scale stage II/latency and channel widths, the schedule
    layer rewrites the simulated access streams, and
    :class:`PartitionPass` applies the reassoc split.  No-op when
    ``options.transforms`` is unset or the identity."""

    name = "transform"

    def run(self, ctx: CompileContext) -> None:
        cfg = getattr(ctx.options, "transforms", None)
        if cfg is None or cfg.is_identity:
            ctx.cdfg.transforms = None
            return
        cfg.validate(ctx.cdfg)
        ctx.cdfg.transforms = cfg


class PartitionPass(Pass):
    """Algorithm 1: SCCs → condensation → topo order → stage groups,
    materialized into a Partition with FIFO channels.  When the active
    transform config asks for memory-port re-association, the plan's
    multi-region stages are split by region first."""

    name = "partition"

    def run(self, ctx: CompileContext) -> None:
        ctx.plan = stage_groups(ctx.cdfg, policy=ctx.options.policy)
        cfg = getattr(ctx.cdfg, "transforms", None)
        if cfg is not None and cfg.reassoc:
            from .transforms import split_by_region
            ctx.plan = split_by_region(ctx.cdfg, ctx.plan)
        ctx.partition = materialize(ctx.cdfg, ctx.plan)


class RewritePass(Pass):
    """Post-partition rewrites: cost-aware boundary merging (for the
    ``cost_aware`` policy) and §III-B1 cheap-op duplication; channels are
    re-derived afterwards."""

    name = "rewrite"

    def run(self, ctx: CompileContext) -> None:
        opts = ctx.options
        if opts.policy == "cost_aware" and len(ctx.plan.groups) > 1:
            ctx.plan = merge_costly_boundaries(
                ctx.cdfg, ctx.plan, opts.channel_cost_bytes)
            ctx.partition = materialize(ctx.cdfg, ctx.plan)
        if opts.duplicate_cheap and opts.policy != "fused":
            duplicate_cheap_rewrite(ctx.partition)


class DsePass(Pass):
    """Partition-space design-space exploration (no-op unless
    ``options.dse`` is set): enumerate legal merge/split/duplicate
    re-partitionings of the Algorithm 1 plan, prune against the
    :class:`~repro.dataflow.options.ResourceConstraints` resource model,
    simulate every survivor (synthetic per-region traces — supply real
    traces through ``Compiled.explore``), and re-partition onto the
    constrained-best candidate.  The full exploration is kept on
    ``ctx.dse_result`` / ``Compiled.dse_result``."""

    name = "dse"

    def run(self, ctx: CompileContext) -> None:
        rc = ctx.options.dse
        if rc is None:
            return
        from . import dse as _dse
        result = _dse.explore_plans(
            ctx.cdfg, ctx.plan, constraints=rc,
            duplicate_base=ctx.options.duplicate_cheap)
        ctx.dse_result = result
        best = result.best()
        if best.plan is not None and best is not result.baseline:
            from ..core.partition import (duplicate_cheap_rewrite,
                                          materialize)
            from .transforms import IDENTITY
            ctx.plan = best.plan
            tf = getattr(best, "tf", None)
            ctx.cdfg.transforms = tf if tf is not None \
                and not tf.is_identity else None
            ctx.partition = materialize(
                ctx.cdfg, best.plan,
                transforms=tf if tf is not None else IDENTITY)
            if best.duplicate:
                duplicate_cheap_rewrite(ctx.partition)


class DecouplePass(Pass):
    """Access/execute decoupling: one executable program per stage."""

    name = "decouple"

    def run(self, ctx: CompileContext) -> None:
        ctx.program = decouple(ctx.partition)


class SchedulePass(Pass):
    """Static schedule analysis: per-stage summaries (II, latency,
    memory-in-SCC), channel totals, and the lazily-built systolic
    executor. Feeds ``Compiled.report()`` / ``.simulate()``."""

    name = "schedule"

    def run(self, ctx: CompileContext) -> None:
        ctx.schedule = Schedule.from_program(
            ctx.program, stream_argnums=ctx.options.stream_argnums)


@dataclasses.dataclass(frozen=True)
class PassPipeline:
    """An ordered, inspectable sequence of passes."""

    passes: tuple[Pass, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "passes", tuple(self.passes))
        names = self.names()
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pass names: {names}")

    def names(self) -> list[str]:
        return [p.name for p in self.passes]

    def __iter__(self):
        return iter(self.passes)

    def __getitem__(self, name: str) -> Pass:
        for p in self.passes:
            if p.name == name:
                return p
        raise KeyError(name)

    def index(self, name: str) -> int:
        for i, p in enumerate(self.passes):
            if p.name == name:
                return i
        raise KeyError(name)

    # -- structural edits (return new pipelines) -----------------------------

    def replace(self, name: str, new_pass: Pass) -> "PassPipeline":
        i = self.index(name)
        return PassPipeline(self.passes[:i] + (new_pass,)
                            + self.passes[i + 1:])

    def without(self, name: str) -> "PassPipeline":
        i = self.index(name)
        return PassPipeline(self.passes[:i] + self.passes[i + 1:])

    def insert_after(self, name: str, new_pass: Pass) -> "PassPipeline":
        i = self.index(name)
        return PassPipeline(self.passes[:i + 1] + (new_pass,)
                            + self.passes[i + 1:])

    # -- execution ------------------------------------------------------------

    def run(self, ctx: CompileContext, *, start: int = 0,
            stop: int | None = None) -> CompileContext:
        from . import verify as _verify
        check = _verify.enabled(ctx.options)
        for p in self.passes[start:stop]:
            t0 = time.perf_counter()
            p.run(ctx)
            ctx.timings[p.name] = time.perf_counter() - t0
            if check:
                # inter-pass IR verification: each pass must leave the
                # invariants it is responsible for intact; an error
                # here names the pass that broke them instead of
                # surfacing as a wrong simulation later
                _verify.verify_ctx(ctx, p.name)
        return ctx

    def signature(self) -> tuple:
        """Identity of the pipeline structure, for cache keying."""
        return tuple((p.name, type(p).__module__ + "." + type(p).__qualname__)
                     for p in self.passes)


def default_pipeline() -> PassPipeline:
    return PassPipeline((TracePass(), MemoryDepPass(), TransformPass(),
                         PartitionPass(), RewritePass(), DsePass(),
                         DecouplePass(), SchedulePass()))

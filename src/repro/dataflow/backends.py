"""Pluggable execution backends for compiled dataflow programs.

A backend turns a :class:`~repro.dataflow.driver.Compiled` artifact plus
call arguments into results.  The registry maps names to backend objects;
``Compiled.__call__(... , backend="name")`` dispatches here.  Registering
a new backend is one call::

    @register_backend
    class MyBackend(Backend):
        name = "mine"
        def execute(self, compiled, args): ...

Built-ins:

* ``sequential`` — replay the decoupled stages in topological order
  (bit-exact oracle for the pipelined executors).
* ``emulated``   — the tick/ppermute systolic schedule in Python on one
  device (schedule-exact, used for tests and CPU demos).
* ``systolic``   — the shard_map executor: one pipeline stage per device
  along a ``stage`` mesh axis (needs ``num_stages`` devices).
* ``xla``        — ``jax.jit`` of the original fused function: the
  conventional-accelerator baseline, and the production serving path.
* ``simulate``   — the discrete-event machine model; returns a
  :class:`~repro.dataflow.schedule.SimReport` instead of outputs.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core.decouple import run_stages_sequential


class BackendUnavailableError(RuntimeError):
    """Raised when a backend cannot run in the current environment."""


class Backend:
    """Base class: subclasses set ``name`` and implement ``execute``."""

    name: str = "?"
    kind: str = "execute"  # "execute" backends return fn's outputs

    def is_available(self, compiled: Any) -> bool:
        return True

    def execute(self, compiled: Any, args: Sequence[Any]) -> Any:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<backend {self.name!r} ({self.kind})>"


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Any = None, *, overwrite: bool = False) -> Any:
    """Register a backend instance or class (instantiated with no args).
    Usable as a decorator."""
    if backend is None:
        return lambda b: register_backend(b, overwrite=overwrite)
    inst = backend() if isinstance(backend, type) else backend
    if inst.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {inst.name!r} already registered")
    _REGISTRY[inst.name] = inst
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def execute_backends() -> tuple[str, ...]:
    """Names of backends that produce the function's outputs."""
    return tuple(sorted(n for n, b in _REGISTRY.items()
                        if b.kind == "execute"))


def available_backends(compiled: Any) -> tuple[str, ...]:
    return tuple(sorted(n for n, b in _REGISTRY.items()
                        if b.is_available(compiled)))


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------


def _expand_stream_args(compiled: Any, args: Sequence[Any]) -> list[Any]:
    """Single-shot call → one-microbatch stream: stream args gain a
    leading axis of 1."""
    args = list(args)
    for i in compiled.options.stream_argnums:
        if i < len(args):
            args[i] = jax.tree_util.tree_map(
                lambda a: jnp.asarray(a)[None], args[i])
    return args


@register_backend
class SequentialBackend(Backend):
    name = "sequential"

    def execute(self, compiled: Any, args: Sequence[Any]) -> Any:
        outs = run_stages_sequential(compiled.program, *args)
        return compiled.unflatten_outputs(outs)


@register_backend
class EmulatedBackend(Backend):
    name = "emulated"

    def execute(self, compiled: Any, args: Sequence[Any]) -> Any:
        outs = compiled.schedule.pipeline.run_emulated(
            *_expand_stream_args(compiled, args))
        return compiled.unflatten_outputs([o[0] for o in outs])


@register_backend
class SystolicBackend(Backend):
    """shard_map executor: stage *s* on device *s*; needs one device per
    pipeline stage."""

    name = "systolic"

    def is_available(self, compiled: Any) -> bool:
        return len(jax.devices()) >= compiled.num_stages

    def _runner(self, compiled: Any):
        cached = compiled.runtime_cache.get(self.name)
        if cached is not None:
            return cached
        S = compiled.num_stages
        devices = jax.devices()
        if len(devices) < S:
            raise BackendUnavailableError(
                f"systolic backend needs {S} devices (one per stage), "
                f"have {len(devices)}; set "
                f"--xla_force_host_platform_device_count or use the "
                f"'emulated' backend")
        mesh = Mesh(np.asarray(devices[:S]), ("stage",))
        run = compiled.schedule.pipeline.build_sharded(mesh)
        compiled.runtime_cache[self.name] = run
        return run

    def execute(self, compiled: Any, args: Sequence[Any]) -> Any:
        run = self._runner(compiled)
        outs = run(*_expand_stream_args(compiled, args))
        return compiled.unflatten_outputs([o[0] for o in outs])


@register_backend
class XLABackend(Backend):
    """The fused baseline: hand the whole function to XLA unchanged.  This
    is the production path when the program should run as one kernel —
    the driver still yields the partition/schedule analysis around it."""

    name = "xla"

    def execute(self, compiled: Any, args: Sequence[Any]) -> Any:
        jitted = compiled.runtime_cache.get(self.name)
        if jitted is None:
            jitted = jax.jit(compiled.fn)
            compiled.runtime_cache[self.name] = jitted
        return jitted(*args)


@register_backend
class SimulateBackend(Backend):
    """Discrete-event machine model (Fig. 2/5); ignores call arguments and
    returns a SimReport.  Also hosts the design-space sweep
    (``Compiled.sweep`` dispatches here), so an alternative simulation
    backend can override both entry points together."""

    name = "simulate"
    kind = "analyze"

    def execute(self, compiled: Any, args: Sequence[Any]) -> Any:
        del args
        return compiled.simulate()

    def sweep(self, compiled: Any, **kwargs: Any) -> Any:
        from .schedule import sweep_schedule
        return sweep_schedule(compiled.schedule, **kwargs)

"""Compilation options for the dataflow compiler driver.

:class:`CompileOptions` is a frozen, hashable value object: together with
the traced jaxpr it forms the key of the driver's in-memory compilation
cache, so every field must be hashable.  Mappings passed for
``latency_table`` / ``regions`` are frozen into sorted tuples.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from ..core.cdfg import LatencyModel


def _freeze(value: Any) -> tuple:
    if isinstance(value, Mapping):
        return tuple(sorted(value.items()))
    return tuple(value)


@dataclasses.dataclass(frozen=True)
class ResourceConstraints:
    """The resource model the partition-space DSE prunes against, plus
    the exploration knobs the ``dse`` pass needs (frozen/hashable so it
    can ride in :class:`CompileOptions` and the compile cache key).

    Limits (``None`` = unconstrained):
      ``max_fifo_bits``            — total FIFO storage across channels
        (``fifo_depth × Σ channel payload bits``, the sweep's
        ``fifo_bits`` metric).
      ``max_mem_ports_per_stage``  — memory regions touched per stage
        (the template gives every stage one access interface per region).
      ``max_duplicated_nodes``     — §III-B1 duplication budget: total
        replicas across stages (0 forbids the rewrite outright).
      ``max_stages``               — stage count cap (area proxy).

    Exploration knobs (used when the ``dse`` pass runs at compile time;
    ``Compiled.explore`` accepts overrides):
      ``n_iters``        — iterations simulated per candidate.
      ``fifo_depth``     — FIFO depth candidates are costed/simulated at.
      ``fifo_depths``    — joint partition×depth search: cost and
        simulate every candidate at every listed depth (the depth
        becomes a search axis; the Pareto front spans both).  ``None``
        keeps the single-depth search at ``fifo_depth``.
      ``mem``            — memory-model name from
        :func:`repro.core.simulator.standard_memory_models`.
      ``max_candidates`` — enumeration budget (BFS over merge/split
        moves from the Algorithm 1 plan; the fused and maximal
        degenerate plans are always included).  Counts (plan,
        duplicate) pairs; the depth / transform / memory-model grids
        multiply evaluated points, not the budget.
      ``seed``           — simulation seed.

    Transform-axis knobs (the catalog in ``repro.dataflow.transforms``;
    all off by default so the stage-regrouping-only search is
    unchanged):
      ``unroll_factors``   — unroll factors to explore as DSE moves
        (e.g. ``(2, 4)``); each factor's FIFO-bit cost scales with the
        widened channels, so ``max_fifo_bits`` prunes them exactly like
        regrouped plans.
      ``explore_coalesce`` — additionally try each unroll factor with
        access coalescing (legality-checked per op stream).
      ``explore_reassoc``  — seed the plan enumeration with the
        memory-port re-association split (multi-region stages split by
        region).
      ``mems``             — memory-model names to span in one
        exploration (empty = just ``mem``); front points record their
        model.
    """

    max_fifo_bits: int | None = None
    max_mem_ports_per_stage: int | None = None
    max_duplicated_nodes: int | None = None
    max_stages: int | None = None
    n_iters: int = 4096
    fifo_depth: int = 8
    fifo_depths: Any = None
    mem: str = "ACP"
    max_candidates: int = 64
    seed: int = 0
    unroll_factors: Any = ()
    explore_coalesce: bool = False
    explore_reassoc: bool = False
    mems: Any = ()

    def __post_init__(self) -> None:
        if self.fifo_depths is not None:
            object.__setattr__(self, "fifo_depths",
                               tuple(self.fifo_depths))
        object.__setattr__(self, "unroll_factors",
                           tuple(self.unroll_factors))
        object.__setattr__(self, "mems", tuple(self.mems))


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """Serving-tier knobs carried on :class:`CompileOptions`.

    When set, ``Compiled.simulate`` / ``sweep`` / ``explore`` default
    their ``server`` argument to ``address`` (``None`` = the store's
    canonical socket, i.e. ``server="auto"``) and install the timeout /
    backoff knobs below as the process's serve-client configuration
    (:func:`repro.serve.client.configure_timeouts`) before resolving —
    the compile-options side of the client's
    :class:`~repro.serve.client.ServeTimeouts`.  ``max_wait_s`` is the
    cumulative connect + busy-retry budget; ``deadline_s`` (optional)
    rides each resolve request to the daemon, which fails the request
    server-side once exceeded (the client then falls back to library
    mode).  Frozen/hashable, so it participates in the compile cache
    key like every other option."""

    address: str | None = None
    connect_timeout_s: float = 10.0
    request_timeout_s: float = 600.0
    max_wait_s: float = 60.0
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    deadline_s: float | None = None

    def timeouts(self) -> Any:
        """The equivalent :class:`repro.serve.client.ServeTimeouts`."""
        from ..serve.client import ServeTimeouts
        return ServeTimeouts(
            connect_timeout_s=self.connect_timeout_s,
            request_timeout_s=self.request_timeout_s,
            max_wait_s=self.max_wait_s,
            backoff_base_s=self.backoff_base_s,
            backoff_cap_s=self.backoff_cap_s,
            deadline_s=self.deadline_s)


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Everything that parameterizes a :func:`repro.dataflow.compile` run.

    Partitioning (Algorithm 1):
      ``policy``             — "paper" | "fused" | "maximal" | "cost_aware".
      ``duplicate_cheap``    — §III-B1 cheap-op duplication rewrite.
      ``channel_cost_bytes`` — merge threshold for the cost_aware policy.

    Front end:
      ``latency_table`` / ``latency_default`` / ``long_threshold`` — the
        abstract latency model (overrides ``DEFAULT_LATENCY``).
      ``regions``          — invar index → region name (user alias results).
      ``add_memory_edges`` — §III-A memory-ordering edges.
      ``loop``             — treat the function as a loop body
        ``body(carry, *xs) -> new_carry`` and add carry back-edges.
      ``nonaliasing_carries`` — carry indices whose back-edge is dropped
        (the paper's user annotation; only meaningful with ``loop=True``).

    Execution:
      ``backend``        — default backend name for ``Compiled.__call__``.
      ``stream_argnums`` — argument positions that vary per microbatch when
        streaming through the systolic executors.

    Design-space exploration:
      ``dse`` — a :class:`ResourceConstraints` block.  When set, the
        ``dse`` pass explores merge/split/duplicate re-partitionings of
        the Algorithm 1 plan under these constraints (each candidate
        fully simulated) and compiles the winner;
        ``compiled.dse_result`` keeps the explored front.

    Transformation catalog:
      ``transforms`` — a
        :class:`repro.dataflow.transforms.TransformConfig` (or ``None``).
        When set, the ``transform`` pass validates it against the
        analyzed CDFG and the partition/schedule layers apply it: unroll
        widens channels and scales SCC II, coalescing merges legal
        unrolled access groups into burst-width ops, tiling permutes the
        simulated iteration space, reassoc splits multi-region stages.
        Frozen/hashable, so it participates in the compile cache key.

    Serving tier:
      ``serve`` — a :class:`ServeOptions` block.  When set,
        ``Compiled.simulate`` / ``sweep`` / ``explore`` resolve through
        the resolution daemon at ``serve.address`` by default and the
        client runs with these timeout/backoff knobs
        (``docs/serving.md``).

    Static verification:
      ``verify`` — run the static dataflow verifier
        (``repro.dataflow.verify``) after every pipeline pass: IR
        invariants (SCC integrity, topo order, channel/token balance,
        §III-A ordering preservation), the FIFO deadlock analysis, and
        the decoupled-access race detector.  Error-severity findings
        raise :class:`~repro.dataflow.verify.VerifyError` at the pass
        that broke the invariant.  On by default; ``REPRO_VERIFY=0``
        in the environment disables it process-wide (``docs/verify
        .md``).
    """

    policy: str = "paper"
    backend: str = "sequential"
    duplicate_cheap: bool = True
    channel_cost_bytes: int = 4096
    latency_table: Any = ()
    latency_default: int = 1
    long_threshold: int = 1
    regions: Any = ()
    add_memory_edges: bool = True
    loop: bool = False
    nonaliasing_carries: Any = ()
    stream_argnums: Any = (0,)
    dse: ResourceConstraints | None = None
    transforms: Any = None
    serve: ServeOptions | None = None
    verify: bool = True

    def __post_init__(self) -> None:
        object.__setattr__(self, "latency_table", _freeze(self.latency_table))
        object.__setattr__(self, "regions", _freeze(self.regions))
        object.__setattr__(self, "stream_argnums",
                           tuple(self.stream_argnums))
        object.__setattr__(self, "nonaliasing_carries",
                           tuple(self.nonaliasing_carries))

    def latency_model(self) -> LatencyModel:
        return LatencyModel(table=dict(self.latency_table),
                            default=self.latency_default,
                            long_threshold=self.long_threshold)

    def regions_map(self) -> dict[int, str]:
        return dict(self.regions)

    def replace(self, **changes: Any) -> "CompileOptions":
        return dataclasses.replace(self, **changes)

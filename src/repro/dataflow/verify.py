"""Static dataflow verifier: IR invariants, deadlock bounds, races.

The pipeline restructures a traced function into a multi-stage dataflow
engine through several IR forms (CDFG → StagePlan → Partition →
DecoupledProgram → Schedule).  Each pass preserves invariants the later
layers silently assume — SCCs are never split, the stage order is a
topological order of the condensation, every cross-stage dependence has
a FIFO channel, §III-A memory-ordering tokens survive rewrites.  Before
this module those invariants were spot-checked (``plan_is_legal``,
per-transform guards) and violations surfaced late, as wrong simulation
results.  This is the production-compiler counterpart: a pure static
analysis over the IR that runs after every pass (``CompileOptions
.verify``, on by default; ``REPRO_VERIFY=0`` disables it process-wide)
and reports structured :class:`Diagnostic` records.

Rule catalog (ids are stable; ``docs/verify.md`` documents each):

  ``plan-cover``     plan groups partition the SCC set; every CDFG node
                     is covered by exactly one SCC/group.
  ``plan-topo``      every cross-group dependence edge flows forward
                     (the group order is a topo order of the
                     condensation).
  ``scc-integrity``  no SCC is split across groups/stages.
  ``chan-missing``   every cross-stage dependence edge has a FIFO
                     channel (or a §III-B1 replica in the consumer).
  ``chan-width``     channel payload widths match the var's bytes ×
                     the active unroll factor (token channels are
                     zero-width).
  ``mem-order``      §III-A memory-ordering tokens are preserved: every
                     ``mem`` edge is intra-stage or has a directed
                     channel path, and no §III-B1 replica drops an
                     ordering feeder.
  ``chan-cycle``     the stage channel graph is acyclic (a directed
                     channel cycle carries zero initial tokens and
                     deadlocks at any FIFO depth).
  ``fifo-depth``     the configured FIFO depth clears the plan's
                     deadlock bound (token-capacity argument — see
                     :func:`deadlock_min_depth`).
  ``race``           stage pairs touching an overlapping memory region
                     (with at least one store) have an ordering-token
                     path between them.
  ``transform``      the active transform config is legal for the
                     materialized CDFG and stage timing matches
                     ``scaled_stage_timing``.
  ``decouple``       the decoupled program's channel wiring matches the
                     partition (producer stages, stage count).

Deadlock model (the ``chan-cycle`` / ``fifo-depth`` rules): channels
form a marked graph — each FIFO contributes a forward edge holding the
producer's in-flight tokens and a reverse *credit* edge holding
``depth`` free slots.  A directed cycle whose places hold zero tokens
can never fire again: a cycle of forward edges alone (``chan-cycle``)
deadlocks at any depth.  Cycles mixing forward and credit edges bound
the achievable initiation interval instead: a cycle through ``b``
credit edges with total forward latency ``L`` sustains at best one
token per ``L / (b·depth)`` cycles.  :func:`deadlock_min_depth` is the
smallest uniform depth at which no such cycle is slower than running
the stages back-to-back — below it the "pipeline" statically collapses
into a serialized machine and the DSE prunes the point before paying
for simulation (``docs/verify.md`` derives both bounds).
"""

from __future__ import annotations

import dataclasses
import itertools
import os
from typing import Any, Iterable, Mapping

import networkx as nx

from ..core.partition import (Partition, StagePlan, _scaled_stage_timing,
                              _var_nbytes, derive_channels)

#: rule id -> one-line description (the catalog; docs/verify.md)
RULES: dict[str, str] = {
    "plan-cover": "plan groups partition the SCC set / cover every node",
    "plan-topo": "cross-group dependence edges flow forward",
    "scc-integrity": "no SCC is split across groups or stages",
    "chan-missing": "every cross-stage edge has a channel or replica",
    "chan-width": "channel widths = var bytes x unroll (tokens 0)",
    "mem-order": "memory-ordering tokens survive rewrites",
    "chan-cycle": "stage channel graph is acyclic",
    "fifo-depth": "configured FIFO depth clears the deadlock bound",
    "race": "overlapping-region stage pairs have an ordering path",
    "transform": "transform config legal post-materialization",
    "decouple": "decoupled program wiring matches the partition",
}

#: cap on credit-graph cycle enumeration (stage graphs are tiny; this
#: only guards pathological hand-built inputs)
_MAX_CYCLES = 4096


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verifier finding: a rule id from :data:`RULES`, a severity
    (``"error"`` — the IR is broken, the pipeline raises; ``"warning"``
    — legal but statically suspect, surfaced in reports/lint), the IR
    location it anchors to, the message, and a fix hint."""

    rule: str
    severity: str          # "error" | "warning"
    loc: str               # e.g. "stage 1 -> stage 3", "node 7", "plan"
    message: str
    hint: str = ""

    def __str__(self) -> str:
        s = f"[{self.rule}] {self.severity} @ {self.loc}: {self.message}"
        return s + (f"  (hint: {self.hint})" if self.hint else "")


class VerifyError(RuntimeError):
    """Raised by the pipeline hook when a pass leaves error-severity
    diagnostics behind.  Carries the structured findings."""

    def __init__(self, diagnostics: Iterable[Diagnostic],
                 where: str = "") -> None:
        self.diagnostics = [d for d in diagnostics
                            if d.severity == "error"]
        head = f"IR verification failed after pass {where!r}" if where \
            else "IR verification failed"
        lines = [head] + [f"  {d}" for d in self.diagnostics]
        super().__init__("\n".join(lines))
        self.where = where


def enabled(options: Any = None) -> bool:
    """Is verification on?  ``REPRO_VERIFY=0`` wins over everything
    (the documented escape hatch); otherwise ``options.verify``
    (default True)."""
    if os.environ.get("REPRO_VERIFY", "").strip() == "0":
        return False
    return bool(getattr(options, "verify", True))


def _err(rule: str, loc: str, msg: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, "error", loc, msg, hint)


def _warn(rule: str, loc: str, msg: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, "warning", loc, msg, hint)


# ---------------------------------------------------------------------------
# Family 1: inter-pass IR invariants
# ---------------------------------------------------------------------------


def verify_plan(cdfg: Any, plan: StagePlan) -> list[Diagnostic]:
    """StagePlan invariants: cover, SCC integrity, topo order, and the
    plan-level half of memory-order preservation (uncovered mem-edge
    endpoints would be silently dropped by ``derive_channels``)."""
    out: list[Diagnostic] = []
    seen = [k for grp in plan.groups for k in grp]
    if sorted(seen) != list(range(len(plan.sccs))):
        missing = set(range(len(plan.sccs))) - set(seen)
        dup = [k for k in set(seen) if seen.count(k) > 1]
        out.append(_err(
            "plan-cover", "plan",
            f"groups do not partition the SCC set "
            f"(missing={sorted(missing)}, repeated={sorted(dup)})",
            "rebuild the plan with stage_groups() or apply only "
            "merge_move/split_move"))
    covered = set(plan.scc_of_node)
    node_ids = {n.id for n in cdfg.nodes}
    if not node_ids <= covered:
        out.append(_err(
            "plan-cover", "plan",
            f"nodes {sorted(node_ids - covered)} not mapped to any SCC",
            "the plan was built for a different CDFG — re-run "
            "stage_groups() on this one"))
    for k, comp in enumerate(plan.sccs):
        mapped = {plan.scc_of_node.get(n) for n in comp}
        if mapped != {k}:
            out.append(_err(
                "scc-integrity", f"scc {k}",
                f"members map to SCCs {sorted(str(m) for m in mapped)}; "
                f"an SCC must stay whole",
                "SCCs are never split (Algorithm 1); regroup whole "
                "SCC ids only"))
    group_of: dict[int, int] = {}
    for gi, grp in enumerate(plan.groups):
        for k in grp:
            group_of[k] = gi
    for e in cdfg.edges:
        a = plan.scc_of_node.get(e.src)
        b = plan.scc_of_node.get(e.dst)
        if a is None or b is None:
            if e.kind == "mem":
                out.append(_err(
                    "mem-order", f"node {e.src} -> node {e.dst}",
                    "memory-order edge endpoint not covered by the "
                    "plan; its ordering token would be dropped",
                    "re-derive the plan from the CDFG that carries "
                    "this edge"))
            continue
        ga, gb = group_of.get(a), group_of.get(b)
        if a != b and ga is not None and gb is not None and ga > gb:
            out.append(_err(
                "plan-topo", f"node {e.src} -> node {e.dst}",
                f"dependence flows backward (group {ga} -> {gb}); the "
                f"group order is not a topological order",
                "only merge adjacent groups or split at interior "
                "points — both preserve the topo order"))
    return out


def _stage_graph(part: Partition) -> nx.DiGraph:
    g = nx.DiGraph()
    for s in part.stages:
        g.add_node(s.id)
    for c in part.channels:
        g.add_edge(c.src_stage, c.dst_stage)
    return g


def verify_partition(part: Partition, *,
                     strict_races: bool = True) -> list[Diagnostic]:
    """Partition invariants: channel balance/width vs an independent
    re-derivation, SCC integrity of ``stage_of_node``, memory-order
    preservation through rewrite/duplication, stage-graph acyclicity,
    the race detector, and transform-timing consistency.

    ``strict_races=False`` downgrades ``race`` findings to warnings —
    the posture when the user compiled with ``add_memory_edges=False``
    and so explicitly asserted the accesses don't alias."""
    cdfg = part.cdfg
    out: list[Diagnostic] = []

    # --- scc-integrity: stages must hold whole SCCs ------------------------
    g = nx.DiGraph()
    g.add_nodes_from(n.id for n in cdfg.nodes)
    g.add_edges_from((e.src, e.dst) for e in cdfg.edges)
    for comp in nx.strongly_connected_components(g):
        stages = {part.stage_of_node.get(n) for n in comp}
        if len(stages) > 1:
            out.append(_err(
                "scc-integrity", f"nodes {sorted(comp)}",
                f"SCC split across stages {sorted(map(str, stages))}",
                "a dependence cycle cannot cross a FIFO; keep the SCC "
                "in one stage"))

    # --- chan-missing / chan-width: balance vs re-derivation ---------------
    expected = {(c.src_stage, c.dst_stage, c.var): c
                for c in derive_channels(part)}
    actual = {(c.src_stage, c.dst_stage, c.var): c
              for c in part.channels}
    for key, c in expected.items():
        have = actual.get(key)
        loc = f"stage {key[0]} -> stage {key[1]}"
        if have is None:
            kind = "memory-order token" if c.var is None else \
                f"var {c.var}"
            rule = "mem-order" if c.kind == "mem" else "chan-missing"
            out.append(_err(
                rule, loc,
                f"cross-stage {kind} edge has no channel",
                "re-derive channels after every stage_of_node or "
                "duplication change (derive_channels)"))
        elif have.nbytes != c.nbytes:
            out.append(_err(
                "chan-width", loc,
                f"channel width {have.nbytes}B != expected {c.nbytes}B "
                f"(var bytes x unroll)",
                "materialize() and derive_channels() must share the "
                "active TransformConfig"))
    for key in actual:
        if key not in expected:
            out.append(_err(
                "chan-missing", f"stage {key[0]} -> stage {key[1]}",
                "channel has no underlying cross-stage dependence edge",
                "stale channel list — re-derive after re-partitioning"))

    # --- chan-width: independent width check (not via re-derivation) ------
    unroll = int(getattr(part.transforms, "unroll", 1) or 1)
    for c in part.channels:
        want = _var_nbytes(c.var) * unroll if c.var is not None else 0
        if c.nbytes != want:
            key = (c.src_stage, c.dst_stage, c.var)
            if key in expected and expected[key].nbytes != c.nbytes:
                continue  # already reported against the re-derivation
            out.append(_err(
                "chan-width",
                f"stage {c.src_stage} -> stage {c.dst_stage}",
                f"channel width {c.nbytes}B != {want}B "
                f"({'token' if c.var is None else 'data'} channel, "
                f"unroll x{unroll})",
                "token channels are zero-width; data channels scale "
                "with the unroll factor"))

    # --- chan-cycle --------------------------------------------------------
    sg = _stage_graph(part)
    try:
        cyc = nx.find_cycle(sg)
    except nx.NetworkXNoCycle:
        cyc = None
    if cyc:
        path = " -> ".join(str(u) for u, _ in cyc) + f" -> {cyc[-1][1]}"
        out.append(_err(
            "chan-cycle", f"stages {path}",
            "directed channel cycle: zero initial tokens, deadlocks at "
            "any FIFO depth",
            "stage order must be a topological order of the "
            "condensation (plan-topo); no channel may flow backward"))

    # --- mem-order through rewrites ----------------------------------------
    reach: dict[int, set[int]] = {}
    if cyc is None:
        for sid in sg.nodes:
            reach[sid] = nx.descendants(sg, sid)
    for e in cdfg.edges:
        if e.kind != "mem":
            continue
        a = part.stage_of_node.get(e.src)
        b = part.stage_of_node.get(e.dst)
        loc = f"node {e.src} -> node {e.dst}"
        if a is None or b is None:
            out.append(_err(
                "mem-order", loc,
                "memory-order edge endpoint has no stage",
                "the partition was built for a different CDFG"))
            continue
        if a == b or cyc is not None:
            continue
        if b not in reach.get(a, ()):
            out.append(_err(
                "mem-order", f"stage {a} -> stage {b} ({loc})",
                "memory-order edge crosses stages with no channel path; "
                "the ordering token was dropped",
                "derive_channels() must keep a token channel (or "
                "transitive path) for every mem edge"))
    # §III-B1: a replica silently drops any ordering feeder of the
    # duplicated node — re-check the rewrite's own guard
    feeders = {}
    for e in cdfg.edges:
        feeders.setdefault(e.dst, []).append(e)
    for nid, consumers in part.duplicated.items():
        fed = feeders.get(nid, ())
        if fed:
            kinds = sorted({e.kind for e in fed})
            out.append(_err(
                "mem-order", f"node {nid}",
                f"duplicated node has feeder edges ({'/'.join(kinds)}); "
                f"its replicas in stages {list(consumers)} drop that "
                f"ordering/dataflow",
                "only feeder-free cheap ops are duplicable (§III-B1)"))

    # --- race detector ------------------------------------------------------
    sev = _err if strict_races else _warn
    touch: dict[str, dict[int, bool]] = {}
    for n in cdfg.nodes if cyc is None else ():
        if not n.is_memory or not n.region:
            continue
        sid = part.stage_of_node.get(n.id)
        if sid is None:
            continue
        per = touch.setdefault(n.region, {})
        per[sid] = per.get(sid, False) or n.is_store
    for region, per in touch.items():
        sids = sorted(per)
        for a, b in itertools.combinations(sids, 2):
            if not (per[a] or per[b]):
                continue  # loads commute (§III-A)
            if cyc is None and (b in reach.get(a, ())
                                or a in reach.get(b, ())):
                continue
            out.append(sev(
                "race", f"stage {a} || stage {b}",
                f"both touch region {region!r} (store involved) with no "
                f"ordering-token path between them",
                "add_memory_order_edges() serializes same-region "
                "stores; or assign the ops distinct regions if they "
                "cannot alias"))

    # --- transform legality + timing re-check ------------------------------
    tf = part.transforms
    if tf is not None and not getattr(tf, "is_identity", True):
        from .transforms import TransformError
        try:
            tf.validate(cdfg)
        except TransformError as ex:
            out.append(_err(
                "transform", "partition",
                f"active transform config illegal for this CDFG: {ex}",
                "the transform pass must re-validate after any CDFG "
                "rewrite"))
    extra: dict[int, int] = {}
    for nid, consumers in part.duplicated.items():
        for sid in consumers:
            extra[sid] = extra.get(sid, 0) + cdfg.node(nid).latency
    for s in part.stages:
        base = sum(cdfg.node(n).latency for n in s.node_ids) \
            + extra.get(s.id, 0)
        ii, lat = _scaled_stage_timing(s.scc_ii, base, part.transforms)
        if (s.ii, s.latency) != (ii, lat):
            out.append(_err(
                "transform", f"stage {s.id}",
                f"stage timing (ii={s.ii}, lat={s.latency}) != scaled "
                f"timing (ii={ii}, lat={lat}) for the active config",
                "recompute stage timing via scaled_stage_timing after "
                "duplication or transform changes"))
    return out


def verify_program(program: Any) -> list[Diagnostic]:
    """DecoupledProgram wiring vs its partition: stage count, producer
    map consistency, and channel-input resolvability."""
    out: list[Diagnostic] = []
    part = program.partition
    if len(program.stages) != len(part.stages):
        out.append(_err(
            "decouple", "program",
            f"{len(program.stages)} stage programs != "
            f"{len(part.stages)} partition stages",
            "decouple() must emit exactly one program per stage"))
    for var, sid in program.producer_stage.items():
        if not any(s.id == sid for s in part.stages):
            out.append(_err(
                "decouple", f"var {var}",
                f"produced by unknown stage {sid}",
                "stale producer map — re-run decouple()"))
    known = set(program.producer_stage)
    for sp in program.stages:
        for src in sp.in_from:
            if src[0] == "chan" and src[1] not in known:
                out.append(_err(
                    "decouple", f"stage {sp.stage_id}",
                    f"channel input {src[1]} has no producing stage",
                    "every ('chan', var) input must appear in "
                    "producer_stage"))
    return out


# ---------------------------------------------------------------------------
# Family 2: static deadlock-freedom analysis
# ---------------------------------------------------------------------------


def _credit_cycle_bounds(lats: Mapping[int, int], iis: Mapping[int, int],
                         edges: set[tuple[int, int]]) -> tuple[int, int]:
    """(deadlock bound, full-throughput bound) over the credit marked
    graph of the stage channel set ``edges``.

    Every channel contributes a forward edge (latency of its producer)
    and a reverse credit edge (``depth`` free slots).  A simple cycle
    through ``b`` credit edges with forward latency ``L`` sustains at
    best one token per ``L/(b*depth)`` cycles, so:

    * **full throughput** needs ``depth >= L/(b*II_p)`` on every cycle
      (``II_p`` = the static pipeline II, ``max`` stage II) — below
      this, backpressure stretches the initiation interval;
    * **collapse ("static deadlock")** happens when the implied II
      reaches the fully serialized per-token cost ``sum(ii)`` — the
      engine is statically no faster than running its stages
      back-to-back, so decoupling has degenerated.  The bound is the
      smallest depth strictly above that point.
    """
    ii_p = max(1, max(iis.values(), default=1))
    serial = max(1, sum(max(1, v) for v in iis.values()))
    g = nx.DiGraph()
    g.add_nodes_from(lats)
    for s, t in edges:
        g.add_edge(s, t, kind="fwd")
        g.add_edge(t, s, kind="credit")
    dead = thr = 1
    for cycle in itertools.islice(nx.simple_cycles(g), _MAX_CYCLES):
        latency = credits = 0
        for u, v in zip(cycle, cycle[1:] + cycle[:1]):
            if (u, v) in edges:
                latency += max(1, lats.get(u, 1))
            else:
                credits += 1
        if credits == 0:
            continue  # pure forward cycle: chan-cycle's error, not ours
        # unsafe iff latency/(credits*d) >= serial, i.e. d <= L/(b*S)
        dead = max(dead, latency // (credits * serial) + 1)
        thr = max(thr, -(-latency // (credits * ii_p)))
    return dead, thr


def deadlock_min_depth(part: Partition) -> int:
    """Smallest uniform FIFO depth at which the partition's channel
    cycles cannot statically collapse the pipeline (see
    :func:`_credit_cycle_bounds`; ``docs/verify.md`` has the
    derivation).  Depths below this are flagged by ``fifo-depth`` and
    pruned by the DSE."""
    lats = {s.id: max(1, s.latency) for s in part.stages}
    iis = {s.id: max(1, s.ii) for s in part.stages}
    edges = {(c.src_stage, c.dst_stage) for c in part.channels
             if c.src_stage != c.dst_stage}
    if any((t, s) in edges for s, t in edges) or not edges:
        return 1  # cyclic graphs are chan-cycle errors; chains of 1 fine
    return _credit_cycle_bounds(lats, iis, edges)[0]


def chain_deadlock_bound(lats: Iterable[int],
                         iis: Iterable[int]) -> int:
    """The :func:`deadlock_min_depth` bound specialized to a linear
    stage chain — the machine model ``simulate_dataflow`` solves, where
    stage ``s`` backpressures on ``start[s+1, i-depth]``.  The binding
    credit cycles are the adjacent pairs, so the bound reduces to
    ``floor(max latency / serialized cost) + 1`` over non-final
    stages."""
    lats, iis = list(lats), list(iis)
    if len(lats) < 2:
        return 1
    serial = max(1, sum(max(1, x) for x in iis))
    return max(1, max(max(1, x) for x in lats[:-1]) // serial + 1)


def fifo_depth_diagnostics(part: Partition,
                           depths: Iterable[int]) -> list[Diagnostic]:
    """``fifo-depth`` findings for the configured depth axis: error
    below the collapse bound (or below 1 — the simulator refuses it),
    warning below the full-throughput bound."""
    out: list[Diagnostic] = []
    lats = {s.id: max(1, s.latency) for s in part.stages}
    iis = {s.id: max(1, s.ii) for s in part.stages}
    edges = {(c.src_stage, c.dst_stage) for c in part.channels
             if c.src_stage != c.dst_stage}
    if not edges or any((t, s) in edges for s, t in edges):
        return out
    dead, thr = _credit_cycle_bounds(lats, iis, edges)
    for d in dict.fromkeys(depths):
        if d < 1:
            out.append(_err(
                "fifo-depth", f"fifo_depth={d}",
                "FIFO depth below 1: a zero-capacity channel can never "
                "transfer a token",
                "fifo_depth must be >= 1"))
        elif d < dead:
            out.append(_err(
                "fifo-depth", f"fifo_depth={d}",
                f"statically deadlocks: depth {d} < bound {dead} — the "
                f"credit cycles' token capacity serializes the "
                f"pipeline below back-to-back stage execution",
                f"use depth >= {dead} (>= {thr} for full throughput)"))
        elif d < thr:
            out.append(_warn(
                "fifo-depth", f"fifo_depth={d}",
                f"below the full-throughput bound {thr}: backpressure "
                f"stretches the initiation interval past the static "
                f"pipeline II",
                f"depth >= {thr} hides all producer latency"))
    return out


# ---------------------------------------------------------------------------
# Entry points: pipeline hook and whole-artifact verification
# ---------------------------------------------------------------------------

#: pass name -> IR forms checked after it.  The front-end and no-op
#: passes re-check nothing; ``dse`` re-materializes, so it re-verifies.
#: Unknown (user-inserted) passes get every form that exists — a custom
#: pass that corrupts the IR is blamed by name, not its successor.
_AFTER_PASS = {
    "trace": (),
    "memdep": (),
    "transform": (),
    "partition": ("plan", "partition"),
    "rewrite": ("plan", "partition"),
    "dse": ("plan", "partition"),
    "decouple": ("program",),
    "schedule": (),
}
_ALL_FORMS = ("plan", "partition", "program")


def verify_ctx(ctx: Any, pass_name: str) -> list[Diagnostic]:
    """The inter-pass hook: verify the IR forms ``pass_name`` is
    responsible for, record findings on ``ctx.diagnostics``, raise
    :class:`VerifyError` on error severity."""
    forms = _AFTER_PASS.get(pass_name, _ALL_FORMS)
    diags: list[Diagnostic] = []
    strict = bool(getattr(ctx.options, "add_memory_edges", True))
    if "plan" in forms and ctx.plan is not None:
        diags += verify_plan(ctx.cdfg, ctx.plan)
    if "partition" in forms and ctx.partition is not None:
        diags += verify_partition(ctx.partition, strict_races=strict)
    if "program" in forms and ctx.program is not None:
        diags += verify_program(ctx.program)
    if diags:
        ctx.diagnostics.setdefault(pass_name, []).extend(diags)
    errors = [d for d in diags if d.severity == "error"]
    if errors:
        raise VerifyError(errors, where=pass_name)
    return diags


def verify_compiled(compiled: Any,
                    fifo_depths: Iterable[int] | None = None
                    ) -> list[Diagnostic]:
    """Whole-artifact verification (``Compiled.verify()``): every rule
    family over the final plan/partition/program, plus the deadlock
    bound against ``fifo_depths`` (default: the DSE constraints' depth
    axis, else the simulator default of 8)."""
    ctx = compiled.context
    strict = bool(getattr(ctx.options, "add_memory_edges", True))
    diags = verify_plan(ctx.cdfg, ctx.plan)
    diags += verify_partition(ctx.partition, strict_races=strict)
    if ctx.program is not None:
        diags += verify_program(ctx.program)
    if fifo_depths is None:
        rc = getattr(ctx.options, "dse", None)
        fifo_depths = tuple(getattr(rc, "fifo_depths", None) or
                            (getattr(rc, "fifo_depth", None) or 8,)) \
            if rc is not None else (8,)
    diags += fifo_depth_diagnostics(ctx.partition, fifo_depths)
    return diags

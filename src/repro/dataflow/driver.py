"""The compiler driver: one entry point for the paper's whole flow.

``compile(fn, *example_args, options=...)`` runs the pass pipeline
(trace → memdep → partition → rewrite → decouple → schedule) and returns a
:class:`Compiled` artifact; ``dataflow_jit`` is the decorator form that
compiles lazily on first call per argument shape (like ``jax.jit``).

Compilation results are cached in memory, keyed on the traced jaxpr
(structure + closed-over constants), the example avals, the options, and
the pipeline structure: recompiling the same function with the same
options is a cache hit returning the *same* ``Compiled`` object.
"""

from __future__ import annotations

import inspect
import logging
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .backends import available_backends, get_backend
from .options import CompileOptions
from .passes import CompileContext, PassPipeline, default_pipeline
from .schedule import SimReport, simulate_schedule


class Compiled:
    """The artifact produced by :func:`compile`.

    Stable surface:
      ``__call__(*args, backend=None)`` — execute via a registered backend
        (default: ``options.backend``).
      ``stream(*args)``   — stream microbatches through the emulated
        systolic pipeline (stream args carry a leading microbatch axis).
      ``simulate(...)``   — discrete-event Fig. 2/5 schedule report.
      ``sweep(...)``      — design-space sweep over memory models × FIFO
        depths × SCC modes (fully simulated grid; ``SweepResult``).
      ``explore(...)``    — partition-space DSE: merge/split/duplicate
        re-partitionings under resource constraints, fully simulated;
        returns a cycles-vs-FIFO-bits Pareto front of ``Compiled``
        artifacts (``DseResult``).
      ``report()``        — per-stage latency / channel summary (text).
      ``cdfg`` / ``partition`` / ``program`` / ``schedule`` — the pass
        products, for inspection and downstream tools.
    """

    def __init__(self, context: CompileContext, pipeline: PassPipeline):
        self.context = context
        self.pipeline = pipeline
        self.fn = context.fn
        self.options = context.options
        #: per-backend runtime state (jitted fns, sharded runners)
        self.runtime_cache: dict[str, Any] = {}

    # -- pass products --------------------------------------------------------

    @property
    def closed_jaxpr(self):
        return self.context.closed_jaxpr

    @property
    def cdfg(self):
        return self.context.cdfg

    @property
    def partition(self):
        return self.context.partition

    @property
    def program(self):
        return self.context.program

    @property
    def schedule(self):
        return self.context.schedule

    @property
    def num_stages(self) -> int:
        return len(self.partition.stages)

    # -- execution ------------------------------------------------------------

    def __call__(self, *args: Any, backend: str | None = None) -> Any:
        return get_backend(backend or self.options.backend).execute(
            self, args)

    def stream(self, *args: Any) -> Any:
        """Run a stream of microbatches through the emulated systolic
        executor; args at ``options.stream_argnums`` have a leading
        microbatch axis, outputs are stacked along it."""
        outs = self.schedule.pipeline.run_emulated(*args)
        return self.unflatten_outputs(list(outs))

    def backends(self) -> tuple[str, ...]:
        """Backends available for this artifact in this environment."""
        return available_backends(self)

    def unflatten_outputs(self, flat: Sequence[Any]) -> Any:
        return jax.tree_util.tree_unflatten(self.context.out_tree,
                                            list(flat))

    # -- analysis -------------------------------------------------------------

    def _serve_defaults(self, kwargs: dict) -> dict:
        """Apply ``options.serve`` (a
        :class:`~repro.dataflow.options.ServeOptions`): default the
        ``server`` argument to its address and install its
        timeout/backoff knobs as the serve-client configuration.  An
        explicit ``server=`` argument still wins."""
        sv = getattr(self.options, "serve", None)
        if sv is not None:
            kwargs.setdefault("server", sv.address or "auto")
            from ..serve import client as _serve_client
            _serve_client.configure_timeouts(sv.timeouts())
        return kwargs

    def simulate(self, n_iters: int = 2048, **kwargs: Any) -> SimReport:
        """Discrete-event simulation of this program on the template vs the
        fused conventional engine (see
        :func:`repro.dataflow.schedule.simulate_schedule`).  Pass
        ``server="auto"`` (or an address) to pre-resolve traces through a
        running resolution daemon — see ``docs/serving.md``."""
        return simulate_schedule(self.schedule, n_iters=n_iters,
                                 **self._serve_defaults(kwargs))

    def sweep(self, **kwargs: Any) -> Any:
        """Design-space sweep: grid the cycle simulator over memory models
        × FIFO depths × ``mem_in_scc`` modes, fully simulated (see
        :func:`repro.dataflow.schedule.sweep_schedule`; dispatched through
        the ``simulate`` backend).  Depth lanes solve deepest-first with
        the depth-incremental warm start, and ``workers=N`` shards the
        trace resolution over the chunk-graph process pool
        (bit-identical; multi-core).  ``server="auto"`` (or an address)
        delegates resolution to a running resolution daemon instead —
        shared pool, cross-client in-flight dedup, streamed chunks;
        results stay bit-identical (``docs/serving.md``)."""
        return get_backend("simulate").sweep(self, **self._serve_defaults(kwargs))

    def explore(self, **kwargs: Any) -> Any:
        """Partition-space DSE (see :func:`repro.dataflow.dse.explore`):
        enumerate legal merge/split/duplicate re-partitionings of this
        kernel, prune against a
        :class:`~repro.dataflow.options.ResourceConstraints` resource
        model, simulate every survivor (sharing resolved traces through
        the chunk-granular per-op rescache), and return a
        :class:`~repro.dataflow.dse.DseResult` whose cycles-vs-FIFO-bits
        Pareto front carries full ``Compiled`` artifacts.  Pass
        ``fifo_depths=[...]`` for the joint partition×FIFO-depth front
        (depth becomes a search axis: every candidate is costed and
        simulated at every depth, one warm-started solve each), and
        ``server="auto"`` to resolve candidate traces through a running
        resolution daemon first (``docs/serving.md``)."""
        from . import dse as _dse
        return _dse.explore(self, **self._serve_defaults(kwargs))

    @property
    def dse_result(self):
        """The ``dse`` pass's exploration (None unless ``options.dse``)."""
        return self.context.dse_result

    @property
    def transform_signature(self) -> str:
        """Active transformation-catalog signature (``"none"`` when the
        pipeline compiled untransformed) — surfaced in :meth:`report`
        and on every sweep row."""
        tf = getattr(self.schedule, "transforms", None)
        return tf.signature() if tf is not None else "none"

    def sim_stages(self, traces: Any = None, **kwargs: Any):
        """Cycle-simulator stage specs (II/latency/mem-in-SCC from the real
        partitioner, traces attached in pipeline order)."""
        return self.schedule.sim_stages(traces, **kwargs)

    def verify(self, fifo_depths: Sequence[int] | None = None,
               *, raise_on_error: bool = False) -> list:
        """Run the static dataflow verifier over this artifact: IR
        invariants (plan/partition/program), the decoupled-access race
        detector, and the FIFO deadlock analysis against
        ``fifo_depths`` (default: the DSE constraints' depth axis, else
        the simulator default of 8).  Returns the
        :class:`~repro.dataflow.verify.Diagnostic` list — empty means
        clean; ``raise_on_error=True`` raises
        :class:`~repro.dataflow.verify.VerifyError` when any
        error-severity finding is present (warnings never raise).  The
        same rules run after every pipeline pass when
        ``options.verify`` is on — see ``docs/verify.md``."""
        from . import verify as _verify
        diags = _verify.verify_compiled(self, fifo_depths)
        if raise_on_error and any(d.severity == "error" for d in diags):
            raise _verify.VerifyError(diags, where="verify()")
        return diags

    def report(self) -> str:
        """Per-stage latency / channel summary."""
        sch = self.schedule
        opts = self.options
        lines = [
            f"dataflow program: {len(self.cdfg.nodes)} ops -> "
            f"{sch.num_stages} stages, {sch.num_channels} channels "
            f"({sch.channel_bytes}B/token), policy={opts.policy!r}, "
            f"backend={opts.backend!r}",
            f"  pipeline II={sch.pipeline_ii}  "
            f"total latency={sch.total_latency}  "
            f"bubble@8mb={sch.bubble_fraction(8):.2f}",
            f"  passes: {' -> '.join(self.pipeline.names())}  "
            f"transforms: {self.transform_signature}",
        ]
        for s in sch.stages:
            tags = [t for t, on in (("MEM", s.has_memory),
                                    ("LONG", s.has_long),
                                    ("MEM-IN-SCC", s.mem_in_scc)) if on]
            prims = ",".join(s.prims[:6]) + ("…" if len(s.prims) > 6 else "")
            lines.append(
                f"  stage {s.id}: [{prims}] ii={s.ii} lat={s.latency} "
                f"in={s.in_channel_bytes}B out={s.out_channel_bytes}B "
                f"{'|'.join(tags)}"
                + (f" regions={list(s.regions)}" if s.regions else ""))
        for name, dt in self.context.timings.items():
            lines.append(f"  pass {name:<10} {dt * 1e3:8.2f} ms")
        diags = self.verify()
        errs = sum(d.severity == "error" for d in diags)
        warns = len(diags) - errs
        lines.append(
            "  verify: clean" if not diags else
            f"  verify: {errs} error(s), {warns} warning(s)")
        for d in diags[:4]:
            lines.append(f"    {d}")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"<Compiled {getattr(self.fn, '__name__', '?')} "
                f"stages={self.num_stages} backend={self.options.backend}>")


# ---------------------------------------------------------------------------
# Compilation cache
# ---------------------------------------------------------------------------

_CACHE: dict[tuple, Compiled] = {}
_STATS = {"hits": 0, "misses": 0}


def _cache_key(closed_jaxpr: Any, out_tree: Any, options: CompileOptions,
               pipeline: PassPipeline) -> tuple:
    # Consts are keyed by identity: make_jaxpr closes over the *same* array
    # objects on retrace, and the cached Compiled keeps them alive, so ids
    # are stable exactly as long as the entry exists.  out_tree
    # disambiguates functions whose flat computation is identical but whose
    # return container differs.
    return (
        str(closed_jaxpr.jaxpr),
        tuple(str(v.aval) for v in closed_jaxpr.jaxpr.invars),
        tuple(id(c) for c in closed_jaxpr.consts),
        out_tree,
        options,
        pipeline.signature(),
    )


def clear_cache() -> None:
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0


def cache_stats() -> dict[str, int]:
    return {"size": len(_CACHE), **_STATS}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def compile(  # noqa: A001 - deliberate: repro.dataflow.compile
    fn: Callable,
    *example_args: Any,
    options: CompileOptions | None = None,
    pipeline: PassPipeline | None = None,
    use_cache: bool = True,
    **option_kwargs: Any,
) -> Compiled:
    """Compile ``fn`` for the dataflow template and return a
    :class:`Compiled` artifact.

    ``example_args`` may be concrete arrays or ``jax.ShapeDtypeStruct``
    trees (analysis-only use).  Options come either as a
    :class:`CompileOptions` or as keyword shorthands
    (``compile(fn, x, policy="fused")``).
    """
    if options is None:
        options = CompileOptions(**option_kwargs)
    elif option_kwargs:
        options = options.replace(**option_kwargs)
    pipeline = pipeline or default_pipeline()

    ctx = CompileContext(fn=fn, example_args=example_args, options=options)
    # run the front end first: the cache key needs the jaxpr
    pipeline.run(ctx, stop=1)
    key = None
    if use_cache and ctx.closed_jaxpr is not None:
        key = _cache_key(ctx.closed_jaxpr, ctx.out_tree, options, pipeline)
        hit = _CACHE.get(key)
        if hit is not None:
            _STATS["hits"] += 1
            return hit
        _STATS["misses"] += 1
    pipeline.run(ctx, start=1)
    compiled = Compiled(ctx, pipeline)
    if key is not None:
        _CACHE[key] = compiled
    return compiled


def _abstract_key(args: tuple) -> tuple:
    flat, treedef = jax.tree_util.tree_flatten(args)
    return treedef, tuple(
        (tuple(np.shape(x)), str(jnp.result_type(x))) for x in flat)


_log = logging.getLogger("repro.dataflow")


def dataflow_jit(
    fn: Callable | None = None,
    *,
    options: CompileOptions | None = None,
    pipeline: PassPipeline | None = None,
    on_error: str = "raise",
    **option_kwargs: Any,
) -> Callable:
    """Decorator form of :func:`compile`: traces lazily on first call (per
    argument-shape signature) and dispatches to the selected backend.

    ::

        @dataflow_jit(stream_argnums=(1,))
        def kernel(table, idx, w): ...

        kernel(table, idx, w)                      # options.backend
        kernel(table, idx, w, backend="emulated")  # explicit dispatch
        kernel.lower(table, idx, w).report()       # the Compiled artifact

    Keyword arguments to the wrapped function are bound to positional form
    via its signature (``backend`` is reserved for dispatch — pass a
    same-named function parameter positionally).

    ``on_error="fallback"`` degrades gracefully: if the analysis pipeline
    fails on some input shape, the call logs a warning and runs plain
    ``jax.jit(fn)`` instead (``lower`` still raises, so the failure stays
    inspectable).
    """
    if on_error not in ("raise", "fallback"):
        raise ValueError(f"on_error must be 'raise' or 'fallback', "
                         f"got {on_error!r}")
    if options is None:
        opts = CompileOptions(**option_kwargs)
    elif option_kwargs:
        opts = options.replace(**option_kwargs)
    else:
        opts = options

    def wrap(f: Callable) -> Callable:
        by_shape: dict[tuple, Compiled | None] = {}
        errors: dict[tuple, Exception] = {}
        state: dict[str, Any] = {}
        _unset = object()

        def bind(args: tuple, kwargs: dict) -> tuple:
            if not kwargs:
                return args
            if "sig" not in state:
                state["sig"] = inspect.signature(f)
            return state["sig"].bind(*args, **kwargs).args

        def lower(*args: Any, **kwargs: Any) -> Compiled:
            args = bind(args, kwargs)
            key = _abstract_key(args)
            compiled = by_shape.get(key)
            if compiled is None:
                compiled = compile(f, *args, options=opts,
                                   pipeline=pipeline)
                by_shape[key] = compiled
            return compiled

        def wrapper(*args: Any, backend: str | None = None,
                    **kwargs: Any) -> Any:
            args = bind(args, kwargs)
            key = _abstract_key(args)
            compiled = by_shape.get(key, _unset)
            if compiled is _unset:
                try:
                    compiled = compile(f, *args, options=opts,
                                       pipeline=pipeline)
                except Exception as e:
                    if on_error != "fallback":
                        raise
                    _log.warning(
                        "dataflow analysis of %s failed; falling back to "
                        "jax.jit", getattr(f, "__name__", f), exc_info=True)
                    compiled = None
                    errors[key] = e
                by_shape[key] = compiled
            if compiled is None:  # analysis failed earlier; fused fallback
                if backend is not None:
                    # an explicit backend request can't be silently
                    # rerouted to fused execution
                    raise RuntimeError(
                        f"dataflow analysis failed for this input shape; "
                        f"cannot honor backend={backend!r}"
                    ) from errors.get(key)
                if "jit" not in state:
                    state["jit"] = jax.jit(f)
                return state["jit"](*args)
            return compiled(*args, backend=backend)

        wrapper.__name__ = getattr(f, "__name__", "dataflow_jit")
        wrapper.__doc__ = getattr(f, "__doc__", None)
        wrapper.__wrapped__ = f
        wrapper.lower = lower
        wrapper.options = opts
        return wrapper

    return wrap(fn) if fn is not None else wrap

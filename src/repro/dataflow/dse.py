"""Partition-space design-space exploration over :class:`StagePlan`s.

Algorithm 1 fixes *one* partitioning rule — cut after every memory op or
long SCC — but the quality of the dataflow template under area/FIFO
constraints depends on *which* partition you pick: HIDA (Ye et al.,
2023) shows hierarchical dataflow DSE over partitions is where the real
wins are, and de Fine Licht et al. (2018) catalog the merge / split /
duplicate transformations such a search must enumerate.  This module is
that explorer for the template:

1. **Enumerate** — BFS over the legal single moves (adjacent-stage
   merges, interior splits; SCCs are never split and topological order
   is preserved by construction — see
   :func:`repro.core.partition.neighbor_plans`) from the Algorithm 1
   plan, with the ``fused`` / ``maximal`` degenerate plans always
   included; the §III-B1 cheap-op duplication rewrite is a per-candidate
   toggle (the *duplicate* move).
2. **Prune** — against :class:`~repro.dataflow.options.ResourceConstraints`:
   total FIFO bits, per-stage memory-port count, duplication budget,
   stage count.  Pruned candidates are never simulated.
3. **Evaluate** — every survivor runs through the *real* cycle
   simulator (no analytic shortcut).  Candidate partitions of one
   kernel regroup the same memory ops, so the per-op rescache keying
   (:mod:`repro.core.rescache`) lets every candidate after the first
   serve its trace resolution from cache: DSE over many candidates
   costs little more than one cold simulation, with cycle counts
   bit-identical to fresh per-candidate runs.
4. **Select** — the cycles-vs-FIFO-bits Pareto front, each front point
   materialized as a full :class:`~repro.dataflow.driver.Compiled`
   artifact (``Compiled.explore``), or the constrained-best plan
   compiled in place (the ``dse`` pass, ``dataflow_jit(..., dse=...)``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping, Sequence

import numpy as np

from ..core.cdfg import CDFG
from ..core.partition import (StagePlan, Partition, materialize,
                              duplicate_cheap_rewrite, fused_plan,
                              maximal_plan, neighbor_plans, plan_is_legal,
                              plan_signature)
from ..core.simulator import (MemAccess, MemoryModel, SimStage,
                              standard_memory_models)
from .options import ResourceConstraints
from .schedule import _cyclic_nodes


# ---------------------------------------------------------------------------
# Enumeration
# ---------------------------------------------------------------------------


def enumerate_plans(cdfg: CDFG, base_plan: StagePlan,
                    max_plans: int) -> list[tuple[tuple[str, ...],
                                                  StagePlan]]:
    """Breadth-first closure of the merge/split move set from
    ``base_plan``, deduplicated by :func:`plan_signature` and capped at
    ``max_plans``.  The fused and maximal degenerate plans are seeded
    explicitly so they are reachable at any budget.  Returns
    ``(moves, plan)`` pairs; the base plan is first with an empty move
    list."""
    from collections import deque

    out: list[tuple[tuple[str, ...], StagePlan]] = [((), base_plan)]
    seen = {plan_signature(base_plan)}
    for tag, p in (("fused", fused_plan(base_plan)),
                   ("maximal", maximal_plan(base_plan))):
        sig = plan_signature(p)
        if sig not in seen and plan_is_legal(cdfg, p):
            seen.add(sig)
            out.append(((tag,), p))
    queue = deque([((), base_plan)])
    while queue and len(out) < max_plans:
        moves, plan = queue.popleft()
        for tag, nb in neighbor_plans(plan):
            sig = plan_signature(nb)
            if sig in seen or not plan_is_legal(cdfg, nb):
                continue
            seen.add(sig)
            rec = (moves + (tag,), nb)
            out.append(rec)
            queue.append(rec)
            if len(out) >= max_plans:
                break
    return out


# ---------------------------------------------------------------------------
# Resource model
# ---------------------------------------------------------------------------


def partition_resources(part: Partition, fifo_depth: int) -> dict:
    """The resource footprint the constraints prune against: channel
    payload bits, total FIFO storage at ``fifo_depth``, the widest
    stage's memory-port count (one access interface per region), and the
    §III-B1 duplication count (replica instances across stages)."""
    channel_bits = sum(c.nbytes for c in part.channels) * 8
    return {
        "num_stages": len(part.stages),
        "num_channels": len(part.channels),
        "channel_bits": channel_bits,
        "fifo_bits": fifo_depth * channel_bits,
        "max_mem_ports": max((len(s.regions) for s in part.stages),
                             default=0),
        "duplicated_nodes": sum(len(v)
                                for v in part.duplicated.values()),
    }


def constraint_violation(res: Mapping[str, int],
                         rc: ResourceConstraints) -> str | None:
    """First violated limit as a human-readable reason, or None."""
    checks = (
        ("fifo_bits", rc.max_fifo_bits),
        ("max_mem_ports", rc.max_mem_ports_per_stage),
        ("duplicated_nodes", rc.max_duplicated_nodes),
        ("num_stages", rc.max_stages),
    )
    for field, limit in checks:
        if limit is not None and res[field] > limit:
            return f"{field} {res[field]} > {limit}"
    return None


# ---------------------------------------------------------------------------
# Candidate evaluation
# ---------------------------------------------------------------------------


def traces_by_node(cdfg: CDFG, base_partition: Partition,
                   traces: Any = None, *, n_iters: int = 4096,
                   seed: int = 0,
                   address_space: int = 4 << 20) -> dict[int,
                                                         list[MemAccess]]:
    """Pin address traces to memory *nodes* so every candidate partition
    sees identical traffic no matter how it groups the ops.

    Deliberate deviation from ``Schedule.sim_stages``: that bridge
    attaches traces per (stage, region), so merging two stages that
    touch one region would *drop* traffic mid-search; here each memory
    node keeps its stream across candidates (conserved traffic, honest
    comparisons).  For kernels where several ops share a region the two
    bridges therefore model different traffic — compare DSE cycles
    against DSE cycles, not against ``Compiled.simulate()``.

    Trace conventions accepted (same shapes as ``sim_stages``):

    * ``None`` — synthetic uniform-random **byte** addresses, one stream
      per region (the cache-hostile default);
    * a mapping ``region -> MemAccess | [MemAccess]`` — a single trace
      is shared by all of the region's ops; a list is assigned
      positionally to the region's ops in node order;
    * a sequence of :class:`MemAccess` — positional, over memory nodes
      in the *baseline* partition's pipeline order (the Fig. 5
      benchmark convention).
    """
    mem_nodes = [nid for st in base_partition.stages
                 for nid in st.node_ids if cdfg.node(nid).is_memory]
    out: dict[int, list[MemAccess]] = {}
    if traces is not None and not isinstance(traces, Mapping):
        # A shorter list leaves trailing memory ops traffic-less — the
        # established ``sim_stages`` convention (the paper kernels
        # supply one stream per *distinct* traffic source, not per op),
        # applied identically to every candidate so comparisons stay
        # apples-to-apples.
        for nid, tr in zip(mem_nodes, list(traces)):
            out[nid] = [tr]
        return out
    rng = np.random.default_rng(seed)
    by_region: dict[str, Any] = dict(traces or {})
    assigned: dict[str, int] = {}
    for nid in mem_nodes:
        region = cdfg.node(nid).region
        if region is None:
            continue
        tr = by_region.get(region)
        if tr is None and traces is None:
            tr = MemAccess(region,
                           rng.integers(0, address_space, n_iters) * 4)
            by_region[region] = tr
        if tr is None:
            continue
        if isinstance(tr, MemAccess):
            out[nid] = [tr]
        else:  # list: positional among the region's ops, last one reused
            i = assigned.get(region, 0)
            assigned[region] = i + 1
            out[nid] = [tr[min(i, len(tr) - 1)]]
    return out


def sim_stages_for_partition(part: Partition,
                             node_traces: Mapping[int, list[MemAccess]],
                             cyclic_mem: set[int]) -> list[SimStage]:
    """Cycle-simulator stage specs for one candidate partition: II and
    latency from the materialized (and possibly duplicated-into) stages,
    traces attached per memory node, ``mem_in_scc`` from the CDFG's
    cyclic memory nodes (partition-independent)."""
    out: list[SimStage] = []
    for st in part.stages:
        accs = [t for nid in st.node_ids
                for t in node_traces.get(nid, ())]
        out.append(SimStage(
            name=f"s{st.id}",
            ii=st.ii,
            latency=max(1, st.latency),
            accesses=accs,
            mem_in_scc=bool(cyclic_mem & set(st.node_ids)),
        ))
    return out


def evaluate_candidates(
    stage_lists: Sequence[Sequence[SimStage]],
    mem: MemoryModel,
    n_iters: int,
    *,
    fifo_depth: int | None = None,
    fifo_depths: Sequence[int] | None = None,
    depth_lists: Sequence[Sequence[int]] | None = None,
    seed: int = 0,
    use_rescache: bool | None = None,
    chunk_iters: int | None = None,
    depth_incremental: bool = True,
) -> tuple[list[dict[int, int]], dict]:
    """Simulate many candidate stage decompositions of *one* kernel —
    each over a grid of FIFO depths — in a single chunk-major streaming
    pass.

    Candidates are grouped by their per-op resolution key: each distinct
    group resolves its traces once (served from the chunk-granular
    rescache when possible — any stored prefix counts — and written
    back when not), and every candidate then only pays the cheap
    per-stage fold plus one wavefront solve per depth.  Depths are
    solved deepest-first with the depth-incremental warm start, so
    gridding depth costs little more than one solve per candidate.
    Iterating chunk-major keeps the per-trace window/burst memos hot,
    so sibling candidates regenerate nothing.  Cycle counts are
    bit-identical to stand-alone
    :func:`repro.core.simulator.simulate_dataflow` runs (same canonical
    access order, same draw streams — asserted in tests).

    Depths per candidate come from ``depth_lists`` (one sequence per
    candidate), else the shared ``fifo_depths``, else the single
    ``fifo_depth`` (default 8).  Returns ``(per-candidate {depth:
    cycles} dicts, stats)``.
    """
    from ..core import rescache as _rc
    from ..core.simulator import (DEFAULT_CHUNK_ITERS, _LaneSolver,
                                  _OpFolder, _ResolutionPlan,
                                  _ResolvedChunk, _ServeLost,
                                  _chunk_bounds, _fold_stage)
    chunk_iters = chunk_iters or DEFAULT_CHUNK_ITERS
    if depth_lists is None:
        shared = tuple(fifo_depths) if fifo_depths is not None \
            else (fifo_depth if fifo_depth is not None else 8,)
        depth_lists = [shared] * len(stage_lists)
    if n_iters <= 0 or not stage_lists:
        return [{d: 0 for d in ds} for ds in depth_lists], \
            {"resolution_groups": 0, "cold_groups": 0}

    def _run(rescache_override: bool | None) -> tuple[list[dict[int,
                                                                int]],
                                                      dict]:
        groups: dict[str, dict] = {}
        gkeys: list[str] = []
        for stages in stage_lists:
            gkey = _rc.resolution_key("dataflow", stages, mem, seed)
            gkeys.append(gkey)
            if gkey not in groups:
                groups[gkey] = {
                    "stages": stages,
                    "plan": _ResolutionPlan(
                        "dataflow", stages, {mem.name: mem}, seed,
                        n_iters, rescache_override)}
        folders = [_OpFolder(st) for st in stage_lists]
        solvers = [{d: _LaneSolver(st, d, collect_stalls=False)
                    for d in ds}
                   for st, ds in zip(stage_lists, depth_lists)]
        align = _rc.CHUNK_ITERS if _rc.enabled(rescache_override) \
            else None
        for lo, hi in _chunk_bounds(n_iters, chunk_iters, align):
            n = hi - lo
            zero = np.zeros(n, dtype=np.int32)
            for g in groups.values():
                plan = g["plan"]
                chunks = plan.advance(lo, hi)
                if mem.name in plan.served:
                    g["L"] = plan.served[mem.name].chunk(lo, hi)
                    g["spec_chunk"] = None
                    _rc.note_chunks(served=1)
                elif plan.live_chunk_is_served(lo):
                    g["L"] = plan.live_ops(mem.name, lo, hi)
                    g["spec_chunk"] = None
                else:
                    g["spec_chunk"] = chunks[mem.name]
                    g["L"] = plan.resolver.last_ops[mem.name]

                # contiguous column views, shared by every candidate of
                # the group this chunk
                def _mk_col(L: np.ndarray, cc: dict) -> Any:
                    def col(k: int) -> np.ndarray:
                        a = cc.get(k)
                        if a is None:
                            a = cc[k] = np.ascontiguousarray(L[:, k])
                        return a
                    return col
                g["col"] = _mk_col(g["L"], {})
            # candidates mostly differ in one or two stages: fold each
            # distinct (group, op set, ii, serialized) stage once per
            # chunk
            fold_cache: dict[tuple, tuple] = {}
            for i, folder in enumerate(folders):
                g = groups[gkeys[i]]
                if g["spec_chunk"] is not None \
                        and g["stages"] is stage_lists[i]:
                    res = g["spec_chunk"]  # group spec: already folded
                else:
                    bw = None
                    c_list, lat_list = [], []
                    for s, st in enumerate(stage_lists[i]):
                        key = (gkeys[i], tuple(folder.stage_cols[s]),
                               st.ii, st.mem_in_scc)
                        hit = fold_cache.get(key)
                        if hit is None:
                            if bw is None:
                                bw = folder.burst_words(lo, hi,
                                                        mem.line_bytes)
                            hit = _fold_stage(
                                mem, st.ii, st.mem_in_scc,
                                folder.stage_cols[s], g["col"], bw[s],
                                folder.is_store, n, zero)
                            fold_cache[key] = hit
                        c_list.append(hit[0])
                        lat_list.append(hit[1])
                    res = _ResolvedChunk(lo, hi, c_list, lat_list)
                warm = None
                for d in sorted(solvers[i], reverse=True):
                    warm = solvers[i][d].solve_chunk(
                        res, warm=warm if depth_incremental else None)
        stats = {"resolution_groups": len(groups),
                 "cold_groups": sum(
                     1 for g in groups.values()
                     if g["plan"].resolver is not None)}
        return [{d: int(sv.last_finish) for d, sv in by_depth.items()}
                for by_depth in solvers], stats

    try:
        return _run(use_rescache)
    except _ServeLost:  # raced store eviction: redo the pass cold
        return _run(False)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DseCandidate:
    """One explored (plan, duplicate-toggle, FIFO-depth) point."""

    groups: tuple[tuple[int, ...], ...]   # plan signature (node-id groups)
    moves: tuple[str, ...]
    duplicate: bool
    resources: dict
    fifo_depth: int = 8
    cycles: int | None = None             # None => pruned, not simulated
    pruned: str | None = None
    pareto: bool = False
    compiled: Any = None                  # Compiled, attached on the front
    plan: StagePlan | None = dataclasses.field(default=None, repr=False)

    @property
    def fifo_bits(self) -> int:
        return self.resources["fifo_bits"]

    def to_json(self) -> dict:
        return {
            "moves": list(self.moves),
            "duplicate": self.duplicate,
            "fifo_depth": self.fifo_depth,
            "cycles": self.cycles,
            "pruned": self.pruned,
            "pareto": self.pareto,
            **{k: self.resources[k]
               for k in ("num_stages", "num_channels", "fifo_bits",
                         "max_mem_ports", "duplicated_nodes")},
        }


@dataclasses.dataclass
class DseResult:
    """The explored partition space: every candidate, the baseline
    (Algorithm 1 as configured), and the cycles-vs-FIFO-bits Pareto
    front.  ``Compiled.explore`` attaches a full ``Compiled`` artifact
    to each front candidate (``cand.compiled``)."""

    baseline: DseCandidate
    candidates: list[DseCandidate]
    front: list[DseCandidate]
    n_iters: int
    fifo_depth: int
    mem_name: str
    #: the explored FIFO-depth axis (a single entry unless the joint
    #: partition×depth front was requested via ``fifo_depths=...``)
    fifo_depths: tuple = ()
    wall_s: float = 0.0
    rescache_hits: int = 0
    rescache_misses: int = 0
    #: from evaluate_candidates: distinct resolution groups / cold ones
    eval_stats: dict = dataclasses.field(default_factory=dict)

    def evaluated(self) -> list[DseCandidate]:
        return [c for c in self.candidates if c.cycles is not None]

    def best(self) -> DseCandidate:
        """Feasible candidate minimizing (cycles, fifo_bits); the
        baseline when nothing else was evaluated."""
        ev = [c for c in self.evaluated() if c.pruned is None]
        if not ev:
            return self.baseline
        return min(ev, key=lambda c: (c.cycles, c.fifo_bits))

    def dominates_baseline(self) -> bool:
        """Does some candidate strictly dominate Algorithm 1's plan —
        fewer cycles at ≤ the FIFO bits, or ≤ cycles at fewer bits?"""
        b = self.baseline
        if b.cycles is None:
            return bool(self.evaluated())
        return any(
            (c.cycles < b.cycles and c.fifo_bits <= b.fifo_bits)
            or (c.cycles <= b.cycles and c.fifo_bits < b.fifo_bits)
            for c in self.evaluated() if c is not b)

    def to_json(self) -> dict:
        return {
            "n_iters": self.n_iters,
            "fifo_depth": self.fifo_depth,
            "fifo_depths": list(self.fifo_depths or (self.fifo_depth,)),
            "mem": self.mem_name,
            "wall_s": self.wall_s,
            "rescache_hits": self.rescache_hits,
            "rescache_misses": self.rescache_misses,
            **self.eval_stats,
            "dominates_baseline": self.dominates_baseline(),
            "baseline": self.baseline.to_json(),
            "best": self.best().to_json(),
            "front": [c.to_json() for c in self.front],
            "candidates": [c.to_json() for c in self.candidates],
        }

    def summary(self) -> str:
        ev = self.evaluated()
        lines = [
            f"partition DSE: {len(self.candidates)} candidates "
            f"({len(ev)} simulated at {self.n_iters} iters on "
            f"{self.mem_name!r}, fifo_depth={self.fifo_depth}; "
            f"rescache {self.rescache_hits} hits / "
            f"{self.rescache_misses} misses)",
            f"  baseline (Algorithm 1): {self.baseline.cycles} cycles @ "
            f"{self.baseline.fifo_bits} FIFO bits, "
            f"{self.baseline.resources['num_stages']} stages",
        ]
        multi_depth = len(set(self.fifo_depths
                              or (self.fifo_depth,))) > 1
        for c in self.front:
            tag = " <- baseline" if c is self.baseline else ""
            depth = f", depth={c.fifo_depth}" if multi_depth else ""
            lines.append(
                f"  front: {c.cycles} cycles @ {c.fifo_bits} bits "
                f"({c.resources['num_stages']} stages, dup="
                f"{c.duplicate}{depth}, moves="
                f"{'/'.join(c.moves) or 'none'}){tag}")
        b = self.best()
        lines.append(
            f"  best: {b.cycles} cycles @ {b.fifo_bits} bits "
            f"(moves={'/'.join(b.moves) or 'none'}, dup={b.duplicate})"
            + ("  [strictly dominates Algorithm 1]"
               if self.dominates_baseline() else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The explorer
# ---------------------------------------------------------------------------


def explore_plans(
    cdfg: CDFG,
    base_plan: StagePlan,
    *,
    constraints: ResourceConstraints | None = None,
    mem: MemoryModel | None = None,
    node_traces: Mapping[int, list[MemAccess]] | None = None,
    duplicate_base: bool = True,
    n_iters: int | None = None,
    fifo_depth: int | None = None,
    fifo_depths: Sequence[int] | None = None,
    seed: int | None = None,
    max_candidates: int | None = None,
    use_rescache: bool | None = None,
    server: str | None = None,
) -> DseResult:
    """Enumerate → prune → simulate → Pareto, over ``(plan, duplicate,
    FIFO depth)`` candidates (no ``Compiled`` construction — see
    :func:`explore` / ``Compiled.explore`` for that layer).

    ``fifo_depths`` turns on the *joint* partition×depth search: every
    (plan, duplicate) pair is costed and simulated at every depth (one
    resolution, one warm-started solve per depth), and the Pareto front
    spans both axes.  The enumeration budget ``max_candidates`` counts
    (plan, duplicate) pairs, not depth points."""
    from ..core import rescache as _rc
    rc = constraints or ResourceConstraints()
    n_iters = rc.n_iters if n_iters is None else n_iters
    if fifo_depths is None:
        fifo_depths = getattr(rc, "fifo_depths", None)
    primary_depth = rc.fifo_depth if fifo_depth is None else fifo_depth
    depths = tuple(dict.fromkeys(fifo_depths)) if fifo_depths \
        else (primary_depth,)
    if primary_depth not in depths:
        primary_depth = depths[0]
    seed = rc.seed if seed is None else seed
    max_candidates = rc.max_candidates if max_candidates is None \
        else max_candidates
    if mem is None:
        mem = standard_memory_models()[rc.mem]()
    if node_traces is None:
        node_traces = traces_by_node(
            cdfg, materialize(cdfg, base_plan), None,
            n_iters=n_iters, seed=seed)
    cyclic = _cyclic_nodes(cdfg)
    cyclic_mem = {nid for nid in cyclic if cdfg.node(nid).is_memory}
    # the §III-B1 duplication rewrite is a per-candidate *move*, explored
    # in both directions regardless of the base setting — forbid it
    # outright with max_duplicated_nodes=0
    dup_options = (duplicate_base, not duplicate_base)

    stats0 = _rc.stats()
    t0 = time.perf_counter()
    plans = enumerate_plans(cdfg, base_plan, max_candidates)
    candidates: list[DseCandidate] = []
    baseline: DseCandidate | None = None
    #: one entry per simulated stage list: (per-depth candidates, stages)
    sim_list: list[tuple[dict[int, DseCandidate], list[SimStage]]] = []
    n_pairs = 0
    for moves, plan in plans:
        if n_pairs >= max_candidates and baseline is not None:
            break
        dup_effect = None
        for dup in dup_options:
            if n_pairs >= max_candidates and baseline is not None:
                break
            part = materialize(cdfg, plan)
            if dup:
                duplicate_cheap_rewrite(part)
                dup_effect = bool(part.duplicated)
            if dup != dup_options[0] and not dup_effect:
                # the rewrite is a no-op for this plan: the toggled
                # variant would be byte-identical — don't burn budget
                # (and a redundant solve) on it
                continue
            n_pairs += 1
            is_base_pair = not moves and dup == duplicate_base
            to_sim: dict[int, DseCandidate] = {}
            for d in depths:
                res = partition_resources(part, d)
                cand = DseCandidate(
                    groups=plan_signature(plan),
                    moves=moves + (() if dup == duplicate_base
                                   else ("duplicate" if dup
                                         else "no-duplicate",)),
                    duplicate=dup, resources=res, fifo_depth=d,
                    plan=plan)
                is_base = is_base_pair and d == primary_depth
                cand.pruned = constraint_violation(res, rc)
                # the baseline is always simulated — it is the
                # comparison point even when it violates the constraints
                if cand.pruned is None or is_base:
                    to_sim[d] = cand
                if is_base:
                    baseline = cand
                candidates.append(cand)
            if to_sim:
                sim_list.append((to_sim, sim_stages_for_partition(
                    part, node_traces, cyclic_mem)))
    if server:
        # resolve every distinct survivor group through the daemon
        # first (shared spawn-pool, in-flight dedup with concurrent
        # explorers); the chunk-major pass below then serves the grid
        # from the store.  Best-effort: a missing daemon or an
        # over-cap artifact just resolves cold locally as before.
        from ..serve.client import ServeUnavailable, prefetch
        addr = None if server == "auto" else server
        for _, st in sim_list:
            try:
                prefetch(st, {mem.name: mem}, n_iters, seed=seed,
                         address=addr)
            except ServeUnavailable:
                break
    # one chunk-major pass simulates every survivor, sharing trace
    # resolution across candidates (and with past/future runs via the
    # chunk-granular rescache); each candidate's depth grid shares one
    # fold and warm-starts shallower depths from deeper fixed points
    cycles, eval_stats = evaluate_candidates(
        [st for _, st in sim_list], mem, n_iters,
        depth_lists=[tuple(by_depth) for by_depth, _ in sim_list],
        seed=seed, use_rescache=use_rescache)
    for (by_depth, _), cyc in zip(sim_list, cycles):
        for d, cand in by_depth.items():
            cand.cycles = cyc[d]
    stats1 = _rc.stats()

    # cycles-vs-FIFO-bits front over feasible evaluated candidates
    front: list[DseCandidate] = []
    best_cycles: int | None = None
    pool = [c for c in candidates
            if c.cycles is not None and c.pruned is None]
    for c in sorted(pool, key=lambda c: (c.fifo_bits, c.cycles)):
        if best_cycles is None or c.cycles < best_cycles:
            best_cycles = c.cycles
            c.pareto = True
            front.append(c)
    return DseResult(
        baseline=baseline, candidates=candidates, front=front,
        n_iters=n_iters, fifo_depth=primary_depth, mem_name=mem.name,
        fifo_depths=depths, wall_s=time.perf_counter() - t0,
        rescache_hits=stats1["mem_hits"] + stats1["disk_hits"]
        - stats0["mem_hits"] - stats0["disk_hits"],
        rescache_misses=stats1["misses"] - stats0["misses"],
        eval_stats=eval_stats)


def compiled_with_plan(base: Any, plan: StagePlan,
                       duplicate: bool) -> Any:
    """Materialize a full ``Compiled`` artifact for one explored plan:
    the front-end products (jaxpr, CDFG) are shared with ``base``, the
    partition is rebuilt from ``plan``, and the decouple/schedule passes
    re-run.  Bypasses the compile cache (candidate plans are not
    reachable from options alone)."""
    from .driver import Compiled
    from .passes import CompileContext, DecouplePass, SchedulePass
    opts = base.options.replace(duplicate_cheap=duplicate, dse=None)
    ctx = CompileContext(fn=base.fn,
                         example_args=base.context.example_args,
                         options=opts)
    ctx.closed_jaxpr = base.context.closed_jaxpr
    ctx.out_tree = base.context.out_tree
    ctx.cdfg = base.context.cdfg
    ctx.plan = plan
    part = materialize(ctx.cdfg, plan)
    if duplicate:
        duplicate_cheap_rewrite(part)
    ctx.partition = part
    DecouplePass().run(ctx)
    SchedulePass().run(ctx)
    return Compiled(ctx, base.pipeline)


def explore(
    compiled: Any,
    *,
    traces: Any = None,
    constraints: ResourceConstraints | None = None,
    mem: MemoryModel | None = None,
    n_iters: int | None = None,
    fifo_depth: int | None = None,
    fifo_depths: Sequence[int] | None = None,
    seed: int | None = None,
    max_candidates: int | None = None,
    use_rescache: bool | None = None,
    server: str | None = None,
) -> DseResult:
    """``Compiled.explore`` implementation: explore re-partitionings of
    ``compiled``'s kernel and return the cycles-vs-FIFO-bits Pareto
    front with a ``Compiled`` artifact attached to every front (and the
    best) candidate.  Pass ``fifo_depths=[...]`` for the joint
    partition×depth front (each candidate costed and simulated at every
    depth; the channel FIFO depth becomes a search axis instead of a
    fixed parameter)."""
    rc = constraints or compiled.options.dse or ResourceConstraints()
    n_iters = rc.n_iters if n_iters is None else n_iters
    seed = rc.seed if seed is None else seed
    node_traces = traces_by_node(
        compiled.cdfg, compiled.partition, traces,
        n_iters=n_iters, seed=seed)
    result = explore_plans(
        compiled.cdfg, compiled.context.plan,
        constraints=rc, mem=mem, node_traces=node_traces,
        duplicate_base=compiled.options.duplicate_cheap,
        n_iters=n_iters, fifo_depth=fifo_depth,
        fifo_depths=fifo_depths, seed=seed,
        max_candidates=max_candidates, use_rescache=use_rescache,
        server=server)
    for cand in {id(c): c for c in result.front + [result.best()]}.values():
        if cand.compiled is None:
            # the baseline IS the caller's artifact (same plan, same
            # duplication setting) — no need to re-decouple/schedule
            cand.compiled = compiled if cand is result.baseline \
                else compiled_with_plan(compiled, cand.plan,
                                        cand.duplicate)
    return result

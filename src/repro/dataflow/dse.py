"""Partition-space design-space exploration over :class:`StagePlan`s.

Algorithm 1 fixes *one* partitioning rule — cut after every memory op or
long SCC — but the quality of the dataflow template under area/FIFO
constraints depends on *which* partition you pick: HIDA (Ye et al.,
2023) shows hierarchical dataflow DSE over partitions is where the real
wins are, and de Fine Licht et al. (2018) catalog the merge / split /
duplicate transformations such a search must enumerate.  This module is
that explorer for the template:

1. **Enumerate** — BFS over the legal single moves (adjacent-stage
   merges, interior splits; SCCs are never split and topological order
   is preserved by construction — see
   :func:`repro.core.partition.neighbor_plans`) from the Algorithm 1
   plan, with the ``fused`` / ``maximal`` degenerate plans always
   included; the §III-B1 cheap-op duplication rewrite is a per-candidate
   toggle (the *duplicate* move), and the HLS transformation catalog
   (:mod:`repro.dataflow.transforms` — unroll/vectorize, access
   coalescing, memory-port re-association) adds per-candidate transform
   lanes plus a re-associated plan seed.
2. **Prune** — against :class:`~repro.dataflow.options.ResourceConstraints`:
   total FIFO bits, per-stage memory-port count, duplication budget,
   stage count.  Pruned candidates are never simulated.
3. **Evaluate** — every survivor runs through the *real* cycle
   simulator (no analytic shortcut).  Candidate partitions of one
   kernel regroup the same memory ops, so the per-op rescache keying
   (:mod:`repro.core.rescache`) lets every candidate after the first
   serve its trace resolution from cache: DSE over many candidates
   costs little more than one cold simulation, with cycle counts
   bit-identical to fresh per-candidate runs.
4. **Select** — the cycles-vs-FIFO-bits Pareto front, each front point
   materialized as a full :class:`~repro.dataflow.driver.Compiled`
   artifact (``Compiled.explore``), or the constrained-best plan
   compiled in place (the ``dse`` pass, ``dataflow_jit(..., dse=...)``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping, Sequence

import numpy as np

from ..core.cdfg import CDFG
from ..core.partition import (StagePlan, Partition, materialize,
                              duplicate_cheap_rewrite, fused_plan,
                              maximal_plan, neighbor_plans, plan_is_legal,
                              plan_signature)
from ..core.simulator import (MemAccess, MemoryModel, SimStage,
                              standard_memory_models)
from .options import ResourceConstraints
from .schedule import _cyclic_nodes


# ---------------------------------------------------------------------------
# Enumeration
# ---------------------------------------------------------------------------


def enumerate_plans(cdfg: CDFG, base_plan: StagePlan,
                    max_plans: int, *,
                    reassoc: bool = False) -> list[tuple[tuple[str, ...],
                                                         StagePlan]]:
    """Breadth-first closure of the merge/split move set from
    ``base_plan``, deduplicated by :func:`plan_signature` and capped at
    ``max_plans``.  The fused and maximal degenerate plans are seeded
    explicitly so they are reachable at any budget; ``reassoc=True``
    additionally seeds the memory-port re-association split
    (:func:`repro.dataflow.transforms.split_by_region` — multi-region
    stages split by region, the documented DSE gap).  Returns
    ``(moves, plan)`` pairs; the base plan is first with an empty move
    list."""
    from collections import deque

    seeds = [("fused", fused_plan(base_plan)),
             ("maximal", maximal_plan(base_plan))]
    if reassoc:
        from .transforms import split_by_region
        seeds.insert(0, ("reassoc", split_by_region(cdfg, base_plan)))
    out: list[tuple[tuple[str, ...], StagePlan]] = [((), base_plan)]
    seen = {plan_signature(base_plan)}
    for tag, p in seeds:
        sig = plan_signature(p)
        if sig not in seen and plan_is_legal(cdfg, p):
            seen.add(sig)
            out.append(((tag,), p))
    queue = deque([((), base_plan)])
    while queue and len(out) < max_plans:
        moves, plan = queue.popleft()
        for tag, nb in neighbor_plans(plan):
            sig = plan_signature(nb)
            if sig in seen or not plan_is_legal(cdfg, nb):
                continue
            seen.add(sig)
            rec = (moves + (tag,), nb)
            out.append(rec)
            queue.append(rec)
            if len(out) >= max_plans:
                break
    return out


# ---------------------------------------------------------------------------
# Resource model
# ---------------------------------------------------------------------------


def partition_resources(part: Partition, fifo_depth: int) -> dict:
    """The resource footprint the constraints prune against: channel
    payload bits, total FIFO storage at ``fifo_depth``, the widest
    stage's memory-port count (one access interface per region), and the
    §III-B1 duplication count (replica instances across stages)."""
    channel_bits = sum(c.nbytes for c in part.channels) * 8
    return {
        "num_stages": len(part.stages),
        "num_channels": len(part.channels),
        "channel_bits": channel_bits,
        "fifo_bits": fifo_depth * channel_bits,
        "max_mem_ports": max((len(s.regions) for s in part.stages),
                             default=0),
        "duplicated_nodes": sum(len(v)
                                for v in part.duplicated.values()),
    }


def constraint_violation(res: Mapping[str, int],
                         rc: ResourceConstraints) -> str | None:
    """First violated limit as a human-readable reason, or None."""
    checks = (
        ("fifo_bits", rc.max_fifo_bits),
        ("max_mem_ports", rc.max_mem_ports_per_stage),
        ("duplicated_nodes", rc.max_duplicated_nodes),
        ("num_stages", rc.max_stages),
    )
    for field, limit in checks:
        if limit is not None and res[field] > limit:
            return f"{field} {res[field]} > {limit}"
    return None


# ---------------------------------------------------------------------------
# Candidate evaluation
# ---------------------------------------------------------------------------


def traces_by_node(cdfg: CDFG, base_partition: Partition,
                   traces: Any = None, *, n_iters: int = 4096,
                   seed: int = 0,
                   address_space: int = 4 << 20) -> dict[int,
                                                         list[MemAccess]]:
    """Pin address traces to memory *nodes* so every candidate partition
    sees identical traffic no matter how it groups the ops.

    Deliberate deviation from ``Schedule.sim_stages``: that bridge
    attaches traces per (stage, region), so merging two stages that
    touch one region would *drop* traffic mid-search; here each memory
    node keeps its stream across candidates (conserved traffic, honest
    comparisons).  For kernels where several ops share a region the two
    bridges therefore model different traffic — compare DSE cycles
    against DSE cycles, not against ``Compiled.simulate()``.

    Trace conventions accepted (same shapes as ``sim_stages``):

    * ``None`` — synthetic uniform-random **byte** addresses, one stream
      per region (the cache-hostile default);
    * a mapping ``region -> MemAccess | [MemAccess]`` — a single trace
      is shared by all of the region's ops; a list is assigned
      positionally to the region's ops in node order;
    * a sequence of :class:`MemAccess` — positional, over memory nodes
      in the *baseline* partition's pipeline order (the Fig. 5
      benchmark convention).
    """
    mem_nodes = [nid for st in base_partition.stages
                 for nid in st.node_ids if cdfg.node(nid).is_memory]
    out: dict[int, list[MemAccess]] = {}
    if traces is not None and not isinstance(traces, Mapping):
        # A shorter list leaves trailing memory ops traffic-less — the
        # established ``sim_stages`` convention (the paper kernels
        # supply one stream per *distinct* traffic source, not per op),
        # applied identically to every candidate so comparisons stay
        # apples-to-apples.
        for nid, tr in zip(mem_nodes, list(traces)):
            out[nid] = [tr]
        return out
    rng = np.random.default_rng(seed)
    by_region: dict[str, Any] = dict(traces or {})
    assigned: dict[str, int] = {}
    for nid in mem_nodes:
        region = cdfg.node(nid).region
        if region is None:
            continue
        tr = by_region.get(region)
        if tr is None and traces is None:
            tr = MemAccess(region,
                           rng.integers(0, address_space, n_iters) * 4)
            by_region[region] = tr
        if tr is None:
            continue
        if isinstance(tr, MemAccess):
            out[nid] = [tr]
        else:  # list: positional among the region's ops, last one reused
            i = assigned.get(region, 0)
            assigned[region] = i + 1
            out[nid] = [tr[min(i, len(tr) - 1)]]
    return out


def sim_stages_for_partition(part: Partition,
                             node_traces: Mapping[int, list[MemAccess]],
                             cyclic_mem: set[int]) -> list[SimStage]:
    """Cycle-simulator stage specs for one candidate partition: II and
    latency from the materialized (and possibly duplicated-into) stages,
    traces attached per memory node, ``mem_in_scc`` from the CDFG's
    cyclic memory nodes (partition-independent)."""
    out: list[SimStage] = []
    for st in part.stages:
        accs = [t for nid in st.node_ids
                for t in node_traces.get(nid, ())]
        out.append(SimStage(
            name=f"s{st.id}",
            ii=st.ii,
            latency=max(1, st.latency),
            accesses=accs,
            mem_in_scc=bool(cyclic_mem & set(st.node_ids)),
        ))
    return out


def evaluate_candidates(
    stage_lists: Sequence[Sequence[SimStage]],
    mem: MemoryModel,
    n_iters: int,
    *,
    fifo_depth: int | None = None,
    fifo_depths: Sequence[int] | None = None,
    depth_lists: Sequence[Sequence[int]] | None = None,
    n_iters_list: Sequence[int] | None = None,
    seed: int = 0,
    use_rescache: bool | None = None,
    chunk_iters: int | None = None,
    depth_incremental: bool = True,
) -> tuple[list[dict[int, int]], dict]:
    """Simulate many candidate stage decompositions of *one* kernel —
    each over a grid of FIFO depths — in a single chunk-major streaming
    pass.

    Candidates are grouped by their per-op resolution key: each distinct
    group resolves its traces once (served from the chunk-granular
    rescache when possible — any stored prefix counts — and written
    back when not), and every candidate then only pays the cheap
    per-stage fold plus one wavefront solve per depth.  Depths are
    solved deepest-first with the depth-incremental warm start, so
    gridding depth costs little more than one solve per candidate.
    Iterating chunk-major keeps the per-trace window/burst memos hot,
    so sibling candidates regenerate nothing.  Cycle counts are
    bit-identical to stand-alone
    :func:`repro.core.simulator.simulate_dataflow` runs (same canonical
    access order, same draw streams — asserted in tests).

    Depths per candidate come from ``depth_lists`` (one sequence per
    candidate), else the shared ``fifo_depths``, else the single
    ``fifo_depth`` (default 8).  ``n_iters_list`` gives per-candidate
    iteration counts (transformed candidates stream
    ``tokens(n_iters) = ceil(n/U)`` channel tokens, so a mixed
    transformed/untransformed batch runs shorter lanes alongside the
    full-length ones; the shared chunk grid is clamped per candidate, so
    every lane sees exactly the chunk boundaries a stand-alone run of
    its own length would).  Returns ``(per-candidate {depth: cycles}
    dicts, stats)``.
    """
    from ..core import rescache as _rc
    from ..core.simulator import (DEFAULT_CHUNK_ITERS, _LaneSolver,
                                  _OpFolder, _ResolutionPlan,
                                  _ResolvedChunk, _ServeLost,
                                  _chunk_bounds, _fold_stage)
    chunk_iters = chunk_iters or DEFAULT_CHUNK_ITERS
    if depth_lists is None:
        shared = tuple(fifo_depths) if fifo_depths is not None \
            else (fifo_depth if fifo_depth is not None else 8,)
        depth_lists = [shared] * len(stage_lists)
    if n_iters_list is None:
        n_iters_list = [n_iters] * len(stage_lists)
    max_n = max(n_iters_list, default=n_iters)
    if max_n <= 0 or not stage_lists:
        return [{d: 0 for d in ds} for ds in depth_lists], \
            {"resolution_groups": 0, "cold_groups": 0}

    def _run(rescache_override: bool | None) -> tuple[list[dict[int,
                                                                int]],
                                                      dict]:
        # candidates sharing a resolution key always share an iteration
        # count (same op streams ⇒ same transform ⇒ same token count),
        # but key on both so a pathological mix stays correct
        groups: dict[tuple, dict] = {}
        gids: list[tuple] = []
        for stages, g_n in zip(stage_lists, n_iters_list):
            gid = (_rc.resolution_key("dataflow", stages, mem, seed), g_n)
            gids.append(gid)
            if gid not in groups:
                groups[gid] = {
                    "stages": stages,
                    "n": g_n,
                    "plan": _ResolutionPlan(
                        "dataflow", stages, {mem.name: mem}, seed,
                        g_n, rescache_override)}
        folders = [_OpFolder(st) for st in stage_lists]
        solvers = [{d: _LaneSolver(st, d, collect_stalls=False)
                    for d in ds}
                   for st, ds in zip(stage_lists, depth_lists)]
        align = _rc.CHUNK_ITERS if _rc.enabled(rescache_override) \
            else None
        zeros: dict[int, np.ndarray] = {}
        for lo, hi in _chunk_bounds(max_n, chunk_iters, align):
            for g in groups.values():
                if lo >= g["n"]:
                    continue
                hi_g = min(hi, g["n"])
                plan = g["plan"]
                chunks = plan.advance(lo, hi_g)
                if mem.name in plan.served:
                    g["L"] = plan.served[mem.name].chunk(lo, hi_g)
                    g["spec_chunk"] = None
                    _rc.note_chunks(served=1)
                elif plan.live_chunk_is_served(lo):
                    g["L"] = plan.live_ops(mem.name, lo, hi_g)
                    g["spec_chunk"] = None
                else:
                    g["spec_chunk"] = chunks[mem.name]
                    g["L"] = plan.resolver.last_ops[mem.name]

                # contiguous column views, shared by every candidate of
                # the group this chunk
                def _mk_col(L: np.ndarray, cc: dict) -> Any:
                    def col(k: int) -> np.ndarray:
                        a = cc.get(k)
                        if a is None:
                            a = cc[k] = np.ascontiguousarray(L[:, k])
                        return a
                    return col
                g["col"] = _mk_col(g["L"], {})
            # candidates mostly differ in one or two stages: fold each
            # distinct (group, op set, ii, serialized) stage once per
            # chunk
            fold_cache: dict[tuple, tuple] = {}
            for i, folder in enumerate(folders):
                if lo >= n_iters_list[i]:
                    continue
                g = groups[gids[i]]
                hi_g = min(hi, g["n"])
                n = hi_g - lo
                zero = zeros.get(n)
                if zero is None:
                    zero = zeros[n] = np.zeros(n, dtype=np.int32)
                if g["spec_chunk"] is not None \
                        and g["stages"] is stage_lists[i]:
                    res = g["spec_chunk"]  # group spec: already folded
                else:
                    bw = None
                    c_list, lat_list = [], []
                    for s, st in enumerate(stage_lists[i]):
                        key = (gids[i], tuple(folder.stage_cols[s]),
                               st.ii, st.mem_in_scc)
                        hit = fold_cache.get(key)
                        if hit is None:
                            if bw is None:
                                bw = folder.burst_words(lo, hi_g,
                                                        mem.line_bytes)
                            hit = _fold_stage(
                                mem, st.ii, st.mem_in_scc,
                                folder.stage_cols[s], g["col"], bw[s],
                                folder.is_store, n, zero)
                            fold_cache[key] = hit
                        c_list.append(hit[0])
                        lat_list.append(hit[1])
                    res = _ResolvedChunk(lo, hi_g, c_list, lat_list)
                warm = None
                for d in sorted(solvers[i], reverse=True):
                    warm = solvers[i][d].solve_chunk(
                        res, warm=warm if depth_incremental else None)
        stats = {"resolution_groups": len(groups),
                 "cold_groups": sum(
                     1 for g in groups.values()
                     if g["plan"].resolver is not None)}
        return [{d: int(sv.last_finish) for d, sv in by_depth.items()}
                for by_depth in solvers], stats

    try:
        return _run(use_rescache)
    except _ServeLost:  # raced store eviction: redo the pass cold
        return _run(False)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DseCandidate:
    """One explored (plan, duplicate-toggle, transform, memory-model,
    FIFO-depth) point."""

    groups: tuple[tuple[int, ...], ...]   # plan signature (node-id groups)
    moves: tuple[str, ...]
    duplicate: bool
    resources: dict
    fifo_depth: int = 8
    cycles: int | None = None             # None => pruned, not simulated
    pruned: str | None = None
    pareto: bool = False
    compiled: Any = None                  # Compiled, attached on the front
    plan: StagePlan | None = dataclasses.field(default=None, repr=False)
    #: transform-config signature ("none" = untransformed); ``tf`` keeps
    #: the config object for re-materialization
    transform: str = "none"
    tf: Any = dataclasses.field(default=None, repr=False)
    #: memory model this point was simulated on (multi-mem fronts)
    mem_name: str = ""
    #: channel tokens simulated (== n_iters unless unrolled)
    n_tokens: int | None = None
    #: static deadlock bound for this candidate's stage chain (the
    #: smallest uniform FIFO depth that cannot statically collapse the
    #: pipeline — ``repro.dataflow.verify.chain_deadlock_bound``);
    #: depths below it are pruned pre-simulation when verification is
    #: on, and ``bench_trend`` asserts no front point ever sits below
    #: its own bound (the analysis soundness guard)
    deadlock_min_depth: int | None = None

    @property
    def fifo_bits(self) -> int:
        return self.resources["fifo_bits"]

    def to_json(self) -> dict:
        return {
            "moves": list(self.moves),
            "duplicate": self.duplicate,
            "fifo_depth": self.fifo_depth,
            "transform": self.transform,
            "mem": self.mem_name,
            "n_tokens": self.n_tokens,
            "cycles": self.cycles,
            "pruned": self.pruned,
            "deadlock_min_depth": self.deadlock_min_depth,
            "pareto": self.pareto,
            **{k: self.resources[k]
               for k in ("num_stages", "num_channels", "fifo_bits",
                         "max_mem_ports", "duplicated_nodes")},
        }


@dataclasses.dataclass
class DseResult:
    """The explored partition space: every candidate, the baseline
    (Algorithm 1 as configured), and the cycles-vs-FIFO-bits Pareto
    front.  ``Compiled.explore`` attaches a full ``Compiled`` artifact
    to each front candidate (``cand.compiled``)."""

    baseline: DseCandidate
    candidates: list[DseCandidate]
    front: list[DseCandidate]
    n_iters: int
    fifo_depth: int
    mem_name: str
    #: the explored FIFO-depth axis (a single entry unless the joint
    #: partition×depth front was requested via ``fifo_depths=...``)
    fifo_depths: tuple = ()
    wall_s: float = 0.0
    rescache_hits: int = 0
    rescache_misses: int = 0
    #: from evaluate_candidates: distinct resolution groups / cold ones
    eval_stats: dict = dataclasses.field(default_factory=dict)
    #: memory models spanned (multi-mem fronts; first = primary)
    mem_names: tuple = ()
    #: transform-config signatures explored alongside the baseline's
    transforms: tuple = ()

    def evaluated(self) -> list[DseCandidate]:
        return [c for c in self.candidates if c.cycles is not None]

    def best(self) -> DseCandidate:
        """Feasible candidate minimizing (cycles, fifo_bits) on the
        *primary* memory model; the baseline when nothing else was
        evaluated."""
        ev = [c for c in self.evaluated() if c.pruned is None
              and c.mem_name == self.mem_name]
        if not ev:
            return self.baseline
        return min(ev, key=lambda c: (c.cycles, c.fifo_bits))

    def dominates_baseline(self) -> bool:
        """Does some candidate strictly dominate Algorithm 1's plan —
        fewer cycles at ≤ the FIFO bits, or ≤ cycles at fewer bits?
        Compared on the baseline's memory model only (cross-model cycle
        counts are not comparable)."""
        b = self.baseline
        if b.cycles is None:
            return bool(self.evaluated())
        return any(
            (c.cycles < b.cycles and c.fifo_bits <= b.fifo_bits)
            or (c.cycles <= b.cycles and c.fifo_bits < b.fifo_bits)
            for c in self.evaluated() if c is not b
            and c.mem_name == b.mem_name)

    def transformed_dominates(self) -> bool:
        """Does some *transformed* candidate strictly dominate the best
        untransformed point — fewer cycles at equal-or-lower FIFO bits —
        on any explored memory model?  This is the widened-front gate
        ``bench_trend.py`` enforces (a transformed front that stops
        dominating the stage-regrouping-only front is a regression)."""
        base_sig = self.baseline.transform
        ev = [c for c in self.evaluated() if c.pruned is None]
        for mn in self.mem_names or (self.mem_name,):
            unt = [c for c in ev if c.mem_name == mn
                   and c.transform == base_sig]
            tfc = [c for c in ev if c.mem_name == mn
                   and c.transform != base_sig]
            if not unt or not tfc:
                continue
            u = min(unt, key=lambda c: (c.cycles, c.fifo_bits))
            if any(t.cycles < u.cycles and t.fifo_bits <= u.fifo_bits
                   for t in tfc):
                return True
        return False

    def to_json(self) -> dict:
        return {
            "n_iters": self.n_iters,
            "fifo_depth": self.fifo_depth,
            "fifo_depths": list(self.fifo_depths or (self.fifo_depth,)),
            "mem": self.mem_name,
            "mems": list(self.mem_names or (self.mem_name,)),
            "transforms": list(self.transforms),
            "wall_s": self.wall_s,
            "rescache_hits": self.rescache_hits,
            "rescache_misses": self.rescache_misses,
            **self.eval_stats,
            "dominates_baseline": self.dominates_baseline(),
            "transformed_dominates": self.transformed_dominates(),
            "baseline": self.baseline.to_json(),
            "best": self.best().to_json(),
            "front": [c.to_json() for c in self.front],
            "candidates": [c.to_json() for c in self.candidates],
        }

    def summary(self) -> str:
        ev = self.evaluated()
        lines = [
            f"partition DSE: {len(self.candidates)} candidates "
            f"({len(ev)} simulated at {self.n_iters} iters on "
            f"{self.mem_name!r}, fifo_depth={self.fifo_depth}; "
            f"rescache {self.rescache_hits} hits / "
            f"{self.rescache_misses} misses)",
            f"  baseline (Algorithm 1): {self.baseline.cycles} cycles @ "
            f"{self.baseline.fifo_bits} FIFO bits, "
            f"{self.baseline.resources['num_stages']} stages",
        ]
        multi_depth = len(set(self.fifo_depths
                              or (self.fifo_depth,))) > 1
        multi_mem = len(set(self.mem_names or (self.mem_name,))) > 1
        for c in self.front:
            tag = " <- baseline" if c is self.baseline else ""
            depth = f", depth={c.fifo_depth}" if multi_depth else ""
            mm = f", mem={c.mem_name}" if multi_mem else ""
            tf = f", tf={c.transform}" if c.transform != "none" else ""
            lines.append(
                f"  front: {c.cycles} cycles @ {c.fifo_bits} bits "
                f"({c.resources['num_stages']} stages, dup="
                f"{c.duplicate}{depth}{mm}{tf}, moves="
                f"{'/'.join(c.moves) or 'none'}){tag}")
        b = self.best()
        lines.append(
            f"  best: {b.cycles} cycles @ {b.fifo_bits} bits "
            f"(moves={'/'.join(b.moves) or 'none'}, dup={b.duplicate})"
            + ("  [strictly dominates Algorithm 1]"
               if self.dominates_baseline() else "")
            + ("  [transformed front dominates untransformed]"
               if self.transformed_dominates() else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The explorer
# ---------------------------------------------------------------------------


def explore_plans(
    cdfg: CDFG,
    base_plan: StagePlan,
    *,
    constraints: ResourceConstraints | None = None,
    mem: MemoryModel | None = None,
    mems: Sequence[Any] | None = None,
    node_traces: Mapping[int, list[MemAccess]] | None = None,
    duplicate_base: bool = True,
    n_iters: int | None = None,
    fifo_depth: int | None = None,
    fifo_depths: Sequence[int] | None = None,
    seed: int | None = None,
    max_candidates: int | None = None,
    use_rescache: bool | None = None,
    server: str | None = None,
    transforms: Sequence[Any] | None = None,
    verify: bool | None = None,
) -> DseResult:
    """Enumerate → prune → simulate → Pareto, over ``(plan, duplicate,
    transform, memory model, FIFO depth)`` candidates (no ``Compiled``
    construction — see :func:`explore` / ``Compiled.explore`` for that
    layer).

    ``fifo_depths`` turns on the *joint* partition×depth search: every
    (plan, duplicate) pair is costed and simulated at every depth (one
    resolution, one warm-started solve per depth), and the Pareto front
    spans both axes.  ``transforms`` (a list of
    :class:`~repro.dataflow.transforms.TransformConfig`, or the
    ``unroll_factors`` / ``explore_coalesce`` / ``explore_reassoc``
    constraint knobs) widens the search with the HLS transformation
    catalog: each config is validated against the CDFG, its candidates
    are materialized with scaled channel widths/II (so
    ``max_fifo_bits`` prunes infeasible unroll factors before any
    simulation), its op streams are rewritten once and shared across
    candidates, and a ``reassoc`` request seeds the port-re-association
    split in the plan enumeration.  ``mems`` spans several memory
    models in one exploration (per-model Pareto fronts, concatenated;
    the first — or the explicit ``mem`` — is primary and hosts the
    baseline).  The enumeration budget ``max_candidates`` counts
    *untransformed* (plan, duplicate) pairs; the depth / transform /
    model grids multiply evaluated points, not the budget.

    ``verify`` (default: on, unless ``REPRO_VERIFY=0``) runs the static
    dataflow verifier on every candidate partition *before* paying for
    simulation: depths below the candidate's static deadlock bound are
    pruned with reason ``"deadlock: ..."``, partitions that dropped an
    ordering token / race with ``"race: ..."``
    (``eval_stats["pruned_deadlock"] / ["pruned_race"]`` count them;
    every candidate records its ``deadlock_min_depth``).  The baseline
    is still always simulated — it is the comparison point."""
    from ..core import rescache as _rc
    from . import verify as _vfy
    from .transforms import IDENTITY, TransformConfig, \
        transform_node_traces
    rc = constraints or ResourceConstraints()
    do_verify = _vfy.enabled(None) if verify is None else bool(verify)
    n_iters = rc.n_iters if n_iters is None else n_iters
    if fifo_depths is None:
        fifo_depths = getattr(rc, "fifo_depths", None)
    primary_depth = rc.fifo_depth if fifo_depth is None else fifo_depth
    depths = tuple(dict.fromkeys(fifo_depths)) if fifo_depths \
        else (primary_depth,)
    if primary_depth not in depths:
        primary_depth = depths[0]
    seed = rc.seed if seed is None else seed
    max_candidates = rc.max_candidates if max_candidates is None \
        else max_candidates

    # -- memory-model axis (multi-mem fronts) --------------------------------
    if mems is None:
        mems = getattr(rc, "mems", ()) or None
    if mems:
        models = standard_memory_models()
        mem_list = [m if isinstance(m, MemoryModel) else models[m]()
                    for m in mems]
        if mem is not None:
            mem_list = [mem] + [m for m in mem_list
                                if m.name != mem.name]
    else:
        mem_list = [mem if mem is not None
                    else standard_memory_models()[rc.mem]()]
    mem = mem_list[0]
    mem_names = tuple(m.name for m in mem_list)

    if node_traces is None:
        node_traces = traces_by_node(
            cdfg, materialize(cdfg, base_plan, transforms=IDENTITY), None,
            n_iters=n_iters, seed=seed)
    cyclic = _cyclic_nodes(cdfg)
    cyclic_mem = {nid for nid in cyclic if cdfg.node(nid).is_memory}

    # -- transform axis ------------------------------------------------------
    # tf=None is the identity lane: the artifact's *own* config (its
    # CDFG may already be transformed) — axis entries are absolute
    # configs, not composed on top of it
    base_cfg = getattr(cdfg, "transforms", None)
    if base_cfg is not None and base_cfg.is_identity:
        base_cfg = None
    reassoc = bool(getattr(rc, "explore_reassoc", False))
    src = transforms
    if src is None:
        src = []
        for u in getattr(rc, "unroll_factors", ()) or ():
            if u and int(u) > 1:
                src.append(TransformConfig(unroll=int(u)))
                if getattr(rc, "explore_coalesce", False):
                    src.append(TransformConfig(unroll=int(u),
                                               coalesce=True))
    axis: list[Any] = []
    for t in src:
        if t is None:
            continue
        if t.reassoc:
            reassoc = True
            t = dataclasses.replace(t, reassoc=False)
        if t.is_identity or t in axis:
            continue
        t.validate(cdfg)  # structural legality — raises TransformError
        axis.append(t)
    tf_axis: list[Any] = [None] + axis

    # transformed op streams, derived once from the node traces and
    # shared by every candidate of a lane (shared fingerprints, window/
    # burst memos, resolution keys); coalescing never applies to memory
    # ops on a dependence cycle (serialized per-request latency)
    tf_traces: dict[str, Any] = {}

    def _traces_for(eff: Any) -> Any:
        key = eff.signature() if eff is not None else "none"
        tr = tf_traces.get(key)
        if tr is None:
            tr = node_traces if eff is None or eff.is_identity \
                else transform_node_traces(node_traces, eff,
                                           serialized_nodes=cyclic_mem)
            tf_traces[key] = tr
        return tr

    # the §III-B1 duplication rewrite is a per-candidate *move*, explored
    # in both directions regardless of the base setting — forbid it
    # outright with max_duplicated_nodes=0
    dup_options = (duplicate_base, not duplicate_base)

    stats0 = _rc.stats()
    t0 = time.perf_counter()
    plans = enumerate_plans(cdfg, base_plan, max_candidates,
                            reassoc=reassoc)
    # race pruning is only meaningful when §III-A ordering was actually
    # requested: without mem edges the user asserted non-aliasing and
    # the verifier downgrades races to warnings
    has_mem_edges = any(e.kind == "mem" for e in cdfg.edges)
    pruned_stats = {"pruned_deadlock": 0, "pruned_race": 0}
    candidates: list[DseCandidate] = []
    baseline: DseCandidate | None = None
    #: per mem: (per-depth candidates, stages, token count) per lane
    sim_by_mem: dict[str, list[tuple[dict[int, DseCandidate],
                                     list[SimStage], int]]] = \
        {mn: [] for mn in mem_names}
    n_pairs = 0
    for moves, plan in plans:
        if n_pairs >= max_candidates and baseline is not None:
            break
        dup_effect = None
        for dup in dup_options:
            if n_pairs >= max_candidates and baseline is not None:
                break
            psig = plan_signature(plan)
            part0 = materialize(
                cdfg, plan,
                transforms=base_cfg if base_cfg is not None else IDENTITY)
            if dup:
                duplicate_cheap_rewrite(part0)
                dup_effect = bool(part0.duplicated)
            if dup != dup_options[0] and not dup_effect:
                # the rewrite is a no-op for this plan: the toggled
                # variant would be byte-identical — don't burn budget
                # (and a redundant solve) on it
                continue
            n_pairs += 1
            is_base_pair = not moves and dup == duplicate_base
            for tf in tf_axis:
                eff = tf if tf is not None else base_cfg
                sig = eff.signature() if eff is not None else "none"
                if tf is None:
                    part = part0
                else:
                    part = materialize(cdfg, plan, transforms=eff)
                    if dup:
                        duplicate_cheap_rewrite(part)
                ntk = eff.tokens(n_iters) if eff is not None else n_iters
                tmoves = moves + (() if dup == duplicate_base
                                  else ("duplicate" if dup
                                        else "no-duplicate",))
                if tf is not None:
                    tmoves = tmoves + tf.active()
                # static verification of the candidate, once per
                # (plan, dup, transform) lane: the deadlock bound of
                # the simulated stage chain, and any dropped ordering
                # token / decoupled-access race in the partition
                bound = _vfy.chain_deadlock_bound(
                    (s.latency for s in part.stages),
                    (s.ii for s in part.stages))
                race_reason: str | None = None
                if do_verify:
                    bad = [d for d in _vfy.verify_partition(
                               part, strict_races=has_mem_edges)
                           if d.severity == "error"
                           and d.rule in ("race", "mem-order")]
                    if bad:
                        race_reason = f"race: {bad[0].message}"
                stages: list[SimStage] | None = None
                for m in mem_list:
                    to_sim: dict[int, DseCandidate] = {}
                    for d in depths:
                        res = partition_resources(part, d)
                        cand = DseCandidate(
                            groups=psig, moves=tmoves, duplicate=dup,
                            resources=res, fifo_depth=d, plan=plan,
                            transform=sig, tf=eff, mem_name=m.name,
                            n_tokens=ntk, deadlock_min_depth=bound)
                        is_base = (is_base_pair and tf is None
                                   and m is mem_list[0]
                                   and d == primary_depth)
                        cand.pruned = constraint_violation(res, rc)
                        if do_verify and cand.pruned is None:
                            if race_reason is not None:
                                cand.pruned = race_reason
                                pruned_stats["pruned_race"] += 1
                            elif d < bound:
                                cand.pruned = (
                                    f"deadlock: fifo depth {d} < "
                                    f"static bound {bound}")
                                pruned_stats["pruned_deadlock"] += 1
                        # the baseline is always simulated — it is the
                        # comparison point even when it violates the
                        # constraints (depths < 1 can never simulate)
                        if (cand.pruned is None or is_base) and d >= 1:
                            to_sim[d] = cand
                        if is_base:
                            baseline = cand
                        candidates.append(cand)
                    if to_sim:
                        if stages is None:
                            # built lazily: a lane whose every depth is
                            # pruned (an over-budget unroll factor)
                            # never transforms its traces
                            stages = sim_stages_for_partition(
                                part, _traces_for(eff), cyclic_mem)
                        sim_by_mem[m.name].append((to_sim, stages, ntk))
    if server:
        # resolve every distinct survivor group through the daemon
        # first (shared spawn-pool, in-flight dedup with concurrent
        # explorers); the chunk-major pass below then serves the grid
        # from the store.  Best-effort: a missing daemon or an
        # over-cap artifact just resolves cold locally as before.
        from ..serve.client import ServeUnavailable, prefetch
        addr = None if server == "auto" else server
        ok = True
        for m in mem_list:
            if not ok:
                break
            for _, st, ntk in sim_by_mem[m.name]:
                try:
                    prefetch(st, {m.name: m}, ntk, seed=seed,
                             address=addr)
                except ServeUnavailable:
                    ok = False
                    break
    # one chunk-major pass per memory model simulates every survivor,
    # sharing trace resolution across candidates (and with past/future
    # runs via the chunk-granular rescache); each candidate's depth grid
    # shares one fold and warm-starts shallower depths from deeper fixed
    # points.  Transformed lanes run their shorter token streams on the
    # same chunk grid (clamped per lane).
    eval_stats = {"resolution_groups": 0, "cold_groups": 0,
                  **pruned_stats}
    for m in mem_list:
        entries = sim_by_mem[m.name]
        if not entries:
            continue
        cycles, es = evaluate_candidates(
            [st for _, st, _ in entries], m, n_iters,
            depth_lists=[tuple(bd) for bd, _, _ in entries],
            n_iters_list=[ntk for _, _, ntk in entries],
            seed=seed, use_rescache=use_rescache)
        for (bd, _, _), cyc in zip(entries, cycles):
            for d, cand in bd.items():
                cand.cycles = cyc[d]
        for k in eval_stats:
            eval_stats[k] += es.get(k, 0)
    stats1 = _rc.stats()

    # cycles-vs-FIFO-bits front per memory model over feasible
    # evaluated candidates (cross-model cycles are not comparable, so
    # each model gets its own frontier; the result concatenates them,
    # primary model first)
    front: list[DseCandidate] = []
    for mn in mem_names:
        best_cycles: int | None = None
        pool = [c for c in candidates if c.mem_name == mn
                and c.cycles is not None and c.pruned is None]
        for c in sorted(pool, key=lambda c: (c.fifo_bits, c.cycles)):
            if best_cycles is None or c.cycles < best_cycles:
                best_cycles = c.cycles
                c.pareto = True
                front.append(c)
    return DseResult(
        baseline=baseline, candidates=candidates, front=front,
        n_iters=n_iters, fifo_depth=primary_depth, mem_name=mem.name,
        fifo_depths=depths, wall_s=time.perf_counter() - t0,
        rescache_hits=stats1["mem_hits"] + stats1["disk_hits"]
        - stats0["mem_hits"] - stats0["disk_hits"],
        rescache_misses=stats1["misses"] - stats0["misses"],
        eval_stats=eval_stats, mem_names=mem_names,
        transforms=tuple(t.signature() for t in axis))


def compiled_with_plan(base: Any, plan: StagePlan,
                       duplicate: bool, transform: Any = None) -> Any:
    """Materialize a full ``Compiled`` artifact for one explored plan:
    the front-end products (jaxpr, CDFG) are shared with ``base``, the
    partition is rebuilt from ``plan`` (with ``transform`` — a
    :class:`~repro.dataflow.transforms.TransformConfig`, or ``None``
    to inherit the base artifact's own config), and the
    decouple/schedule passes re-run.  Bypasses the compile cache
    (candidate plans are not reachable from options alone)."""
    from .driver import Compiled
    from .passes import CompileContext, DecouplePass, SchedulePass
    from .transforms import IDENTITY
    eff = transform if transform is not None \
        else getattr(base.options, "transforms", None)
    opts = base.options.replace(duplicate_cheap=duplicate, dse=None,
                                transforms=eff)
    ctx = CompileContext(fn=base.fn,
                         example_args=base.context.example_args,
                         options=opts)
    ctx.closed_jaxpr = base.context.closed_jaxpr
    ctx.out_tree = base.context.out_tree
    # the CDFG is shared with ``base`` — never mutate its ``transforms``
    # annotation; pass the config straight into ``materialize`` instead
    ctx.cdfg = base.context.cdfg
    ctx.plan = plan
    part = materialize(
        ctx.cdfg, plan,
        transforms=eff if eff is not None and not eff.is_identity
        else IDENTITY)
    if duplicate:
        duplicate_cheap_rewrite(part)
    ctx.partition = part
    DecouplePass().run(ctx)
    SchedulePass().run(ctx)
    return Compiled(ctx, base.pipeline)


def explore(
    compiled: Any,
    *,
    traces: Any = None,
    constraints: ResourceConstraints | None = None,
    mem: MemoryModel | None = None,
    mems: Sequence[Any] | None = None,
    n_iters: int | None = None,
    fifo_depth: int | None = None,
    fifo_depths: Sequence[int] | None = None,
    seed: int | None = None,
    max_candidates: int | None = None,
    use_rescache: bool | None = None,
    server: str | None = None,
    transforms: Sequence[Any] | None = None,
    verify: bool | None = None,
) -> DseResult:
    """``Compiled.explore`` implementation: explore re-partitionings of
    ``compiled``'s kernel and return the cycles-vs-FIFO-bits Pareto
    front with a ``Compiled`` artifact attached to every front (and the
    best) candidate.  Pass ``fifo_depths=[...]`` for the joint
    partition×depth front (each candidate costed and simulated at every
    depth; the channel FIFO depth becomes a search axis instead of a
    fixed parameter), ``transforms=[TransformConfig(...), ...]`` to
    widen the search with the transformation catalog, and
    ``mems=["ACP", "ACP+64KB", ...]`` (names or
    :class:`~repro.core.memory.MemoryModel` instances) to span memory
    models in one exploration — the front then carries one sub-front
    per model, each candidate recording its model in ``mem_name``."""
    rc = constraints or compiled.options.dse or ResourceConstraints()
    n_iters = rc.n_iters if n_iters is None else n_iters
    seed = rc.seed if seed is None else seed
    node_traces = traces_by_node(
        compiled.cdfg, compiled.partition, traces,
        n_iters=n_iters, seed=seed)
    result = explore_plans(
        compiled.cdfg, compiled.context.plan,
        constraints=rc, mem=mem, mems=mems, node_traces=node_traces,
        duplicate_base=compiled.options.duplicate_cheap,
        n_iters=n_iters, fifo_depth=fifo_depth,
        fifo_depths=fifo_depths, seed=seed,
        max_candidates=max_candidates, use_rescache=use_rescache,
        server=server, transforms=transforms, verify=verify)
    artifacts: dict[tuple, Any] = {}
    for cand in {id(c): c for c in result.front + [result.best()]}.values():
        if cand.compiled is None:
            # the baseline IS the caller's artifact (same plan, same
            # duplication setting, same transform config) — no need to
            # re-decouple/schedule; otherwise one artifact per distinct
            # (plan, duplicate, transform), shared across the mem/depth
            # grid
            if cand is result.baseline:
                cand.compiled = compiled
                continue
            key = (cand.groups, cand.duplicate, cand.transform)
            art = artifacts.get(key)
            if art is None:
                art = compiled_with_plan(compiled, cand.plan,
                                         cand.duplicate, cand.tf)
                artifacts[key] = art
            cand.compiled = art
    return result

"""The HLS transformation catalog (de Fine Licht et al.) for the
dataflow template: four semantics-preserving rewrites, each a named,
legality-checked *pre-partition* pass in the :class:`PassPipeline` and a
DSE move alongside merge/split/duplicate (``docs/transforms.md``).

1. **Loop tiling** (``tile`` × ``tile_rows``) — re-chunk a declared 2-D
   iteration space (row-major ``tile_rows`` × C) so column tiles are
   visited innermost; the trace layer re-derives address windows through
   the tile permutation.  Legal only when no memory op sits on a
   dependence cycle (a loop-carried memory access pins the iteration
   order — the DFS pathology).
2. **Unroll / vectorize** (``unroll=U``) — U iterations per channel
   token: channels widen ×U (FIFO bit accounting scales with them), ops
   replicate U-way spatially, and a stage whose SCC imposes a cyclic II
   serializes its U recurrence steps (``ii → U·scc_ii``).  Memory ops
   split into U strided sub-streams resolved per token.
3. **Access coalescing** (``coalesce``, rides on ``unroll≥2``) — the U
   sub-accesses of an unrolled op merge into one burst-width op
   (``MemAccess.width = U``) when a stride/alignment legality check
   passes: constant positive stride, group span within one line, and
   group-aligned bases.  Ops that fail the check (or sit in a
   ``mem_in_scc`` stage) stay unrolled-but-uncoalesced.
4. **Memory-port re-association** (``reassoc``) — split a stage that
   touches several memory regions into per-region stages
   (:func:`split_by_region`), closing the documented DSE gap; always a
   legal contiguous split of the topological order
   (:func:`repro.core.partition.plan_is_legal` re-checks).

Rescache key contract: transformed op streams have different addresses
and generator closures, so :func:`repro.core.rescache.trace_fingerprint`
gives them **distinct v3 keys** — transformed traces are *new cache
entries, never invalidations* of untransformed artifacts.  The coalesced
``width`` is fold-only (bandwidth accounting), exactly like
``words_per_cycle``: it never keys.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import networkx as nx
import numpy as np

from ..core.simulator import DEFAULT_LINE_BYTES, MemAccess


class TransformError(ValueError):
    """A transform's legality check failed."""


@dataclasses.dataclass(frozen=True)
class TransformConfig:
    """Active transforms + factors (frozen/hashable: rides on
    :class:`~repro.dataflow.options.CompileOptions` and in the compile
    cache key).

    ``unroll``     — iterations per channel token (1 = off).
    ``coalesce``   — merge each op's unrolled sub-accesses into one
                     burst-width access where the stride/alignment check
                     passes (requires ``unroll >= 2``).
    ``tile``       — column-tile width of the tiled iteration order
                     (0 = off; requires ``tile_rows``).
    ``tile_rows``  — row count of the declared 2-D iteration space.
    ``reassoc``    — split multi-region stages by memory region.
    """

    unroll: int = 1
    coalesce: bool = False
    tile: int = 0
    tile_rows: int = 0
    reassoc: bool = False

    def __post_init__(self) -> None:
        if self.unroll < 1:
            raise TransformError(f"unroll factor must be >= 1, "
                                 f"got {self.unroll}")
        if self.coalesce and self.unroll < 2:
            raise TransformError(
                "coalesce merges an op's unrolled sub-accesses: it "
                "requires unroll >= 2")
        if (self.tile > 0) != (self.tile_rows > 0):
            raise TransformError(
                "tiling needs the iteration-space shape: set both "
                f"tile (got {self.tile}) and tile_rows "
                f"(got {self.tile_rows})")
        if self.tile < 0 or self.tile_rows < 0:
            raise TransformError("tile / tile_rows must be >= 0")

    # -- identity / naming ----------------------------------------------------

    @property
    def is_identity(self) -> bool:
        return (self.unroll == 1 and not self.coalesce and not self.tile
                and not self.reassoc)

    def active(self) -> tuple[str, ...]:
        """Move tags, one per active transform (DSE move names)."""
        tags = []
        if self.tile:
            tags.append(f"tile={self.tile}x{self.tile_rows}")
        if self.unroll > 1:
            tags.append(f"unroll={self.unroll}")
        if self.coalesce:
            tags.append("coalesce")
        if self.reassoc:
            tags.append("reassoc")
        return tuple(tags)

    def signature(self) -> str:
        """Compact label for reports / sweep rows (``"none"`` when
        identity)."""
        return "+".join(self.active()) or "none"

    # -- iteration-space accounting -------------------------------------------

    def tokens(self, n_iters: int) -> int:
        """Channel tokens for ``n_iters`` original iterations (tiling
        permutes, unrolling groups U iterations per token)."""
        return -(-n_iters // self.unroll) if self.unroll > 1 else n_iters

    # -- structural legality (needs the CDFG) ---------------------------------

    def validate(self, cdfg: Any = None) -> None:
        """Structural legality against a CDFG (the shape checks already
        ran in ``__post_init__``).  Tiling reorders the iteration space,
        so it is illegal when any memory op sits on a dependence cycle:
        a loop-carried access (the DFS pathology, or a dp-table
        back-edge that was *not* waived via ``nonaliasing_carries``)
        pins the original order."""
        if cdfg is None or not self.tile:
            return
        cyclic = _cyclic_memory_nodes(cdfg)
        if cyclic:
            prims = sorted(cdfg.node(n).prim for n in cyclic)
            raise TransformError(
                f"tiling reorders iterations, but memory ops {prims} sit "
                f"on a dependence cycle (loop-carried access): the "
                f"iteration order is pinned.  Drop the back-edge via "
                f"nonaliasing_carries if the regions do not alias.")


#: the do-nothing config (the untransformed point of the DSE axis)
IDENTITY = TransformConfig()


def _cyclic_memory_nodes(cdfg: Any) -> set[int]:
    g = nx.DiGraph()
    g.add_nodes_from(n.id for n in cdfg.nodes)
    g.add_edges_from((e.src, e.dst) for e in cdfg.edges)
    cyclic: set[int] = set()
    for comp in nx.strongly_connected_components(g):
        if len(comp) > 1 or any(g.has_edge(n, n) for n in comp):
            cyclic |= {n for n in comp if cdfg.node(n).is_memory}
    return cyclic


# ---------------------------------------------------------------------------
# Trace-layer rewrites
#
# Each rewrite produces MemAccess objects whose ``gen`` is a plain
# closure over (base trace, integer factors, base fingerprint string):
# rescache.trace_fingerprint hashes the closure's bytecode, scalar
# cells, and sampled windows, so transformed streams get distinct keys
# automatically.  Generators stay pure in (lo, hi) — required by the
# MemAccess contract (chunking, resume, cloudpickle'd workers).
# ---------------------------------------------------------------------------


def _base_tag(acc: MemAccess) -> str:
    """Content tag of the base trace, captured as a *string closure
    cell* of every derived generator so the fingerprint distinguishes
    transforms of different bases even when sampling coincides."""
    from ..core import rescache as _rc
    return _rc.trace_fingerprint(acc)


def unrolled_access(acc: MemAccess, factor: int, lane: int) -> MemAccess:
    """Sub-stream ``lane`` of ``acc`` unrolled by ``factor``: token
    ``i`` carries original iteration ``i*factor + lane``.  All lanes
    share one token count ``ceil(len(acc)/factor)``; positions past the
    original trace pad to −1 (no access)."""
    if not 0 <= lane < factor:
        raise ValueError(f"lane {lane} outside unroll factor {factor}")
    n_tok = -(-len(acc) // factor)
    tag = _base_tag(acc)

    def gen(lo: int, hi: int) -> np.ndarray:
        _ = (factor, lane, tag)  # closure cells: keyed by the fingerprint
        if hi <= lo:
            return np.empty(0, dtype=np.int64)
        w = acc._raw_window(lo * factor + lane,
                            (hi - 1) * factor + lane + 1)
        return np.ascontiguousarray(w[::factor])

    return MemAccess(acc.region, gen=gen, length=n_tok,
                     is_store=acc.is_store)


def coalescible(acc: MemAccess, factor: int,
                line_bytes: int = DEFAULT_LINE_BYTES) -> bool:
    """Stride/alignment legality of merging each ``factor``-group of
    ``acc`` into one burst access: within every group the addresses
    advance by one constant positive stride ``s``, the group spans at
    most one line (``s*factor <= line_bytes``), and group bases are
    ``s*factor``-aligned (no line straddle).  Materialized traces up to
    2²⁰ addresses are checked in full; longer or generated traces check
    a deterministic spread of group-aligned windows (the same sampling
    posture as ``rescache.trace_fingerprint``)."""
    n = len(acc)
    if factor < 2 or n < factor:
        return False
    full = acc.addrs is not None and n <= (1 << 20)
    if full:
        windows = [(0, n)]
    else:
        span = 1024 * factor
        step = max(factor, ((n - span) // (7 * factor)) * factor)
        windows = []
        for i in range(8):
            lo = min(i * step, max(0, ((n - span) // factor) * factor))
            windows.append((lo, min(n, lo + span)))
    stride: int | None = None
    for lo, hi in windows:
        g = (hi - lo) // factor
        if g == 0:
            continue
        a = acc._raw_window(lo, lo + g * factor).reshape(g, factor)
        rows = (a >= 0).all(axis=1)  # partial tail groups are exempt
        if not rows.any():
            continue
        a = a[rows]
        d = np.diff(a, axis=1)
        if stride is None:
            stride = int(d[0, 0])
        if stride <= 0 or not (d == stride).all():
            return False
        if stride * factor > line_bytes:
            return False
        if (a[:, 0] % (stride * factor)).any():
            return False
    return stride is not None


def coalesced_access(acc: MemAccess, factor: int) -> MemAccess:
    """The merged burst-width op: one access per token at the group base
    address, ``width=factor`` words.  Caller is responsible for the
    :func:`coalescible` legality check."""
    base = unrolled_access(acc, factor, 0)
    return MemAccess(acc.region, gen=base.gen, length=len(base),
                     is_store=acc.is_store, width=factor)


def tiled_access(acc: MemAccess, tile_rows: int, tile: int) -> MemAccess:
    """``acc`` re-windowed through the tile permutation of its
    ``tile_rows`` × C row-major iteration space: column tiles of width
    ``tile`` are interchanged outermost, so token ``j`` reads original
    iteration ``π(j)`` with tile-column-row-column′ order (the working
    set of a tile is ``tile_rows × tile`` instead of a full row).  The
    trace length must factor (``len % tile_rows == 0``) — trace-level
    legality."""
    n = len(acc)
    R, T = int(tile_rows), int(tile)
    if R < 1 or T < 1:
        raise TransformError(f"tile shape {T}x{R} must be positive")
    if n % R != 0:
        raise TransformError(
            f"trace length {n} does not factor into tile_rows={R} rows")
    C = n // R
    widths = np.minimum(T, C - T * np.arange(-(-C // T)))
    cum = np.cumsum(widths * R)  # block end offsets, one per column tile
    starts = np.concatenate(([0], cum[:-1]))
    tag = _base_tag(acc)

    def gen(lo: int, hi: int) -> np.ndarray:
        _ = (R, C, T, tag)
        if hi <= lo:
            return np.empty(0, dtype=np.int64)
        j = np.arange(lo, hi, dtype=np.int64)
        t = np.searchsorted(cum, j, side="right")
        within = j - starts[t]
        w = widths[t]
        idx = (within // w) * C + t * T + within % w
        # fetch contiguous runs of the permuted index through the base
        # trace's own windowing (works for materialized and gen traces)
        out = np.empty(hi - lo, dtype=np.int64)
        cuts = np.flatnonzero(np.diff(idx) != 1) + 1
        bounds = np.concatenate(([0], cuts, [len(idx)]))
        for a, b in zip(bounds[:-1], bounds[1:]):
            out[a:b] = acc._raw_window(int(idx[a]), int(idx[a]) + (b - a))
        return out

    return MemAccess(acc.region, gen=gen, length=n, is_store=acc.is_store)


def transform_access(
    cfg: TransformConfig,
    acc: MemAccess,
    *,
    line_bytes: int = DEFAULT_LINE_BYTES,
    allow_coalesce: bool = True,
) -> list[MemAccess]:
    """Apply ``cfg``'s trace-layer rewrites to one memory op's stream:
    tile first (iteration-space permutation), then unroll into U
    sub-streams, then coalesce them into one burst-width op when legal.
    ``allow_coalesce=False`` for ops in ``mem_in_scc`` stages: a
    serialized access pays per-request latency, so merging would drop
    U−1 of its draws.  Results are memoized on the base access per
    config, so sibling candidates (DSE) share transformed objects — and
    with them the window/burst/fingerprint memos and resolution keys."""
    key = ("_tf_memo", cfg.tile, cfg.tile_rows, cfg.unroll,
           cfg.coalesce and allow_coalesce, line_bytes)
    memo = acc.__dict__.setdefault("_tf_memo", {})
    hit = memo.get(key)
    if hit is not None:
        return hit
    out = acc
    if cfg.tile:
        out = tiled_access(out, cfg.tile_rows, cfg.tile)
    if cfg.unroll > 1:
        if cfg.coalesce and allow_coalesce \
                and coalescible(out, cfg.unroll, line_bytes):
            res = [coalesced_access(out, cfg.unroll)]
        else:
            res = [unrolled_access(out, cfg.unroll, u)
                   for u in range(cfg.unroll)]
    else:
        res = [out]
    memo[key] = res
    return res


def transform_node_traces(
    node_traces: Mapping[int, list[MemAccess]],
    cfg: TransformConfig,
    *,
    serialized_nodes: set[int] | frozenset[int] = frozenset(),
    line_bytes: int = DEFAULT_LINE_BYTES,
) -> dict[int, list[MemAccess]]:
    """Transform a DSE node→traces map (``dse.traces_by_node`` layout).
    ``serialized_nodes`` are memory nodes on a dependence cycle — their
    streams never coalesce (see :func:`transform_access`)."""
    if cfg.is_identity:
        return dict(node_traces)
    return {
        nid: [t for a in accs
              for t in transform_access(
                  cfg, a, line_bytes=line_bytes,
                  allow_coalesce=nid not in serialized_nodes)]
        for nid, accs in node_traces.items()
    }


# ---------------------------------------------------------------------------
# Memory-port re-association (the partition-layer rewrite)
# ---------------------------------------------------------------------------


def split_by_region(cdfg: Any, plan: Any) -> Any:
    """Split every multi-region stage of ``plan`` by memory region: a
    new group starts whenever an SCC touches memory regions disjoint
    from those already in the current run (non-memory SCCs ride with the
    current run; an SCC whose *own* memory nodes span several regions is
    unsplittable and keeps them together).  Groups stay contiguous runs
    of the fixed topological order, so the result is legal by
    construction — re-checked via ``plan_is_legal``."""
    from ..core.partition import plan_is_legal
    # walk each group in the plan's topological order — group lists are
    # not guaranteed to be topo-sorted internally (the fused plan lists
    # SCC ids numerically), and the split groups' relative order must
    # follow the condensation order to stay legal
    pos = {k: i for i, k in enumerate(plan.order)}
    groups: list[list[int]] = []
    for grp in plan.groups:
        cur: list[int] = []
        cur_regions: set[str] = set()
        for k in sorted(grp, key=pos.__getitem__):
            regs = {cdfg.node(n).region for n in plan.sccs[k]
                    if cdfg.node(n).is_memory and cdfg.node(n).region}
            if regs and cur_regions and not (regs & cur_regions):
                groups.append(cur)
                cur, cur_regions = [], set()
            cur.append(k)
            cur_regions |= regs
        if cur:
            groups.append(cur)
    out = dataclasses.replace(plan, groups=groups)
    assert plan_is_legal(cdfg, out), "reassoc produced an illegal plan"
    return out


def scaled_stage_timing(scc_ii: int, base_latency: int,
                        cfg: TransformConfig | None) -> tuple[int, int]:
    """(ii, latency) of a stage under ``cfg``'s unroll — the partition
    layer owns the definition (see
    ``repro.core.partition._scaled_stage_timing``); re-exported here as
    the catalog's public name."""
    from ..core.partition import _scaled_stage_timing
    return _scaled_stage_timing(scc_ii, base_latency, cfg)

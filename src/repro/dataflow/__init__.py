"""repro.dataflow — the compiler driver for the dataflow template.

One entry point for the paper's whole flow::

    from repro.dataflow import dataflow_jit

    @dataflow_jit(stream_argnums=(1,))
    def kernel(table, idx, w):
        return jnp.tanh(table[idx] * w) + 1.0

    kernel(table, idx, w)                       # default backend
    kernel(table, idx, w, backend="systolic")   # one stage per device
    c = kernel.lower(table, idx, w)             # Compiled artifact
    print(c.report()); print(c.simulate().summary())

Internals (all public, all swappable):

* :mod:`~repro.dataflow.options`  — :class:`CompileOptions` (hashable).
* :mod:`~repro.dataflow.passes`   — the ordered pass pipeline
  (trace → memdep → transform → partition → rewrite → dse →
  decouple → schedule); each pass
  delegates to the paper-faithful implementation in ``repro.core``.
* :mod:`~repro.dataflow.backends` — the execution-backend registry
  (``sequential`` / ``emulated`` / ``systolic`` / ``xla`` / ``simulate``).
* :mod:`~repro.dataflow.schedule` — static schedule analysis and the
  Fig. 2/5 simulation report.
* :mod:`~repro.dataflow.transforms` — the HLS transformation catalog
  (tiling, unroll/vectorize, access coalescing, memory-port
  re-association), applied pre-partition and explored by the DSE.
* :mod:`~repro.dataflow.verify` — the static dataflow verifier:
  inter-pass IR invariants, the FIFO deadlock analysis, and the
  decoupled-access race detector (``docs/verify.md``).
"""

from .backends import (Backend, BackendUnavailableError, available_backends,
                       execute_backends, get_backend, register_backend,
                       registered_backends, unregister_backend)
from .driver import (Compiled, cache_stats, clear_cache, compile,
                     dataflow_jit)
from .dse import (DseCandidate, DseResult, enumerate_plans, explore,
                  explore_plans, partition_resources)
from .options import CompileOptions, ResourceConstraints, ServeOptions
from .passes import (CompileContext, DecouplePass, DsePass, MemoryDepPass,
                     Pass, PartitionPass, PassPipeline, RewritePass,
                     SchedulePass, TracePass, TransformPass,
                     default_pipeline)
from .schedule import (Schedule, SimReport, StageSummary, SweepResult,
                       fused_stage, simulate_schedule, sweep_schedule)
from .transforms import TransformConfig, TransformError
from .verify import (RULES, Diagnostic, VerifyError, chain_deadlock_bound,
                     deadlock_min_depth, fifo_depth_diagnostics,
                     verify_compiled, verify_partition, verify_plan,
                     verify_program)

__all__ = [
    "Backend", "BackendUnavailableError", "available_backends",
    "execute_backends", "get_backend", "register_backend",
    "registered_backends", "unregister_backend",
    "Compiled", "cache_stats", "clear_cache", "compile", "dataflow_jit",
    "CompileOptions", "ResourceConstraints", "ServeOptions",
    "DseCandidate", "DseResult", "enumerate_plans", "explore",
    "explore_plans", "partition_resources",
    "CompileContext", "Pass", "PassPipeline", "TracePass", "MemoryDepPass",
    "PartitionPass", "RewritePass", "DsePass", "DecouplePass",
    "SchedulePass", "TransformPass", "default_pipeline",
    "Schedule", "SimReport", "StageSummary", "SweepResult", "fused_stage",
    "simulate_schedule", "sweep_schedule",
    "TransformConfig", "TransformError",
    "RULES", "Diagnostic", "VerifyError", "chain_deadlock_bound",
    "deadlock_min_depth", "fifo_depth_diagnostics", "verify_compiled",
    "verify_partition", "verify_plan", "verify_program",
]

"""Static schedule analysis + simulation bridge for compiled artifacts.

:class:`Schedule` is the product of the driver's final pass: per-stage
summaries (initiation interval, latency, memory-in-SCC classification),
channel totals, and a lazily-built :class:`~repro.core.pipeline.SystolicPipeline`
for the streaming executors.  :class:`SimReport` packages the Fig. 2
occupancy view and the Fig. 5 machine comparison produced by
``Compiled.simulate()``; :class:`SweepResult` / :func:`sweep_schedule`
grid the same machines over memory models × FIFO depths × SCC modes
(``Compiled.sweep()``, the Fig. 5 design-space sweep).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, Sequence

import networkx as nx
import numpy as np

from ..core.decouple import DecoupledProgram
from ..core.pipeline import SystolicPipeline, gpipe_bubble_fraction
from ..core.simulator import (MemAccess, MemoryModel, SimResult, SimStage,
                              acp, simulate_conventional,
                              simulate_conventional_many, simulate_dataflow,
                              simulate_dataflow_many, standard_memory_models)


@dataclasses.dataclass(frozen=True)
class StageSummary:
    """One pipeline stage as the scheduler sees it."""

    id: int
    prims: tuple[str, ...]
    ii: int
    latency: int
    has_memory: bool
    has_long: bool
    regions: tuple[str, ...]
    mem_in_scc: bool
    memory_node_ids: tuple[int, ...]
    in_channel_bytes: int
    out_channel_bytes: int
    #: unscaled dependence-cycle latency (``ii``/``latency`` already
    #: reflect the active transform config; this recovers the base)
    scc_ii: int = 0


def _cyclic_nodes(cdfg: Any) -> set[int]:
    """Nodes on a dependence cycle (the DFS pathology detector)."""
    g = nx.DiGraph()
    g.add_nodes_from(n.id for n in cdfg.nodes)
    g.add_edges_from((e.src, e.dst) for e in cdfg.edges)
    cyclic: set[int] = set()
    for comp in nx.strongly_connected_components(g):
        if len(comp) > 1 or any(g.has_edge(n, n) for n in comp):
            cyclic |= comp
    return cyclic


@dataclasses.dataclass
class Schedule:
    """Static pipeline schedule for a decoupled program."""

    program: DecoupledProgram
    stream_argnums: tuple[int, ...]
    stages: list[StageSummary]
    num_channels: int
    channel_bytes: int
    #: active TransformConfig carried from the partition (None =
    #: untransformed); stage timing and channel_bytes already reflect it
    transforms: Any = None
    _pipeline: SystolicPipeline | None = None

    @classmethod
    def from_program(cls, program: DecoupledProgram,
                     *, stream_argnums: Sequence[int] = (0,)) -> "Schedule":
        part = program.partition
        cdfg = part.cdfg
        cyclic = _cyclic_nodes(cdfg)
        in_bytes = {s.id: 0 for s in part.stages}
        out_bytes = {s.id: 0 for s in part.stages}
        for c in part.channels:
            out_bytes[c.src_stage] += c.nbytes
            in_bytes[c.dst_stage] += c.nbytes
        summaries = []
        for s in part.stages:
            mem_ids = tuple(n for n in s.node_ids if cdfg.node(n).is_memory)
            summaries.append(StageSummary(
                id=s.id,
                prims=tuple(cdfg.node(n).prim for n in s.node_ids),
                ii=s.ii,
                latency=s.latency,
                has_memory=s.has_memory,
                has_long=s.has_long,
                regions=s.regions,
                mem_in_scc=any(n in cyclic for n in mem_ids),
                memory_node_ids=mem_ids,
                in_channel_bytes=in_bytes[s.id],
                out_channel_bytes=out_bytes[s.id],
                scc_ii=getattr(s, "scc_ii", 0),
            ))
        return cls(program, tuple(stream_argnums), summaries,
                   num_channels=len(part.channels),
                   channel_bytes=sum(c.nbytes for c in part.channels),
                   transforms=getattr(part, "transforms", None))

    # -- derived quantities ---------------------------------------------------

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def pipeline_ii(self) -> int:
        """Steady-state initiation interval: the slowest stage's II."""
        return max([1] + [s.ii for s in self.stages])

    @property
    def total_latency(self) -> int:
        return sum(s.latency for s in self.stages)

    def bubble_fraction(self, microbatches: int) -> float:
        return gpipe_bubble_fraction(self.num_stages, microbatches)

    @property
    def pipeline(self) -> SystolicPipeline:
        """The systolic executor (built on first use: boundary packing
        allocates example payloads, so it is not free for large programs)."""
        if self._pipeline is None:
            self._pipeline = SystolicPipeline(
                self.program, stream_argnums=self.stream_argnums)
        return self._pipeline

    # -- Fig. 2 occupancy -----------------------------------------------------

    def occupancy(self, microbatches: int) -> list[list[int]]:
        """Fig. 2 grid: ``occ[t][s]`` is the microbatch in stage ``s`` at
        tick ``t`` (-1 = idle).  Microbatch m occupies stage s at tick
        ``t = m + s``."""
        S, T = self.num_stages, microbatches
        return [[t - s if 0 <= t - s < T else -1 for s in range(S)]
                for t in range(T + S - 1)]

    def render_occupancy(self, microbatches: int = 6) -> str:
        occ = self.occupancy(microbatches)
        lines = ["tick " + " ".join(f"s{s}" for s in
                                    range(self.num_stages))]
        for t, row in enumerate(occ):
            cells = " ".join(f"{m:>2}" if m >= 0 else " ." for m in row)
            lines.append(f"{t:>4} {cells}")
        return "\n".join(lines)

    # -- simulator bridge -----------------------------------------------------

    def sim_stages(
        self,
        traces: Mapping[str, Any] | Sequence[MemAccess] | None = None,
        *,
        n_iters: int = 2048,
        seed: int = 0,
        address_space: int = 4 << 20,
        apply_transforms: bool = True,
    ) -> list[SimStage]:
        """Build cycle-simulator stages from the partition.

        ``traces`` assigns memory address streams (**byte** addresses; the
        kernels touch 32-bit words, hence the ``* 4``) to the memory
        operations:

        * a mapping ``region name -> MemAccess | [MemAccess]`` (one entry
          per memory region, as :func:`repro.core.simulator.stages_from_partition`);
        * a sequence of :class:`MemAccess`, assigned positionally to memory
          ops in pipeline-stage order (the Fig. 5 benchmark convention);
        * ``None`` — synthetic uniform-random word addresses, the
          cache-hostile default.

        Traces are always supplied per *original iteration*; when a
        transform config is active (and ``apply_transforms``), each op's
        stream is rewritten through the catalog (tile permutation, U
        strided unroll sub-streams, coalesced burst ops — coalescing is
        skipped for ``mem_in_scc`` stages, whose serialized accesses pay
        per-request latency) and the stages expect
        ``transforms.tokens(n_iters)`` simulated tokens.
        ``apply_transforms=False`` returns the *untransformed* machine —
        raw streams and unscaled II/latency — which is what the
        conventional-HLS comparison runs."""
        cfg = self.transforms
        if cfg is not None and cfg.is_identity:
            cfg = None
        rng = np.random.default_rng(seed)
        out: list[SimStage] = []
        if traces is None or isinstance(traces, Mapping):
            by_region = dict(traces or {})
        else:
            by_region = None
            trace_list = list(traces)
            ti = 0
        for s in self.stages:
            accesses: list[MemAccess] = []
            if by_region is not None:
                for region in s.regions:
                    tr = by_region.get(region)
                    if tr is None and traces is None:
                        tr = MemAccess(region, rng.integers(
                            0, address_space, n_iters) * 4)
                        by_region[region] = tr
                    if tr is None:
                        continue
                    accesses.extend(tr if isinstance(tr, list) else [tr])
            else:
                for _ in s.memory_node_ids:
                    if ti < len(trace_list):
                        accesses.append(trace_list[ti])
                        ti += 1
            ii, latency = s.ii, s.latency
            if cfg is not None:
                if apply_transforms:
                    from .transforms import transform_access
                    accesses = [t for a in accesses
                                for t in transform_access(
                                    cfg, a,
                                    allow_coalesce=not s.mem_in_scc)]
                elif cfg.unroll > 1 and s.scc_ii > 0:
                    # undo the unroll scaling baked in by materialize
                    ii = max(1, s.scc_ii)
                    latency = s.latency - (cfg.unroll - 1) * s.scc_ii
            out.append(SimStage(
                name=f"s{s.id}",
                ii=ii,
                latency=max(1, latency),
                accesses=accesses,
                mem_in_scc=s.mem_in_scc,
            ))
        return out


def fused_stage(stages: Sequence[SimStage]) -> SimStage:
    """The conventional-HLS counterpart: every op in one static schedule."""
    if not stages:
        return SimStage(name="fused", ii=1, latency=1)
    return SimStage(
        name="fused",
        ii=max(st.ii for st in stages),
        latency=sum(st.latency for st in stages),
        accesses=[a for st in stages for a in st.accesses],
        mem_in_scc=any(st.mem_in_scc for st in stages),
    )


@dataclasses.dataclass
class SimReport:
    """The Fig. 2/5 schedule report returned by ``Compiled.simulate()``."""

    schedule: Schedule
    stages: list[SimStage]
    dataflow: SimResult
    conventional: SimResult
    mem: MemoryModel
    n_iters: int
    microbatches: int

    @property
    def speedup(self) -> float:
        return self.conventional.cycles / max(1, self.dataflow.cycles)

    def summary(self) -> str:
        df, cv = self.dataflow, self.conventional

        def fmt_stalls(buckets: dict[str, int]) -> str:
            parts = [f"{k}={v}" for k, v in buckets.items() if v]
            return "+".join(parts) if parts else "none"

        lines = [
            f"simulated {self.n_iters} iterations on memory model "
            f"{self.mem.name!r}:",
            f"  conventional (fused) : {cv.cycles_per_iter:8.2f} cycles/iter"
            f"  ({cv.cycles} cycles)",
            f"  dataflow  (decoupled): {df.cycles_per_iter:8.2f} cycles/iter"
            f"  ({df.cycles} cycles)",
            f"  speedup              : {self.speedup:8.2f}x",
            "  per-stage stalls     : "
            + ", ".join(f"{k}[{fmt_stalls(v)}]"
                        for k, v in df.stage_stall_cycles.items()),
            "",
            f"Fig. 2 occupancy ({self.microbatches} microbatches, "
            f"{self.schedule.num_stages} stages, bubble fraction "
            f"{self.schedule.bubble_fraction(self.microbatches):.2f}):",
            self.schedule.render_occupancy(self.microbatches),
        ]
        return "\n".join(lines)


def simulate_schedule(
    schedule: Schedule,
    *,
    n_iters: int = 2048,
    mem: MemoryModel | None = None,
    traces: Any = None,
    fifo_depth: int = 8,
    microbatches: int = 6,
    seed: int = 0,
    use_rescache: bool | None = None,
    server: str | None = None,
    engine: str | None = None,
) -> SimReport:
    mem = mem or acp()
    cfg = getattr(schedule, "transforms", None)
    transformed = cfg is not None and not cfg.is_identity
    stages = schedule.sim_stages(traces, n_iters=n_iters, seed=seed)
    # the dataflow machine runs the transformed pipeline over its token
    # stream; the conventional baseline runs the *untransformed* fused
    # machine over the original iterations (same total work)
    n_df = cfg.tokens(n_iters) if transformed else n_iters
    base_stages = stages if not transformed else schedule.sim_stages(
        traces, n_iters=n_iters, seed=seed, apply_transforms=False)
    if server:
        # resolve through the daemon first (shared pool, in-flight
        # dedup); the local run below then serves from the store —
        # best-effort, so a missing daemon costs nothing
        from ..serve.client import ServeUnavailable, prefetch
        try:
            prefetch(stages, {"mem": mem}, n_df, seed=seed,
                     address=None if server == "auto" else server)
        except ServeUnavailable:
            pass
    df = simulate_dataflow(stages, mem, n_df, fifo_depth=fifo_depth,
                           seed=seed, use_rescache=use_rescache,
                           engine=engine)
    cv = simulate_conventional([fused_stage(base_stages)], mem, n_iters,
                               seed=seed, use_rescache=use_rescache,
                               engine=engine)
    return SimReport(schedule, stages, df, cv, mem, n_iters, microbatches)


# ---------------------------------------------------------------------------
# The Fig. 5 design-space sweep
# ---------------------------------------------------------------------------

#: ``mem_in_scc`` axis values: keep the partitioner's analysis, force the
#: DFS pathology everywhere (what the template degrades to when a memory
#: access cannot be decoupled), or force it off (perfect decoupling).
SCC_MODES = ("auto", "forced", "off")


def _with_scc_mode(stages: Sequence[SimStage], mode: str) -> list[SimStage]:
    if mode == "auto":
        return list(stages)
    if mode not in SCC_MODES:
        raise ValueError(f"mem_in_scc mode must be one of {SCC_MODES}, "
                         f"got {mode!r}")
    force = mode == "forced"
    return [dataclasses.replace(st, mem_in_scc=force if st.accesses
                                else st.mem_in_scc)
            for st in stages]


@dataclasses.dataclass
class SweepResult:
    """Grid of fully-simulated machine comparisons.

    ``rows`` is JSON-ready: one dict per (memory model × fifo depth ×
    SCC mode × bandwidth × outstanding-cap) point with
    dataflow/conventional cycles, cycles/iteration, runtimes, speedup,
    stall buckets, cache statistics, and the FIFO storage cost
    (``fifo_bits`` = depth × channel bits).  ``pareto()`` returns the
    cycles-vs-FIFO-bits frontier (HIDA-style: how much buffering the
    latency tolerance actually needs).
    """

    rows: list[dict]
    n_iters: int

    def best(self, metric: str = "dataflow_cycles") -> dict:
        """The grid point minimizing ``metric``."""
        return min(self.rows, key=lambda r: r[metric])

    def pareto(self, x: str = "fifo_bits",
               y: str = "dataflow_cycles") -> list[dict]:
        """Non-dominated rows minimizing ``(x, y)`` — by default the
        cycles-vs-FIFO-storage frontier.  Rows on the front are also
        marked in place (``row["pareto"] = True``)."""
        for r in self.rows:
            r["pareto"] = False
        front: list[dict] = []
        best_y = None
        for r in sorted(self.rows, key=lambda r: (r[x], r[y])):
            if best_y is None or r[y] < best_y:
                best_y = r[y]
                r["pareto"] = True
                front.append(r)
        return front

    def to_json(self) -> dict:
        return {"n_iters": self.n_iters, "rows": self.rows}

    def summary(self) -> str:
        lines = [f"sweep over {len(self.rows)} configurations "
                 f"({self.n_iters} iterations each):",
                 f"  {'mem':<10}{'fifo':>5}{'scc':>8}{'wpc':>5}{'mo':>4}"
                 f"{'df cyc/it':>11}{'conv cyc/it':>13}{'speedup':>9}"]
        for r in self.rows:
            lines.append(
                f"  {r['mem']:<10}{r['fifo_depth']:>5}"
                f"{r['mem_in_scc']:>8}"
                f"{r['words_per_cycle']:>5.2g}{r['max_outstanding']:>4}"
                f"{r['dataflow_cpi']:>11.2f}{r['conventional_cpi']:>13.2f}"
                f"{r['speedup']:>9.2f}")
        b = self.best()
        front = self.pareto()
        lines.append(f"  best dataflow config: {b['mem']} "
                     f"fifo={b['fifo_depth']} scc={b['mem_in_scc']} "
                     f"({b['dataflow_cpi']:.2f} cyc/iter, "
                     f"{b['speedup']:.2f}x over conventional)")
        lines.append(
            "  cycles-vs-FIFO-bits Pareto front: "
            + " → ".join(f"{r['fifo_depth']}@{r['fifo_bits']}b"
                         f"={r['dataflow_cycles']}" for r in front))
        return "\n".join(lines)


def sweep_schedule(
    schedule: Schedule,
    *,
    n_iters: int = 1 << 16,
    mems: Mapping[str, Callable[[], MemoryModel]] | None = None,
    fifo_depths: Iterable[int] = (8, 32),
    scc_modes: Iterable[str] = ("auto",),
    traces: Any = None,
    seed: int = 0,
    freq_mhz: float = 150.0,
    max_outstanding: int | None = None,
    words_per_cycle: Iterable[float] | None = None,
    max_outstandings: Iterable[int] | None = None,
    collect_stalls: bool = True,
    use_rescache: bool | None = None,
    workers: int | None = None,
    depth_incremental: bool = True,
    server: str | None = None,
    engine: str | None = None,
) -> SweepResult:
    """Grid-run the cycle simulator over memory models (§V: ACP / HP,
    ±64 KB cache) × FIFO depths × ``mem_in_scc`` modes × port bandwidths
    (``words_per_cycle``) × in-flight caps (``max_outstandings``).

    Every point simulates all ``n_iters`` iterations (no steady-state
    extrapolation), but the planner orders the grid so cells share work
    instead of re-resolving the same traces: per SCC mode, *all* memory
    variants and FIFO depths run through one
    :func:`~repro.core.simulator.simulate_dataflow_many` pass — windows
    and burst masks are computed once, each distinct cache geometry
    replays once, bandwidth/outstanding variants reuse the same draws,
    and each FIFO depth only re-runs the wavefront solve.  The
    conventional engine has no FIFOs and ignores both SCC classification
    and the decoupled-port knobs, so one simulation per memory model
    covers its share of the grid.  Resolved traces are further memoized
    across calls, iteration counts (prefix serving), and processes via
    :mod:`repro.core.rescache` (``use_rescache=False`` opts out).

    ``workers > 1`` shards the dataflow resolution across a process
    pool (the chunk-graph executor — bit-identical, multi-core);
    ``depth_incremental`` (default) warm-starts each FIFO-depth lane
    from the adjacent deeper lane's fixed point; ``server`` delegates
    resolution to a running resolution daemon (:mod:`repro.serve` —
    ``"auto"`` or an explicit address), falling back to the local
    engines when none answers.  Each row records the engine that
    actually ran in ``resolution_mode`` (``"served:ADDR"`` /
    ``"sharded:N"`` / ``"streaming"``) and, in ``resilience``, the
    fault/retry counters its grid pass incurred (worker retries,
    quarantined store records, serve failovers) — a sweep that silently
    recovered from faults says so in its own output.
    """
    mems = dict(mems) if mems is not None else standard_memory_models()
    fifo_depths = tuple(fifo_depths)
    scc_modes = tuple(scc_modes)
    wpcs = tuple(words_per_cycle) if words_per_cycle is not None else (None,)
    mos = tuple(max_outstandings) if max_outstandings is not None \
        else (max_outstanding,)
    cfg = getattr(schedule, "transforms", None)
    transformed = cfg is not None and not cfg.is_identity
    tf_sig = cfg.signature() if transformed else "none"
    base_stages = schedule.sim_stages(traces, n_iters=n_iters, seed=seed)
    # transformed pipelines stream tokens (U iterations each); the
    # conventional baseline always runs the untransformed fused machine
    # over the original iterations — same total work on both sides
    n_df = cfg.tokens(n_iters) if transformed else n_iters
    conv_stages = base_stages if not transformed else schedule.sim_stages(
        traces, n_iters=n_iters, seed=seed, apply_transforms=False)
    channel_bits = schedule.channel_bytes * 8

    def variant(mk: Callable[[], MemoryModel], wpc, mo) -> MemoryModel:
        m = mk()
        if wpc is not None:
            m.words_per_cycle = wpc
        if mo is not None:
            m.max_outstanding = mo
        return m

    # conventional: one run per memory model (no FIFOs, no decoupled-port
    # knobs, SCC-independent), shared across the rest of the grid
    conv_mems = {mn: variant(mk, None, mos[0]) for mn, mk in mems.items()}
    conv = simulate_conventional_many(
        [fused_stage(conv_stages)], conv_mems, n_iters,
        freq_mhz=freq_mhz, seed=seed, use_rescache=use_rescache,
        engine=engine)

    # the engine the dataflow grid actually runs on, recorded per row
    # (satellite of the serving tier: on <4-core machines the workers
    # heuristic falls back to streaming — make the choice auditable)
    resolution_mode = "streaming" if not workers or workers < 2 \
        else f"sharded:{workers}"
    if server:
        from ..serve import client as _serve_client
        addr = None if server == "auto" else server
        if _serve_client.ping(addr):
            from ..serve import protocol as _serve_protocol
            resolution_mode = "served:" + (
                addr or _serve_protocol.default_address())

    # resilience observability (chaos-harness satellite): each row
    # carries the store/serve fault counters its grid pass incurred, so
    # a sweep that silently survived worker deaths, quarantined records
    # or daemon failovers says so in the output instead of only in logs
    from ..core import rescache as _resc
    _RESIL = ("worker_retries", "quarantined", "serve_failovers")

    def _resil_snap() -> dict[str, int]:
        s = _resc.stats()
        return {k: int(s.get(k, 0)) for k in _RESIL}

    rows: list[dict] = []
    for mode in scc_modes:
        resil0 = _resil_snap()
        stages = _with_scc_mode(base_stages, mode)
        variants: dict[str, tuple[str, float | None, int | None]] = {}
        vmems: dict[str, MemoryModel] = {}
        for mn, mk in mems.items():
            for wpc in wpcs:
                for mo in mos:
                    vn = mn if (wpc is None and mo is None) \
                        else f"{mn}|wpc={wpc}|mo={mo}"
                    variants[vn] = (mn, wpc, mo)
                    vmems[vn] = variant(mk, wpc, mo)
        grid = simulate_dataflow_many(
            stages, vmems, n_df, fifo_depths=fifo_depths,
            freq_mhz=freq_mhz, seed=seed, collect_stalls=collect_stalls,
            use_rescache=use_rescache, workers=workers,
            depth_incremental=depth_incremental, server=server,
            engine=engine)
        resil1 = _resil_snap()
        resilience = {k: resil1[k] - resil0[k] for k in _RESIL}
        for vn, (mn, wpc, mo) in variants.items():
            cv = conv[mn]
            m = vmems[vn]
            for depth in fifo_depths:
                df = grid[(vn, depth)]
                rows.append({
                    "mem": mn,
                    "fifo_depth": depth,
                    "fifo_bits": depth * channel_bits,
                    "transform": tf_sig,
                    "n_tokens": n_df,
                    "mem_in_scc": mode,
                    "words_per_cycle": m.words_per_cycle,
                    "max_outstanding": m.max_outstanding,
                    "dataflow_cycles": df.cycles,
                    "conventional_cycles": cv.cycles,
                    "dataflow_cpi": df.cycles_per_iter,
                    "conventional_cpi": cv.cycles_per_iter,
                    "dataflow_s": df.runtime_s,
                    "conventional_s": cv.runtime_s,
                    "speedup": cv.cycles / max(1, df.cycles),
                    "dataflow_stalls": df.total_stalls(),
                    "cache_hits": df.cache_hits,
                    "cache_misses": df.cache_misses,
                    "resolution_mode": resolution_mode,
                    "resilience": resilience,
                })
    res = SweepResult(rows, n_iters)
    res.pareto()  # mark the default frontier on the rows
    return res

"""Attention: GQA/MHA/MQA with RoPE + KV cache, and MLA (DeepSeek-V3).

Three attention implementations, selected by ``impl``:

* ``"full"``    — materialized S×S logits (oracle; small configs only).
* ``"chunked"`` — online-softmax streamed over KV blocks in pure JAX
  (``lax.scan``): the template's decoupled KV streaming expressed at the
  XLA level; memory stays O(S·d) per step.  Default for long sequences and
  the dry-run path.
* ``"pallas"``  — the kernels/flash_attention.py Pallas kernels (TPU).

The KV-cache decode step is the framework's canonical "memory operation"
per the paper's classification: a data-dependent HBM stream (the cache)
feeding a small amount of compute, decoupled from the projection GEMMs.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from ..kernels import ops as kops


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(rng, cfg) -> dict:
    d = cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "w_q": layers._dense_init(ks[0], d, cfg.num_heads * hd, cfg.np_dtype),
        "w_k": layers._dense_init(ks[1], d, cfg.num_kv_heads * hd,
                                  cfg.np_dtype),
        "w_v": layers._dense_init(ks[2], d, cfg.num_kv_heads * hd,
                                  cfg.np_dtype),
        "w_o": layers._dense_init(ks[3], cfg.num_heads * hd, d,
                                  cfg.np_dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.num_heads * hd,), cfg.np_dtype)
        p["b_k"] = jnp.zeros((cfg.num_kv_heads * hd,), cfg.np_dtype)
        p["b_v"] = jnp.zeros((cfg.num_kv_heads * hd,), cfg.np_dtype)
    return p


def _project_qkv(params, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if cfg.qkv_bias:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    q = q.reshape(B, S, cfg.num_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    q = layers.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = layers.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def _chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                       q_offset: int = 0):
    """Online-softmax over KV chunks via lax.scan (flash-in-XLA).

    Head dims may differ between q/k (d) and v (dv) — MLA uses 192/128.
    """
    B, H, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    dv = v.shape[-1]
    group = H // Hkv
    scale = 1.0 / np.sqrt(d)
    nchunks = (Sk + chunk - 1) // chunk
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(B, Hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nchunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32)
    qi = jnp.arange(Sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        kb = jnp.repeat(kb, group, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vb, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale
        ki = ci * chunk + jnp.arange(chunk)
        mask = ki[None, :] < Sk
        if causal:
            mask = mask & (ki[None, :] <= qi[:, None])
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(q.dtype)


def _full_attention(q, k, v, *, causal: bool, q_offset: int = 0):
    group = q.shape[1] // k.shape[1]
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Sk)[None, :]
        s = jnp.where(ki <= qi, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def gqa_apply(params: dict, x: jax.Array, cfg, *,
              positions: jax.Array | None = None) -> jax.Array:
    """Training / prefill forward (causal)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(params, x, cfg, positions)
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if S > 2048 else "full"
    if impl == "pallas":
        out = kops.flash_attention(q, k, v, causal=True)
    elif impl == "chunked":
        out = _chunked_attention(q, k, v, causal=True)
    else:
        out = _full_attention(q, k, v, causal=True)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ params["w_o"]


def gqa_prefill(params: dict, x: jax.Array, cfg, max_len: int
                ) -> tuple[jax.Array, dict]:
    """Forward over the prompt AND build the decode cache in one pass."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(params, x, cfg, positions)
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if S > 2048 else "full"
    if impl == "pallas":
        out = kops.flash_attention(q, k, v, causal=True)
    elif impl == "chunked":
        out = _chunked_attention(q, k, v, causal=True)
    else:
        out = _full_attention(q, k, v, causal=True)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        spad = ((0, 0), (0, 0), (0, max_len - S), (0, 0))
        cache = {"k": jnp.pad(kq, pad), "v": jnp.pad(vq, pad),
                 "k_scale": jnp.pad(ks, spad),
                 "v_scale": jnp.pad(vs, spad)}
    else:
        cache = {"k": jnp.pad(k, pad).astype(cfg.np_dtype),
                 "v": jnp.pad(v, pad).astype(cfg.np_dtype)}
    return out @ params["w_o"], cache


def mla_prefill(params: dict, x: jax.Array, cfg, max_len: int
                ) -> tuple[jax.Array, dict]:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)
    out = _mla_attend(params, q_nope, q_pe, c_kv, k_pe, cfg, causal=True)
    pad = ((0, 0), (0, max_len - S), (0, 0))
    cache = {"c_kv": jnp.pad(c_kv, pad).astype(cfg.np_dtype),
             "k_pe": jnp.pad(k_pe, pad).astype(cfg.np_dtype)}
    return out, cache


def _kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-vector symmetric int8: x (..., hd) → (int8, f16 scale (..., 1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def gqa_init_cache(cfg, batch: int, max_len: int) -> dict:
    shape = (batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        # §Perf: int8 KV halves decode's dominant HBM stream (the cache
        # read); per-vector f16 scales add hd/2 bytes per 128-wide vector.
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float16),
                "v_scale": jnp.zeros(sshape, jnp.float16)}
    return {"k": jnp.zeros(shape, cfg.np_dtype),
            "v": jnp.zeros(shape, cfg.np_dtype)}


def gqa_decode(params: dict, x: jax.Array, cache: dict, length: jax.Array,
               cfg) -> tuple[jax.Array, dict]:
    """One-token decode: append to cache, attend over the valid prefix.

    x: (B, 1, d); length: scalar int32 (tokens already in cache).
    """
    B = x.shape[0]
    length = jnp.asarray(length, jnp.int32)
    positions = jnp.broadcast_to(length[None], (B,))[:, None]  # (B, 1)
    q, k, v = _project_qkv(params, x, cfg, positions)
    lengths = jnp.full((B,), length + 1, jnp.int32)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                              (0, 0, length, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                              (0, 0, length, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, length, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, length, 0)),
        }
        out = _decode_chunked(q[:, :, 0], new_cache["k"], new_cache["v"],
                              lengths, k_scale=new_cache["k_scale"],
                              v_scale=new_cache["v_scale"])
        out = out.reshape(B, 1, -1)
        return out @ params["w_o"], new_cache
    # append new k/v at `length` (the decoupled cache write stage)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, length, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, length, 0))
    if cfg.attn_impl == "pallas":
        out = kops.decode_attention(q[:, :, 0], k_cache, v_cache, lengths)
    else:
        out = _decode_chunked(q[:, :, 0], k_cache, v_cache, lengths)
    out = out.reshape(B, 1, -1)
    return out @ params["w_o"], {"k": k_cache, "v": v_cache}


def _decode_chunked(q, k_cache, v_cache, lengths, chunk: int = 2048,
                    k_scale=None, v_scale=None):
    """(B,H,d) vs (B,Hkv,S,d) ragged cache — streamed online softmax.
    Optional per-vector scales dequantize an int8 cache chunk-by-chunk (the
    dequant fuses into the chunk body; HBM only streams int8)."""
    S = k_cache.shape[2]
    return _decode_masked_scan(q, k_cache, v_cache, lengths,
                               chunk=min(chunk, S),
                               k_scale=k_scale, v_scale=v_scale)


def _chunkify(x, nchunks, chunk, pad):
    B, Hkv = x.shape[:2]
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return xp.reshape(B, Hkv, nchunks, chunk,
                      x.shape[-1]).transpose(2, 0, 1, 3, 4)


def _decode_masked_scan(q, k_cache, v_cache, lengths, chunk: int,
                        k_scale=None, v_scale=None):
    B, H, d = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = H // Hkv
    scale = 1.0 / np.sqrt(d)
    nchunks = (S + chunk - 1) // chunk
    pad = nchunks * chunk - S
    kc = _chunkify(k_cache, nchunks, chunk, pad)
    vc = _chunkify(v_cache, nchunks, chunk, pad)
    quant = k_scale is not None
    if quant:
        ksc = _chunkify(k_scale, nchunks, chunk, pad)
        vsc = _chunkify(v_scale, nchunks, chunk, pad)
    else:  # dummy zero-width scales keep the scan structure uniform
        ksc = jnp.zeros((nchunks, B, Hkv, chunk, 0), jnp.float16)
        vsc = ksc
    qf = q.astype(jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ksb, vsb, ci = inp
        if quant:
            kb = _kv_dequantize(kb, ksb, jnp.float32)
            vb = _kv_dequantize(vb, vsb, jnp.float32)
        kb = jnp.repeat(kb, group, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vb, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhd,bhkd->bhk", qf, kb) * scale
        ki = ci * chunk + jnp.arange(chunk)
        mask = ki[None, None, :] < lengths[:, None, None]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhk,bhkd->bhd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    a0 = jnp.zeros((B, H, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, ksc, vsc,
                                   jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437)
# ---------------------------------------------------------------------------
#
# The KV cache stores only the compressed latent c_kv (kv_lora_rank) plus the
# decoupled RoPE key (rope_head_dim) — the memory stage shrinks by ~an order
# of magnitude, which is precisely the paper's "customize the memory
# interface per access stream" (§III-B2) applied to the KV cache.

def mla_init(rng, cfg) -> dict:
    m = cfg.mla
    d = cfg.d_model
    H = cfg.num_heads
    ks = jax.random.split(rng, 8)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": layers._dense_init(ks[0], d, m.q_lora_rank, cfg.np_dtype),
        "q_norm": layers.rmsnorm_init(m.q_lora_rank, cfg.np_dtype),
        "w_uq": layers._dense_init(ks[1], m.q_lora_rank, H * qk_head,
                                   cfg.np_dtype),
        "w_dkv": layers._dense_init(
            ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, cfg.np_dtype),
        "kv_norm": layers.rmsnorm_init(m.kv_lora_rank, cfg.np_dtype),
        "w_ukv": layers._dense_init(
            ks[3], m.kv_lora_rank,
            H * (m.qk_nope_head_dim + m.v_head_dim), cfg.np_dtype),
        "w_o": layers._dense_init(ks[4], H * m.v_head_dim, d, cfg.np_dtype),
    }


def _mla_qkv(params, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    # query path
    cq = layers.rmsnorm_apply(params["q_norm"], x @ params["w_dq"])
    q = (cq @ params["w_uq"]).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = layers.apply_rope(
        q_pe.transpose(0, 2, 1, 3), positions[:, None, :],
        cfg.rope_theta).transpose(0, 2, 1, 3)
    # kv latent path
    ckv_full = x @ params["w_dkv"]
    c_kv, k_pe = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = layers.rmsnorm_apply(params["kv_norm"], c_kv)
    k_pe = layers.apply_rope(k_pe[:, None], positions[:, None, :],
                             cfg.rope_theta)[:, 0]
    return q_nope, q_pe, c_kv, k_pe


def _mla_attend(params, q_nope, q_pe, c_kv, k_pe, cfg, *, causal,
                q_offset: int = 0):
    m = cfg.mla
    B, Sq, H, _ = q_nope.shape
    kv = (c_kv @ params["w_ukv"]).reshape(
        c_kv.shape[0], c_kv.shape[1], H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    qh = jnp.concatenate([q_nope, q_pe], axis=-1).transpose(0, 2, 1, 3)
    kh = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(k_pe[:, :, None],
                          k_nope.shape[:2] + (H, m.qk_rope_head_dim))],
        axis=-1).transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    if cfg.attn_impl in ("chunked", "auto") and qh.shape[2] > 2048:
        out = _chunked_attention(qh, kh, vh, causal=causal,
                                 q_offset=q_offset)
    else:
        out = _full_attention(qh, kh, vh, causal=causal, q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, H * m.v_head_dim)
    return out @ params["w_o"]


def mla_apply(params: dict, x: jax.Array, cfg, *,
              positions: jax.Array | None = None) -> jax.Array:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)
    return _mla_attend(params, q_nope, q_pe, c_kv, k_pe, cfg, causal=True)


def mla_init_cache(cfg, batch: int, max_len: int) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), cfg.np_dtype),
        "k_pe": jnp.zeros((batch, max_len, m.qk_rope_head_dim),
                          cfg.np_dtype),
    }


def mla_decode(params: dict, x: jax.Array, cache: dict, length: jax.Array,
               cfg) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    length = jnp.asarray(length, jnp.int32)
    positions = jnp.broadcast_to(length[None], (B,))[:, None]
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)
    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, length, 0))
    p_cache = jax.lax.dynamic_update_slice(
        cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, length, 0))
    if getattr(cfg, "mla_absorbed", False):
        out = _mla_decode_absorbed(params, q_nope, q_pe, c_cache, p_cache,
                                   length, cfg)
    else:
        # naive: decompress the whole cache and attend (baseline)
        out = _mla_attend(params, q_nope, q_pe, c_cache, p_cache, cfg,
                          causal=True, q_offset=length)
    return out, {"c_kv": c_cache, "k_pe": p_cache}


def _mla_decode_absorbed(params, q_nope, q_pe, c_cache, p_cache, length,
                         cfg) -> jax.Array:
    """Absorbed MLA decode (DeepSeek-V2 §Inference): fold W_uk into the
    query and W_uv into the output so attention runs directly in the
    compressed latent space — the per-step cache decompression
    (S·H·(nope+v) GEMM + its S·H·192 materialization) disappears.

    Beyond-paper §Perf optimization; numerically identical to the naive
    path (same linear algebra, reassociated).
    """
    m = cfg.mla
    B, _, H, _ = q_nope.shape
    S = c_cache.shape[1]
    r = m.kv_lora_rank
    w_ukv = params["w_ukv"].reshape(r, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[:, :, :m.qk_nope_head_dim]          # (r, H, nope)
    w_uv = w_ukv[:, :, m.qk_nope_head_dim:]          # (r, H, v)

    # absorb: q_lat (B, H, r) = q_nope · W_uk^T
    q_lat = jnp.einsum("bqhn,rhn->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    cf = c_cache.astype(jnp.float32)                 # (B, S, r)
    pf = p_cache.astype(jnp.float32)                 # (B, S, rope)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat, cf)
              + jnp.einsum("bqhp,bsp->bhs",
                           q_pe.astype(jnp.float32), pf)) * scale
    mask = jnp.arange(S)[None, None, :] <= length
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)              # (B, H, S)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, cf)        # (B, H, r)
    out = jnp.einsum("bhr,rhv->bhv", o_lat,
                     w_uv.astype(jnp.float32))       # (B, H, v)
    out = out.reshape(B, 1, H * m.v_head_dim).astype(q_nope.dtype)
    return out @ params["w_o"]

"""Mixture-of-Experts with top-k routing, shared experts, capacity dispatch.

MoE dispatch is the framework's second canonical "memory operation" in the
paper's taxonomy: a data-dependent scatter (tokens → expert buffers)
followed by a gather (expert outputs → token order), with the expert GEMMs
as the long-latency compute stage in between.  Algorithm 1 therefore cuts
stages exactly at dispatch and combine — which is how the layer is written:
scatter → batched expert FFN → gather, so the all-to-all traffic induced by
expert-parallel sharding (experts on the ``model`` axis) overlaps with the
expert GEMMs under the XLA scheduler.

Dispatch is sort-free scatter-add with per-expert capacity
``C = ceil(k·T/E · capacity_factor)``; overflow tokens are dropped (their
residual passes through — standard Switch behaviour), and the combine
re-weights by the router probabilities.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from . import layers


def moe_init(rng, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    E = m.num_experts
    p = {
        "router": layers._dense_init(ks[0], d, E, jnp.float32, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (E, d, m.d_ff), jnp.float32)
                   / np.sqrt(d)).astype(cfg.np_dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, m.d_ff), jnp.float32)
                 / np.sqrt(d)).astype(cfg.np_dtype),
        "w_down": (jax.random.normal(ks[3], (E, m.d_ff, d), jnp.float32)
                   / np.sqrt(m.d_ff)).astype(cfg.np_dtype),
    }
    if m.num_shared > 0:
        p["shared"] = layers.mlp_init(ks[4], d, m.d_ff * m.num_shared,
                                      cfg.act, cfg.np_dtype)
    return p


def moe_apply(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, dict]:
    """x: (B, S, d) → (y, aux) with load-balance metrics in aux."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    xt = x.reshape(T, d)

    # --- router (fp32 for numerics) ---------------------------------------
    logits = xt.astype(jnp.float32) @ params["router"]     # (T, E)
    if m.router_fn == "sigmoid":   # DeepSeek-V3 style
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    if m.route_groups > 1 and m.route_device_limit > 0:
        # §Perf: device-limited routing (DeepSeek-V3 node-limited routing):
        # keep only the top-M expert groups per token before the top-k, so
        # each token's dispatch fans out to ≤ M EP devices.
        G = m.route_groups
        gs = scores.reshape(T, G, E // G).max(axis=-1)      # (T, G)
        _, top_g = jax.lax.top_k(gs, m.route_device_limit)
        gmask = jax.nn.one_hot(top_g, G, dtype=scores.dtype).sum(1)
        scores = (scores.reshape(T, G, E // G)
                  * gmask[..., None]).reshape(T, E)
    top_w, top_ids = jax.lax.top_k(scores, k)              # (T, k)
    if m.normalize_weights:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- capacity + position within expert --------------------------------
    cap = int(np.ceil(k * T / E * m.capacity_factor))
    onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.int32)   # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                  # pos in expert
    pos = (pos * flat).sum(-1).reshape(T, k)               # (T, k)
    keep = pos < cap
    slot = top_ids * cap + pos                             # (T, k) in [0,E*cap)

    # --- scatter (dispatch: the memory stage) ------------------------------
    # §Perf knob: int8 dispatch — quantize the token payload before the
    # scatter (the expert-parallel all-to-all moves the scattered buffer,
    # so this halves its wire bytes); per-token f16 scales ride along.
    src = jnp.repeat(xt[:, None, :], k, axis=1)            # (T, k, d)
    src = jnp.where(keep[..., None], src, 0)
    if m.dispatch_dtype == "int8":
        s8 = jnp.max(jnp.abs(src.astype(jnp.float32)), -1,
                     keepdims=True) / 127.0
        s8 = jnp.maximum(s8, 1e-8)
        src_q = jnp.clip(jnp.round(src.astype(jnp.float32) / s8),
                         -127, 127).astype(jnp.int8)
        xe_q = jnp.zeros((E * cap, d), jnp.int8)
        xe_q = xe_q.at[slot.reshape(-1)].add(src_q.reshape(T * k, d))
        se = jnp.zeros((E * cap, 1), jnp.float16)
        se = se.at[slot.reshape(-1)].add(
            s8.reshape(T * k, 1).astype(jnp.float16))
        xe = (xe_q.astype(jnp.float32)
              * se.astype(jnp.float32)).astype(x.dtype)
    else:
        xe = jnp.zeros((E * cap, d), x.dtype)
        xe = xe.at[slot.reshape(-1)].add(src.reshape(T * k, d))
    xe = xe.reshape(E, cap, d)

    # --- expert FFN (the long-latency stage) -------------------------------
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = (jax.nn.silu(gate.astype(jnp.float32))
         * up.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, cap, d)

    # --- gather (combine: the second memory stage) --------------------------
    yk = ye.reshape(E * cap, d)[slot.reshape(-1)].reshape(T, k, d)
    yk = yk * (top_w * keep).astype(jnp.float32)[..., None]
    y = yk.sum(axis=1).astype(x.dtype)

    # --- shared experts (always-on streaming partition) ---------------------
    if m.num_shared > 0:
        y = y + layers.mlp_apply(params["shared"], xt, cfg.act)

    # --- aux: load-balance loss (Switch-style) ------------------------------
    me = scores.mean(axis=0)                                # (E,)
    ce = (onehot.sum(axis=1).astype(jnp.float32)).mean(axis=0) * (E / k)
    aux = {
        "lb_loss": (me * ce).sum() * E,
        "dropped_frac": 1.0 - keep.mean(),
    }
    return y.reshape(B, S, d), aux

"""State-space sequence mixers: Mamba-1 (Jamba) and RWKV-6 "Finch".

Both recurrences are loop-carried SCCs in the paper's terms: the state
update ``h_t = f(h_{t-1}, x_t)`` is a dependence cycle that Algorithm 1
keeps inside one stage — the template cannot pipeline *across* it (the DFS
negative result, §V-A).  What the template *does* decouple is the traffic
around the cycle: input projections (streaming loads), the scan itself
(the SCC stage), and the output projection/gating (downstream compute).

Two scan implementations:

* ``sequential`` — ``lax.scan`` over time with O(B·d_inner·N) state; always
  correct, memory-minimal; the default and the decode path.
* ``chunked``    — scan over chunks with an in-chunk parallel prefix
  (materializes (B, chunk, d_inner, N) only per chunk) — the TPU-friendly
  training path; chunk size bounds the VMEM/HBM working set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM) — arXiv:2312.00752 as used by Jamba (2403.19887)
# ---------------------------------------------------------------------------

def mamba_init(rng, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner
    ks = jax.random.split(rng, 6)
    A = jnp.broadcast_to(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                         (d_in, s.d_state))
    return {
        "w_in": layers._dense_init(ks[0], d, 2 * d_in, cfg.np_dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_in), jnp.float32)
                   * 0.1).astype(cfg.np_dtype),
        "conv_b": jnp.zeros((d_in,), cfg.np_dtype),
        "w_x": layers._dense_init(ks[2], d_in,
                                  s.dt_rank + 2 * s.d_state, cfg.np_dtype),
        "w_dt": layers._dense_init(ks[3], s.dt_rank, d_in, cfg.np_dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "w_out": layers._dense_init(ks[4], d_in, d, cfg.np_dtype),
    }


def _causal_conv1d(x, w, b, state=None):
    """x: (B, L, d_in); w: (K, d_in) depthwise.  state: (B, K-1, d_in)
    carries the last K−1 inputs for decode."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out + b, new_state


def _selective_scan_seq(dt, A, Bc, Cc, x):
    """Sequential scan.  dt,x: (B,L,dI); A: (dI,N); Bc,Cc: (B,L,N)."""

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp                   # (B,dI),(B,N),(B,N),(B,dI)
        da = jnp.exp(dt_t[..., None] * A)           # (B, dI, N)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = (h * C_t[:, None, :]).sum(-1)           # (B, dI)
        return h, y

    B, L, dI = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((B, dI, N), jnp.float32)
    xs = (dt.transpose(1, 0, 2), Bc.transpose(1, 0, 2),
          Cc.transpose(1, 0, 2), x.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_final           # (B, L, dI), (B, dI, N)


def _selective_scan_chunked(dt, A, Bc, Cc, x, chunk: int = 16):
    """Chunked scan: sequential over L/chunk, parallel inside the chunk via
    materialized decay products (the SSD-style formulation)."""
    B, L, dI = x.shape
    N = A.shape[1]
    nc = L // chunk
    assert L % chunk == 0

    dt_c = dt.reshape(B, nc, chunk, dI)
    Bc_c = Bc.reshape(B, nc, chunk, N)
    Cc_c = Cc.reshape(B, nc, chunk, N)
    x_c = x.reshape(B, nc, chunk, dI)

    def chunk_step(h, inp):
        dtc, bcc, ccc, xc = inp      # (B,chunk,dI),(B,chunk,N),...
        # log-decay prefix within the chunk
        la = dtc[..., None] * A      # (B,chunk,dI,N)
        cum = jnp.cumsum(la, axis=1)
        # contribution of the carried state h to each position
        h_part = jnp.einsum("bcin,bin->bcin", jnp.exp(cum),
                            h)                        # decayed carry
        # pairwise within-chunk contributions: token j→i (j<=i)
        # decay(i,j) = exp(cum_i - cum_j)
        contrib = (dtc * xc)[..., None] * bcc[:, :, None, :]  # (B,c,dI,N)
        dec = jnp.exp(cum[:, :, None] - cum[:, None])  # (B,c,c,dI,N)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dec = jnp.where(mask[None, :, :, None, None], dec, 0.0)
        acc = jnp.einsum("bijdn,bjdn->bidn", dec, contrib)
        hs = h_part + acc                              # (B,c,dI,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, ccc)
        return hs[:, -1], y

    h0 = jnp.zeros((B, dI, N), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_step, h0,
        (dt_c.transpose(1, 0, 2, 3), Bc_c.transpose(1, 0, 2, 3),
         Cc_c.transpose(1, 0, 2, 3), x_c.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).reshape(B, L, dI), h_final


def mamba_apply(params: dict, x: jax.Array, cfg,
                return_cache: bool = False):
    s = cfg.ssm
    B, L, _ = x.shape
    xz = x @ params["w_in"]
    xin_raw, z = jnp.split(xz, 2, axis=-1)
    xin, _ = _causal_conv1d(xin_raw, params["conv_w"], params["conv_b"])
    xin = jax.nn.silu(xin.astype(jnp.float32))
    proj = (xin.astype(x.dtype) @ params["w_x"]).astype(jnp.float32)
    dt, Bc, Cc = jnp.split(proj, [s.dt_rank, s.dt_rank + s.d_state], -1)
    dt = jax.nn.softplus(dt @ params["w_dt"].astype(jnp.float32)
                         + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    if s.scan_impl == "chunked" and L % s.chunk == 0 and L > s.chunk:
        y, h_final = _selective_scan_chunked(dt, A, Bc, Cc, xin,
                                             chunk=s.chunk)
    else:
        y, h_final = _selective_scan_seq(dt, A, Bc, Cc, xin)
    y = y + params["D"] * xin
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(x.dtype)) @ params["w_out"]
    if return_cache:
        K = s.d_conv
        conv_state = xin_raw[:, -(K - 1):, :].astype(cfg.np_dtype)
        return out, {"h": h_final, "conv": conv_state}
    return out


def mamba_init_cache(cfg, batch: int) -> dict:
    s = cfg.ssm
    return {
        "h": jnp.zeros((batch, s.d_inner, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, s.d_inner), cfg.np_dtype),
    }


def mamba_decode(params: dict, x: jax.Array, cache: dict,
                 cfg) -> tuple[jax.Array, dict]:
    """One-token step.  x: (B, 1, d)."""
    s = cfg.ssm
    xz = x @ params["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _causal_conv1d(xin, params["conv_w"],
                                     params["conv_b"], cache["conv"])
    xin = jax.nn.silu(xin.astype(jnp.float32))[:, 0]     # (B, dI)
    proj = (xin.astype(x.dtype) @ params["w_x"]).astype(jnp.float32)
    dt, Bc, Cc = jnp.split(proj, [s.dt_rank, s.dt_rank + s.d_state], -1)
    dt = jax.nn.softplus(dt @ params["w_dt"].astype(jnp.float32)
                         + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[..., None] * A)
    h = da * cache["h"] + (dt * xin)[..., None] * Bc[:, None, :]
    y = (h * Cc[:, None, :]).sum(-1) + params["D"] * xin
    y = y * jax.nn.silu(z.astype(jnp.float32)[:, 0])
    out = (y.astype(x.dtype) @ params["w_out"])[:, None, :]
    return out, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" — arXiv:2404.05892 (data-dependent decay)
# ---------------------------------------------------------------------------

def rwkv6_init(rng, cfg) -> dict:
    d = cfg.d_model
    H = cfg.rwkv_heads
    hd = d // H
    ks = jax.random.split(rng, 10)
    lora = cfg.rwkv_decay_lora
    return {
        # token-shift mix coefficients (per channel)
        "mu_r": jnp.full((d,), 0.5, cfg.np_dtype),
        "mu_k": jnp.full((d,), 0.5, cfg.np_dtype),
        "mu_v": jnp.full((d,), 0.5, cfg.np_dtype),
        "mu_w": jnp.full((d,), 0.5, cfg.np_dtype),
        "mu_g": jnp.full((d,), 0.5, cfg.np_dtype),
        "w_r": layers._dense_init(ks[0], d, d, cfg.np_dtype),
        "w_k": layers._dense_init(ks[1], d, d, cfg.np_dtype),
        "w_v": layers._dense_init(ks[2], d, d, cfg.np_dtype),
        "w_g": layers._dense_init(ks[3], d, d, cfg.np_dtype),
        "w_o": layers._dense_init(ks[4], d, d, cfg.np_dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_A": layers._dense_init(ks[5], d, lora, cfg.np_dtype),
        "decay_B": layers._dense_init(ks[6], lora, d, cfg.np_dtype),
        "bonus_u": (jax.random.normal(ks[7], (H, hd), jnp.float32)
                    * 0.1),
        "ln_x": layers.layernorm_init(d, cfg.np_dtype),
    }


def _token_shift(x, prev=None):
    """RWKV token shift: x_{t-1} (zeros / carried state at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_mix(x, xs, mu):
    return x + (xs - x) * mu


def rwkv6_apply(params: dict, x: jax.Array, cfg,
                return_cache: bool = False):
    B, L, d = x.shape
    H = cfg.rwkv_heads
    hd = d // H
    xs = _token_shift(x)
    r = _rwkv_mix(x, xs, params["mu_r"]) @ params["w_r"]
    k = _rwkv_mix(x, xs, params["mu_k"]) @ params["w_k"]
    v = _rwkv_mix(x, xs, params["mu_v"]) @ params["w_v"]
    g = _rwkv_mix(x, xs, params["mu_g"]) @ params["w_g"]
    xw = _rwkv_mix(x, xs, params["mu_w"])
    w = params["decay_w0"] + (jnp.tanh(
        (xw @ params["decay_A"]).astype(jnp.float32))
        @ params["decay_B"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w))                                  # (B, L, d)

    rh = r.reshape(B, L, H, hd).astype(jnp.float32)
    kh = k.reshape(B, L, H, hd).astype(jnp.float32)
    vh = v.reshape(B, L, H, hd).astype(jnp.float32)
    wh = w.reshape(B, L, H, hd)
    u = params["bonus_u"]                                      # (H, hd)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp        # (B,H,hd) each
        kv = k_t[..., None] * v_t[..., None, :]        # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S_final, ys = jax.lax.scan(
        step, S0,
        (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
         vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, d)
    y = layers.layernorm_apply(params["ln_x"], y.astype(x.dtype))
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = y @ params["w_o"]
    if return_cache:
        return out, {"S": S_final, "x_prev": x[:, -1:, :]}
    return out


def rwkv6_init_cache(cfg, batch: int) -> dict:
    d = cfg.d_model
    H = cfg.rwkv_heads
    hd = d // H
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_prev": jnp.zeros((batch, 1, d), cfg.np_dtype),
    }


def rwkv6_decode(params: dict, x: jax.Array, cache: dict,
                 cfg) -> tuple[jax.Array, dict]:
    B, _, d = x.shape
    H = cfg.rwkv_heads
    hd = d // H
    xs = cache["x_prev"]
    r = _rwkv_mix(x, xs, params["mu_r"]) @ params["w_r"]
    k = _rwkv_mix(x, xs, params["mu_k"]) @ params["w_k"]
    v = _rwkv_mix(x, xs, params["mu_v"]) @ params["w_v"]
    g = _rwkv_mix(x, xs, params["mu_g"]) @ params["w_g"]
    xw = _rwkv_mix(x, xs, params["mu_w"])
    w = params["decay_w0"] + (jnp.tanh(
        (xw @ params["decay_A"]).astype(jnp.float32))
        @ params["decay_B"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w)).reshape(B, H, hd)
    r_t = r.reshape(B, H, hd).astype(jnp.float32)
    k_t = k.reshape(B, H, hd).astype(jnp.float32)
    v_t = v.reshape(B, H, hd).astype(jnp.float32)
    u = params["bonus_u"]
    kv = k_t[..., None] * v_t[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r_t, cache["S"] + u[..., None] * kv)
    S = w[..., None] * cache["S"] + kv
    y = y.reshape(B, 1, d).astype(x.dtype)
    y = layers.layernorm_apply(params["ln_x"], y)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return y @ params["w_o"], {"S": S, "x_prev": x}

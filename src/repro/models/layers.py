"""Shared model layers: norms, rotary embeddings, MLPs, embeddings.

Pure-functional: params are nested dicts of arrays, every layer is
``apply(params, x, cfg) -> y`` with a matching ``init(rng, cfg) -> params``.
All inits work under ``jax.eval_shape`` (the dry-run never allocates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(rng, in_dim: int, out_dim: int, dtype,
                scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params: dict, x: jax.Array,
                    eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    if "scale" in params:
        out = out * params["scale"].astype(jnp.float32)
    if "bias" in params:
        out = out + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def nonparametric_ln_apply(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style LayerNorm without learnable affine [arXiv:2402.00838]."""
    return layernorm_apply({}, x, eps)


def make_norm(kind: str):
    """Returns (init(d, dtype) -> params, apply(params, x) -> y)."""
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm_apply
    if kind == "layernorm":
        return layernorm_init, layernorm_apply
    if kind == "nonparametric_ln":
        return (lambda d, dtype: {}), (
            lambda params, x: nonparametric_ln_apply(x))
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: (..., S, d) with d even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    p = {"w_up": _dense_init(ks[0], d, d_ff, dtype),
         "w_down": _dense_init(ks[1], d_ff, d, dtype)}
    if act == "silu":  # SwiGLU: separate gate
        p["w_gate"] = _dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    up = x @ params["w_up"]
    if act == "silu":
        gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
        h = (gate * up.astype(jnp.float32)).astype(x.dtype)
    elif act == "gelu":
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(act)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embedding_init(rng, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(rng, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embedding_apply(params: dict, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed_apply(params: dict, x: jax.Array) -> jax.Array:
    """Logits in fp32 (loss numerics)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))

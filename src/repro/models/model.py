"""Model facade: init / loss / prefill / decode / input_specs per arch.

This is the public modelling API the launcher, dry-run, examples and tests
use.  Everything is shape-driven: ``input_specs`` produces the
ShapeDtypeStruct stand-ins for any (config × input-shape) cell, so the
multi-pod dry-run lowers every cell without allocating a byte.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers, transformer
from ..configs.base import InputShape, ModelConfig, SHAPES


LB_LOSS_WEIGHT = 0.01
MTP_LOSS_WEIGHT = 0.3


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    params = transformer.init_params(rng, cfg)
    if cfg.mtp_depth > 0:
        k1, k2 = jax.random.split(jax.random.fold_in(rng, 7))
        d = cfg.d_model
        params["mtp"] = {
            "proj": layers._dense_init(k1, 2 * d, d, cfg.np_dtype),
            "layer": transformer._layer_init(
                k2, cfg.segments[-1].unit[-1], cfg),
            "norm": layers.rmsnorm_init(d, cfg.np_dtype),
        }
    return params


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy in fp32.  logits (..., V), labels (...)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_fn(params: dict, batch: dict, cfg: ModelConfig
            ) -> tuple[jax.Array, dict]:
    """Next-token LM loss (+ MoE load-balance + optional MTP)."""
    if cfg.frontend_stub and "embeds" in batch:
        inputs = batch["embeds"]
        labels = batch["labels"]
    else:
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = transformer.forward(
        params, inputs, cfg, return_hidden=cfg.mtp_depth > 0)
    loss = _xent(logits, labels)
    metrics = {"lm_loss": loss}
    if cfg.moe is not None:
        n_moe = max(1, sum(
            seg.repeats * sum(1 for s in seg.unit if s.mlp == "moe")
            for seg in cfg.segments))
        lb = aux["lb_loss"] / n_moe
        loss = loss + LB_LOSS_WEIGHT * lb
        metrics["lb_loss"] = lb
    if cfg.mtp_depth > 0:
        # DeepSeek-V3 MTP: predict token t+2 from [h_t ; emb(t+1)]
        h = aux["hidden"][:, :-1]                       # h_t, t < S-1
        nxt = inputs[:, 1:]                             # token t+1
        emb_nxt = layers.embedding_apply(params["embed"], nxt)
        h2 = jnp.concatenate([h, emb_nxt], axis=-1) @ params["mtp"]["proj"]
        h2 = transformer._layer_apply(
            params["mtp"]["layer"], h2, cfg.segments[-1].unit[-1], cfg, {})
        h2 = layers.rmsnorm_apply(params["mtp"]["norm"], h2)
        emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits2 = layers.unembed_apply(emb, h2)
        mtp_loss = _xent(logits2, labels[:, 1:])
        loss = loss + MTP_LOSS_WEIGHT * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


forward = transformer.forward
prefill = transformer.prefill
decode_step = transformer.decode_step
init_cache = transformer.init_cache


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape | str) -> dict:
    """Inputs for the step function of the given kind — ShapeDtypeStructs
    only, weak-type-correct, shardable, no device allocation."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.frontend_stub:
            return {"embeds": sds((B, S, cfg.d_model), cfg.np_dtype),
                    "labels": sds((B, S), jnp.int32)}
        return {"tokens": sds((B, S + 1), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.frontend_stub:
            return {"embeds": sds((B, S, cfg.d_model), cfg.np_dtype)}
        return {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, B, S))
        return {"token": sds((B,), jnp.int32),
                "length": sds((), jnp.int32),
                "cache": cache}
    raise ValueError(shape.kind)

"""Model zoo: config-driven dense / MoE / hybrid / SSM decoder LMs."""

from . import attention, layers, model, moe, ssm, transformer
from .model import (init_params, loss_fn, forward, prefill, decode_step,
                    init_cache, input_specs)

__all__ = ["attention", "layers", "model", "moe", "ssm", "transformer",
           "init_params", "loss_fn", "forward", "prefill", "decode_step",
           "init_cache", "input_specs"]

"""Config-driven decoder LM: dense / MoE / hybrid / SSM in one builder.

Layers are grouped into config-declared *segments* (a repeating unit of
≤8 layer specs, scanned ``repeats`` times).  Per-repeat parameters are
stacked on a leading axis so ``lax.scan`` keeps the HLO proportional to the
unit size, not the depth — 61-layer DeepSeek and 72-layer Jamba lower in
seconds and the dry-run's compiled artifact stays tractable.

Decode carries a pytree of caches with the same (segments → repeats →
sublayer) structure; the per-repeat cache slices ride through the scan as
``xs``/``ys``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention, layers, moe, ssm
from ..configs.base import LayerSpec, ModelConfig


# ---------------------------------------------------------------------------
# RWKV channel mix (the FFN used with rwkv mixer layers)
# ---------------------------------------------------------------------------

def _cmix_init(rng, cfg) -> dict:
    d = cfg.d_model
    dh = int(3.5 * d)
    ks = jax.random.split(rng, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, cfg.np_dtype),
        "w_k": layers._dense_init(ks[0], d, dh, cfg.np_dtype),
        "w_v": layers._dense_init(ks[1], dh, d, cfg.np_dtype),
        "w_r": layers._dense_init(ks[2], d, d, cfg.np_dtype),
    }


def _cmix_apply(params, x, prev=None):
    xs = ssm._token_shift(x, prev)
    xk = ssm._rwkv_mix(x, xs, params["mu_k"])
    k = jnp.square(jax.nn.relu((xk @ params["w_k"]).astype(jnp.float32)))
    r = jax.nn.sigmoid((x @ params["w_r"]).astype(jnp.float32))
    return (r * (k.astype(x.dtype) @ params["w_v"]).astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Sub-layer init/apply dispatch
# ---------------------------------------------------------------------------

def _mixer_init(rng, spec: LayerSpec, cfg) -> dict:
    if spec.mixer == "attn":
        return attention.gqa_init(rng, cfg)
    if spec.mixer == "mla":
        return attention.mla_init(rng, cfg)
    if spec.mixer == "mamba":
        return ssm.mamba_init(rng, cfg)
    if spec.mixer == "rwkv":
        return ssm.rwkv6_init(rng, cfg)
    raise ValueError(spec.mixer)


def _mlp_init(rng, spec: LayerSpec, cfg) -> dict:
    if spec.mlp == "dense":
        return layers.mlp_init(rng, cfg.d_model, cfg.d_ff, cfg.act,
                               cfg.np_dtype)
    if spec.mlp == "moe":
        return moe.moe_init(rng, cfg)
    if spec.mlp == "rwkv_cmix":
        return _cmix_init(rng, cfg)
    raise ValueError(spec.mlp)


def _layer_init(rng, spec: LayerSpec, cfg) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    ninit, _ = layers.make_norm(cfg.norm)
    return {
        "norm1": ninit(cfg.d_model, cfg.np_dtype),
        "mixer": _mixer_init(k1, spec, cfg),
        "norm2": ninit(cfg.d_model, cfg.np_dtype),
        "mlp": _mlp_init(k2, spec, cfg),
    }


def _layer_apply(params: dict, x: jax.Array, spec: LayerSpec, cfg,
                 aux_acc: dict) -> jax.Array:
    _, napply = layers.make_norm(cfg.norm)
    h1 = napply(params["norm1"], x)
    if spec.mixer == "attn":
        mix = attention.gqa_apply(params["mixer"], h1, cfg)
    elif spec.mixer == "mla":
        mix = attention.mla_apply(params["mixer"], h1, cfg)
    elif spec.mixer == "mamba":
        mix = ssm.mamba_apply(params["mixer"], h1, cfg)
    elif spec.mixer == "rwkv":
        mix = ssm.rwkv6_apply(params["mixer"], h1, cfg)
    else:
        raise ValueError(spec.mixer)

    if cfg.parallel_block:
        # Cohere-style: attn and mlp both read the same normed input
        if spec.mlp == "moe":
            ff, aux = moe.moe_apply(params["mlp"], h1, cfg)
            aux_acc["lb_loss"] = aux_acc.get("lb_loss", 0.0) + aux["lb_loss"]
        elif spec.mlp == "rwkv_cmix":
            ff = _cmix_apply(params["mlp"], h1)
        else:
            ff = layers.mlp_apply(params["mlp"], h1, cfg.act)
        return x + mix + ff

    x = x + mix
    h2 = napply(params["norm2"], x)
    if spec.mlp == "moe":
        ff, aux = moe.moe_apply(params["mlp"], h2, cfg)
        aux_acc["lb_loss"] = aux_acc.get("lb_loss", 0.0) + aux["lb_loss"]
    elif spec.mlp == "rwkv_cmix":
        ff = _cmix_apply(params["mlp"], h2)
    else:
        ff = layers.mlp_apply(params["mlp"], h2, cfg.act)
    return x + ff


# ---------------------------------------------------------------------------
# Decode (cache-carrying) sub-layer apply
# ---------------------------------------------------------------------------

def _layer_decode(params: dict, x: jax.Array, cache: dict,
                  length: jax.Array, spec: LayerSpec, cfg
                  ) -> tuple[jax.Array, dict]:
    _, napply = layers.make_norm(cfg.norm)
    h1 = napply(params["norm1"], x)
    if spec.mixer == "attn":
        mix, mcache = attention.gqa_decode(params["mixer"], h1,
                                           cache["mixer"], length, cfg)
    elif spec.mixer == "mla":
        mix, mcache = attention.mla_decode(params["mixer"], h1,
                                           cache["mixer"], length, cfg)
    elif spec.mixer == "mamba":
        mix, mcache = ssm.mamba_decode(params["mixer"], h1,
                                       cache["mixer"], cfg)
    elif spec.mixer == "rwkv":
        mix, mcache = ssm.rwkv6_decode(params["mixer"], h1,
                                       cache["mixer"], cfg)
    else:
        raise ValueError(spec.mixer)

    new_cache = dict(cache)
    new_cache["mixer"] = mcache
    if cfg.parallel_block:
        if spec.mlp == "moe":
            ff, _ = moe.moe_apply(params["mlp"], h1, cfg)
        elif spec.mlp == "rwkv_cmix":
            ff = _cmix_apply(params["mlp"], h1, prev=cache.get("cmix_prev"))
            new_cache["cmix_prev"] = h1
        else:
            ff = layers.mlp_apply(params["mlp"], h1, cfg.act)
        return x + mix + ff, new_cache

    x = x + mix
    h2 = napply(params["norm2"], x)
    if spec.mlp == "moe":
        ff, _ = moe.moe_apply(params["mlp"], h2, cfg)
    elif spec.mlp == "rwkv_cmix":
        ff = _cmix_apply(params["mlp"], h2, prev=cache.get("cmix_prev"))
        new_cache["cmix_prev"] = h2
    else:
        ff = layers.mlp_apply(params["mlp"], h2, cfg.act)
    return x + ff, new_cache


def _layer_prefill(params: dict, x: jax.Array, spec: LayerSpec, cfg,
                   max_len: int) -> tuple[jax.Array, dict]:
    """Forward over the prompt, emitting this layer's decode cache."""
    _, napply = layers.make_norm(cfg.norm)
    h1 = napply(params["norm1"], x)
    new_cache: dict[str, Any] = {}
    if spec.mixer == "attn":
        mix, mcache = attention.gqa_prefill(params["mixer"], h1, cfg,
                                            max_len)
    elif spec.mixer == "mla":
        mix, mcache = attention.mla_prefill(params["mixer"], h1, cfg,
                                            max_len)
    elif spec.mixer == "mamba":
        mix, mcache = ssm.mamba_apply(params["mixer"], h1, cfg,
                                      return_cache=True)
    elif spec.mixer == "rwkv":
        mix, mcache = ssm.rwkv6_apply(params["mixer"], h1, cfg,
                                      return_cache=True)
    else:
        raise ValueError(spec.mixer)
    new_cache["mixer"] = mcache

    if cfg.parallel_block:
        if spec.mlp == "moe":
            ff, _ = moe.moe_apply(params["mlp"], h1, cfg)
        elif spec.mlp == "rwkv_cmix":
            ff = _cmix_apply(params["mlp"], h1)
            new_cache["cmix_prev"] = h1[:, -1:, :]
        else:
            ff = layers.mlp_apply(params["mlp"], h1, cfg.act)
        return x + mix + ff, new_cache

    x = x + mix
    h2 = napply(params["norm2"], x)
    if spec.mlp == "moe":
        ff, _ = moe.moe_apply(params["mlp"], h2, cfg)
    elif spec.mlp == "rwkv_cmix":
        ff = _cmix_apply(params["mlp"], h2)
        new_cache["cmix_prev"] = h2[:, -1:, :]
    else:
        ff = layers.mlp_apply(params["mlp"], h2, cfg.act)
    return x + ff, new_cache


def prefill(params: dict, tokens_or_embeds: jax.Array, cfg: ModelConfig,
            max_len: int) -> tuple[jax.Array, dict]:
    """Prompt forward + cache build.  Returns (last-position logits, cache)."""
    if cfg.frontend_stub and tokens_or_embeds.ndim == 3:
        x = tokens_or_embeds.astype(cfg.np_dtype)
    else:
        x = layers.embedding_apply(params["embed"], tokens_or_embeds)
    cache: dict[str, Any] = {}
    for si, seg in enumerate(cfg.segments):
        stacked = params[f"segment_{si}"]

        def body(x, rep_params, seg=seg):
            rep_cache = []
            for j, spec in enumerate(seg.unit):
                x, c = _layer_prefill(rep_params[j], x, spec, cfg, max_len)
                rep_cache.append(c)
            return x, rep_cache

        x, seg_cache = jax.lax.scan(body, x, stacked)
        cache[f"segment_{si}"] = seg_cache

    _, napply = layers.make_norm(cfg.norm)
    x = napply(params["final_norm"], x[:, -1:, :])
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(emb, x)[:, 0]
    return logits, cache


def _layer_init_cache(spec: LayerSpec, cfg, batch: int,
                      max_len: int) -> dict:
    c: dict[str, Any] = {}
    if spec.mixer == "attn":
        c["mixer"] = attention.gqa_init_cache(cfg, batch, max_len)
    elif spec.mixer == "mla":
        c["mixer"] = attention.mla_init_cache(cfg, batch, max_len)
    elif spec.mixer == "mamba":
        c["mixer"] = ssm.mamba_init_cache(cfg, batch)
    elif spec.mixer == "rwkv":
        c["mixer"] = ssm.rwkv6_init_cache(cfg, batch)
    if spec.mlp == "rwkv_cmix":
        c["cmix_prev"] = jnp.zeros((batch, 1, cfg.d_model), cfg.np_dtype)
    return c


# ---------------------------------------------------------------------------
# Whole-model init / forward / decode
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig) -> dict:
    keys = jax.random.split(rng, len(cfg.segments) + 2)
    params: dict[str, Any] = {
        "embed": layers.embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                       cfg.np_dtype),
    }
    ninit, _ = layers.make_norm(cfg.norm)
    params["final_norm"] = ninit(cfg.d_model, cfg.np_dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = layers.embedding_init(
            keys[1], cfg.vocab_size, cfg.d_model, cfg.np_dtype)

    for si, seg in enumerate(cfg.segments):
        seg_keys = jax.random.split(keys[2 + si], seg.repeats)

        def one_repeat(k):
            lk = jax.random.split(k, len(seg.unit))
            return [
                _layer_init(lk[j], spec, cfg)
                for j, spec in enumerate(seg.unit)
            ]

        stacked = jax.vmap(one_repeat)(seg_keys)
        params[f"segment_{si}"] = stacked
    return params


def forward(params: dict, tokens_or_embeds: jax.Array,
            cfg: ModelConfig, *,
            return_hidden: bool = False) -> tuple[jax.Array, dict]:
    """Full-sequence causal forward.  Returns (logits, aux)."""
    if cfg.frontend_stub and tokens_or_embeds.ndim == 3:
        x = tokens_or_embeds.astype(cfg.np_dtype)
    else:
        x = layers.embedding_apply(params["embed"], tokens_or_embeds)

    from ..runtime.sharding import sp_constrain

    total_aux = {"lb_loss": jnp.zeros((), jnp.float32)}
    for si, seg in enumerate(cfg.segments):
        stacked = params[f"segment_{si}"]

        def body(x, rep_params, seg=seg):
            aux_acc: dict[str, Any] = {}
            for j, spec in enumerate(seg.unit):
                x = _layer_apply(rep_params[j], x, spec, cfg, aux_acc)
                x = sp_constrain(x)  # §Perf B3: no-op unless SP enabled
            lb = jnp.asarray(aux_acc.get("lb_loss", 0.0), jnp.float32)
            return x, lb

        if cfg.remat:
            # activation checkpointing: store only the per-repeat residual,
            # recompute layer internals in backward (trades ~1/3 more
            # flops for O(depth) less live activation memory)
            body = jax.checkpoint(body)

        x, lbs = jax.lax.scan(body, x, stacked)
        total_aux["lb_loss"] = total_aux["lb_loss"] + lbs.sum()

    _, napply = layers.make_norm(cfg.norm)
    x = napply(params["final_norm"], x)
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(emb, x)
    if return_hidden:
        total_aux["hidden"] = x
    return logits, total_aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    cache: dict[str, Any] = {}
    for si, seg in enumerate(cfg.segments):

        def one_repeat(_):
            return [_layer_init_cache(spec, cfg, batch, max_len)
                    for spec in seg.unit]

        cache[f"segment_{si}"] = jax.vmap(one_repeat)(
            jnp.arange(seg.repeats))
    return cache


def decode_step(params: dict, token: jax.Array, cache: dict,
                length: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, dict]:
    """One new token for every sequence.  token: (B,) int32; returns
    (logits (B, vocab), new_cache)."""
    x = layers.embedding_apply(params["embed"], token[:, None])
    new_cache: dict[str, Any] = {}
    for si, seg in enumerate(cfg.segments):
        stacked = params[f"segment_{si}"]
        seg_cache = cache[f"segment_{si}"]

        def body(x, inp, seg=seg):
            rep_params, rep_cache = inp
            new_rep_cache = []
            for j, spec in enumerate(seg.unit):
                x, c = _layer_decode(rep_params[j], x, rep_cache[j],
                                     length, spec, cfg)
                new_rep_cache.append(c)
            return x, new_rep_cache

        x, new_seg_cache = jax.lax.scan(body, x, (stacked, seg_cache))
        new_cache[f"segment_{si}"] = new_seg_cache

    _, napply = layers.make_norm(cfg.norm)
    x = napply(params["final_norm"], x)
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(emb, x)[:, 0]
    return logits, new_cache

"""repro — the dataflow architectural template (Cheng & Wawrzynek 2016)
as a production JAX/TPU training & serving framework.

Subpackages:
  core       — CDFG partitioner (Algorithm 1), channels, pipeline executors,
               fidelity simulator
  dataflow   — the compiler driver: dataflow_jit / compile, the pass
               pipeline, and the execution-backend registry (docs/api.md)
  kernels    — Pallas TPU kernels (decoupled access/execute) + oracles
  models     — config-driven LM zoo (dense / MoE / hybrid / SSM)
  configs    — the 10 assigned architectures (exact public configs)
  optim      — sharded AdamW, schedules, int8 gradient compression
  data       — prefetching input pipeline
  checkpoint — atomic async checkpoints, resharding restore
  runtime    — sharding rules, fault tolerance
  launch     — mesh, dry-run, train/serve drivers
"""

__version__ = "1.0.0"

"""Input pipeline (decoupled host stage with bounded prefetch FIFO)."""

from .pipeline import DataConfig, file_stream, prefetched, synthetic_stream

__all__ = ["DataConfig", "file_stream", "prefetched", "synthetic_stream"]

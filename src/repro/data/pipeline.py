"""Input pipeline — the dataflow template applied to the host boundary.

The training step's first "memory operation" is the batch fetch itself.
Per the template, it gets its own decoupled stage: a producer thread
tokenizes/shards the next batches into a bounded :class:`HostFIFO` while
the device computes the current step — host latency is hidden exactly like
a cache miss behind a long-latency FMA stage (§II).

Sources: a deterministic synthetic LM stream (self-contained benchmarks),
and a memory-mapped token-file reader for real corpora.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channels import HostFIFO


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 256
    seed: int = 0
    prefetch_depth: int = 4


def synthetic_stream(cfg: DataConfig, *, start_step: int = 0
                     ) -> Iterator[dict]:
    """Deterministic synthetic LM data with learnable structure (a noisy
    periodic token process — losses actually go down on it).

    Deterministic in ``step`` so that checkpoint-resume reproduces the
    exact same batch sequence (required by the fault-tolerance test).
    """
    step = start_step
    while True:
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch_size, cfg.seq_len
        pos = np.arange(S + 1)[None, :] + rng.integers(
            0, cfg.vocab_size, (B, 1))
        period = rng.integers(3, 11, (B, 1))
        base = (pos // period * period) % cfg.vocab_size
        noise = rng.integers(0, cfg.vocab_size, (B, S + 1))
        mask = rng.random((B, S + 1)) < 0.1
        tokens = np.where(mask, noise, base).astype(np.int32)
        yield {"tokens": tokens, "step": step}
        step += 1


def file_stream(path: str, cfg: DataConfig, *, start_step: int = 0
                ) -> Iterator[dict]:
    """Reads a flat .npy/.bin int32 token file (memory-mapped), cutting
    deterministic (batch, seq+1) windows."""
    tokens = np.memmap(path, dtype=np.int32, mode="r")
    n = len(tokens)
    B, S = cfg.batch_size, cfg.seq_len
    step = start_step
    while True:
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, n - (S + 1), size=(B,))
        batch = np.stack([tokens[s:s + S + 1] for s in starts])
        yield {"tokens": batch.astype(np.int32), "step": step}
        step += 1


def prefetched(source: Iterator[dict], depth: int = 4,
               sharding: Any | None = None) -> HostFIFO:
    """Wrap a source in the bounded prefetch FIFO; optionally device_put
    with a NamedSharding on the producer thread (H2D overlap)."""

    def transform(item: dict) -> dict:
        arr = item["tokens"]
        if sharding is not None:
            arr = jax.device_put(arr, sharding)
        else:
            arr = jnp.asarray(arr)
        return {"tokens": arr, "step": item["step"]}

    return HostFIFO(source, depth=depth, transform=transform)

"""The chunk-graph executor: sharded trace resolution on a process pool.

The streaming resolver of :mod:`repro.core.simulator` visits a kernel's
iteration range chunk by chunk with *carried* state — the on-PL cache's
replacement state and each memory model's RNG draw position thread
serially through the chunks, so one core does all the work while the
rest idle behind its memory bandwidth.  This module breaks that chain
into a **chunk graph** whose expensive nodes are independent:

* **Phase A+B (parallel, fused)** — each chunk replays **once** from
  an empty cache
  (:meth:`~repro.core.simulator._SharedResolver.chunk_effects_fused`),
  producing both its *own* cache effect — the per-set "last N distinct
  lines" recency stacks, an associative monoid needing no incoming
  state — and its hit flags up to a small boundary-ambiguity table
  (the chunk's first ``ways`` first-touches per set, the only verdicts
  an incoming state can change).  Earlier revisions ran phases A and B
  as two full replays, which made 2-way sharding an honest slowdown
  (0.19× recorded in BENCH_sim.json); the fused pass does the work
  exactly once.  Effects are additionally persisted as rescache
  *effect records* (``<key>.eNNNNN.npz``) so a re-shard composes
  stored effects instead of waiting for phase-A messages at all.
* **Compose (master, cheap)** — a serial scan over the tiny per-chunk
  effect snapshots (:func:`~repro.core.simulator.compose_stacks`) —
  stored effect records when present, phase-A messages otherwise —
  yields every chunk's exact *incoming* cache state.
* **Finalize (parallel, tiny)** — each worker patches its chunk's
  ambiguous verdicts against the incoming state
  (:meth:`~repro.core.simulator._SharedResolver.finalize_replay`)
  and installs the composed outgoing stacks — bit-identical to a full
  warm replay, at the cost of a few hundred boundary lookups.
* **Phase C (parallel)** — backing-store draws.  The draw stream is
  position-exact (one PCG64 double per draw), so the master turns the
  per-chunk miss counts into per-chunk draw *offsets* and each worker
  fast-forwards a fresh seeded RNG with ``advance`` — draw-for-draw
  identical to the streaming pass.  The per-op latency matrices are
  committed to the rescache as ordinary v3 chunk records (or handed
  back inline when the artifact is above the size cap).
* **Fold + solve (master, overlapped)** — the master consumes chunks in
  order, folds them into per-stage arrays, and runs every (memory model
  × FIFO depth) lane's wavefront solve with the depth-incremental warm
  start — concurrently with the workers resolving ahead.

The result is bit-identical to the streaming engine (same canonical
access order, same replacement decisions, same draw stream — asserted
access-for-access in tests); only the wall clock changes.  Served and
resumed prefixes compose with sharding: chunks below the store's resume
point never reach the pool.

Workers receive the stage list via ``cloudpickle`` (the paper kernels'
window generators are closures, which plain pickle rejects); when
``cloudpickle`` is unavailable or the payload will not serialize, the
caller transparently falls back to the streaming path.
"""

from __future__ import annotations

import multiprocessing
import os
import queue
import time
import traceback
from collections import deque
from typing import Mapping, Sequence

import numpy as np

#: Outstanding chunks per worker before the master stops dispatching
#: (bounds queue memory: at most ``workers * _WINDOW`` unconsumed
#: per-op matrices are in flight).
_WINDOW = 2

#: Chunk re-dispatches tolerated per run after worker deaths (OOM
#: kills, segfaults) before the run hard-fails instead of looping —
#: every retry is counted in ``rescache.census()["worker_retries"]``.
RETRY_BUDGET = 3

#: Completed pool executions in this process — lets tests assert the
#: sharded path actually engaged rather than silently falling back to
#: the streaming engine (missing cloudpickle, too few chunks, …).
_POOL_RUNS = 0


def default_workers(*, cpus: int | None = None, jobs: int = 1,
                    explicit: int | None = None,
                    full: bool = True) -> int:
    """The ``--workers`` default heuristic, shared by every benchmark
    driver: the fused effect+replay pass made sharding break even on
    2 cores (``worker_scaling`` in BENCH_sim.json; it recorded 0.19×
    when phases A and B were two separate replays), but process spawn
    and payload pickling still cost seconds that only amortize when
    several cores actually run concurrently — so auto-sharding keeps
    falling back to the streaming engine below 4 cores unless the user
    passed an explicit count.  ``jobs`` is the concurrent task-pool
    width the workers share the cores with."""
    if explicit is not None:
        return max(1, explicit)
    if cpus is None:
        cpus = multiprocessing.cpu_count()
    if not full or cpus < 4:
        return 1
    return max(2, cpus // max(1, jobs))


def _compose_state(older, newer):
    """Compose two per-geometry state maps (``None`` = empty cache)."""
    from .simulator import compose_stacks
    if older is None:
        return newer
    out = {}
    for geo, (stk_new, mt_new) in newer.items():
        old = older.get(geo)
        if old is None:
            out[geo] = (stk_new, mt_new)
        else:
            out[geo] = (compose_stacks(old[0], stk_new),
                        max(old[1], mt_new))
    return out


def _worker_main(payload_bytes: bytes, task_q, result_q) -> None:
    """One pool worker: processes its chunks' A/B/C phases, exchanging
    tiny state messages with the master (see the module docstring)."""
    current = -1
    try:
        import cloudpickle
        p = cloudpickle.loads(payload_bytes)
        from . import engine as _eng
        from . import rescache as _rc
        from ..serve import faults as _faults
        from .simulator import _SharedResolver, _lat_itemsize
        _rc.configure(**p["rescache_cfg"])
        _rc.CHUNK_ITERS = p["C"]
        if p.get("engine"):  # master's backend, not the worker's env
            _eng.select(p["engine"])
        resolver = _SharedResolver(p["stages"], p["mems"], p["seed"],
                                   capture=p["capture"])
        writers = {mn: _rc.ChunkWriter(
            key, resolver.K, p["n_iters"],
            itemsize=_lat_itemsize(p["mems"][mn]))
            for mn, key in p["keys"].items() if key is not None}
        writers = {mn: w for mn, w in writers.items() if not w.dead}
        pending: deque = deque()
        mailbox: dict[tuple, tuple] = {}

        def next_msg(kind: str, k: int):
            """Wait for the master's (kind, k) reply, buffering tasks
            and replies that belong to this worker's later chunks."""
            want = (kind, k)
            while want not in mailbox:
                m = task_q.get()
                if m[0] == "task":
                    pending.append(m)
                elif m[0] == "stop":
                    return None
                else:
                    mailbox[(m[0], m[1])] = m
            return mailbox.pop(want)

        def next_task():
            if pending:
                return pending.popleft()
            while True:
                m = task_q.get()
                if m[0] in ("task", "stop"):
                    return m
                mailbox[(m[0], m[1])] = m

        while True:
            msg = next_task()
            if msg[0] == "stop":
                return
            _, k, lo, hi = msg
            current = k
            if _faults.active():  # chaos: die mid-chunk
                _faults.maybe_kill("worker_kill", chunk=k)
            # A+B fused: one empty-cache replay yields the chunk's own
            # effect AND its hit flags up to the boundary-ambiguity
            # table finalize_replay patches below — the second full
            # replay the unfused executor paid is gone
            effects, n_addrs = resolver.chunk_effects_fused(lo, hi)
            with _eng.phase("effect"):
                for mn, ekey in p.get("effect_keys", {}).items():
                    geo = resolver.cache_keys[mn]
                    if geo is not None and geo in effects:
                        _rc.put_effect(ekey, k, effects[geo], n_addrs)
            result_q.put(("effect", k, effects, n_addrs))
            # B: patch the fused verdicts against the composed
            # incoming state and install the outgoing stacks
            m = next_msg("state", k)
            if m is None:
                return
            deltas = resolver.finalize_replay(m[2])
            result_q.put(("replay", k, deltas))
            # C: position the draw streams, materialize latencies
            m = next_msg("draws", k)
            if m is None:
                return
            if _faults.active():  # chaos: straggle in the heavy phase
                _faults.maybe_sleep("straggler", chunk=k)
            for mn, cum in m[2].items():
                resolver.import_resume(mn, {}, {"draws": cum["base"]})
                geo = resolver.cache_keys[mn]
                if geo is not None:
                    resolver.caches[geo].hits = cum["hits_after"]
                    resolver.caches[geo].misses = cum["misses_after"]
            resolver.finish(lo, hi, fold=False)
            ops_payload = {}
            for mn in p["mems"]:
                w = writers.get(mn)
                if w is not None and lo // p["C"] < w.max_chunks:
                    hb = vb = None
                    if resolver.last_hits.get(mn) is not None:
                        hb = _rc.pack_flags(resolver.last_hits[mn])
                        vb = _rc.pack_flags(resolver.last_visits[mn])
                    states, cum = resolver.export_resume(mn)
                    w.add(lo // p["C"], hi - lo,
                          np.ascontiguousarray(resolver.last_ops[mn]),
                          hb, vb, states, cum)
                    ops_payload[mn] = None  # master reads the record
                else:
                    # no writer, or past the artifact's stored-prefix
                    # budget: hand the matrix back inline
                    ops_payload[mn] = _rc.shrink_ops(
                        resolver.last_ops[mn])
            cums = {mn: resolver.export_resume(mn)[1]
                    for mn in p["mems"]}
            walls = _eng.walls()
            _eng.reset_walls()
            result_q.put(("done", k, cums, ops_payload, walls))
    except Exception:  # noqa: BLE001 - forwarded to the master verbatim
        result_q.put(("error", current, traceback.format_exc()))


def simulate_dataflow_sharded(
    stages: Sequence,
    mems: Mapping[str, object],
    n_iters: int,
    *,
    fifo_depths: Sequence[int],
    freq_mhz: float,
    seed: int,
    workers: int,
    collect_stalls: bool,
    use_rescache: bool | None,
    depth_incremental: bool = True,
):
    """Grid simulation with resolution sharded over ``workers``
    processes — the entry point behind
    ``simulate_dataflow_many(..., workers=N)``.  Falls back to the
    streaming engine whenever sharding cannot help (no live resolution,
    too few chunks) or the stage list will not serialize."""
    from . import engine as _eng
    from . import rescache as _rc
    from .simulator import (SimResult, _LaneSolver, _OpFolder,
                            _ResolutionPlan, _ServeLost,
                            _dataflow_many_stream)

    mems = dict(mems)

    def _stream(rescache_override):
        try:
            return _dataflow_many_stream(
                stages, mems, n_iters, fifo_depths=fifo_depths,
                freq_mhz=freq_mhz, seed=seed,
                chunk_iters=_rc.CHUNK_ITERS,
                collect_stalls=collect_stalls,
                use_rescache=rescache_override,
                depth_incremental=depth_incremental)
        except _ServeLost:  # raced store eviction: redo cold
            if rescache_override is False:
                raise
            return _dataflow_many_stream(
                stages, mems, n_iters, fifo_depths=fifo_depths,
                freq_mhz=freq_mhz, seed=seed,
                chunk_iters=_rc.CHUNK_ITERS,
                collect_stalls=collect_stalls, use_rescache=False,
                depth_incremental=depth_incremental)

    try:
        plan = _ResolutionPlan("dataflow", stages, mems, seed, n_iters,
                               use_rescache)
    except _ServeLost:
        return _stream(False)
    C = plan.C
    n_chunks = -(-n_iters // C)
    first_live = plan.resume // C
    if not plan.live or n_chunks - first_live < 2 or workers < 2:
        return _stream(use_rescache)
    # every live cached model with a v3 key also persists its chunks'
    # cache-effect monoids as effect records (tiny, content-determined)
    # — the next shard of this artifact composes them from the store
    # and never waits on the phase-A message chain
    effect_keys = {}
    if _rc.enabled(use_rescache):
        effect_keys = {
            mn: plan.keys[mn] for mn in plan.live
            if plan.keys.get(mn) is not None
            and plan.resolver.cache_keys[mn] is not None}
    try:
        import cloudpickle
        payload = cloudpickle.dumps({
            "stages": list(stages),
            "mems": plan.live,
            "seed": seed,
            "n_iters": n_iters,
            "C": C,
            "capture": bool(plan.writers),
            "keys": {mn: plan.keys[mn] for mn in plan.writers},
            "engine": _eng.current(),
            "effect_keys": effect_keys,
            "rescache_cfg": {
                "enabled": _rc._cfg.enabled,
                "directory": _rc._dir(),
                "memory_mb": _rc._cfg.memory_mb,
                "artifact_mb": _rc._cfg.artifact_mb,
                "disk_mb": _rc._cfg.disk_mb,
            },
        })
    except Exception:  # unpicklable traces: shard is impossible
        return _stream(use_rescache)

    W = min(workers, n_chunks - first_live)
    ctx = multiprocessing.get_context("spawn")
    result_q = ctx.Queue()
    task_qs = [ctx.Queue() for _ in range(W)]
    procs = [ctx.Process(target=_worker_main,
                         args=(payload, task_qs[w], result_q),
                         daemon=True)
             for w in range(W)]
    for pr in procs:
        pr.start()

    #: chunk -> worker; seeded round-robin, rewritten when a dead
    #: worker's in-flight chunks are re-dispatched
    owner_of: dict[int, int] = {}

    def owner(k: int) -> int:
        return owner_of.setdefault(k, (k - first_live) % W)

    folder = _OpFolder(stages)
    live_cold: set[int] = set()  # live chunks, for the store census
    solvers = {(mn, d): _LaneSolver(stages, d, collect_stalls)
               for mn in mems for d in fifo_depths}
    depth_order = sorted(set(fifo_depths), reverse=True)
    resolver = plan.resolver

    def solve_chunk(k: int, ops_by_model) -> None:
        lo = k * C
        hi = min(lo + C, n_iters)
        for mn in mems:
            if mn in plan.served:
                L = plan.served[mn].chunk(lo, hi)
                _rc.note_chunks(served=1)
            elif k < first_live:
                L = plan.live_ops(mn, lo, hi)
                _rc.note_chunks(served=1)
            elif ops_by_model[mn] is not None:
                L = ops_by_model[mn]
            else:
                # refresh: the worker just (re)wrote this record; a
                # stale partial tail may still sit in the master's LRU
                rec = _rc.get_chunk(plan.keys[mn], k, refresh=True)
                if rec is None:
                    raise _ServeLost(
                        f"sharded record {plan.keys[mn]}.c{k} vanished")
                L = rec.ops
            if L.dtype != np.int32:  # widen shrunk records for the fold
                L = L.astype(np.int32)
            res = folder.fold(mems[mn], lo, hi, L)
            if mn not in plan.served and k >= first_live:
                live_cold.add(k)
            warm = None
            for d in depth_order:
                warm = solvers[(mn, d)].solve_chunk(
                    res, warm=warm if depth_incremental else None)

    # master bookkeeping: effect composition, draw prefixes, dispatch
    state_at: dict[int, dict | None] = {
        first_live: ({geo: sim.export_stacks()
                      for geo, sim in resolver.caches.items()}
                     if plan.resume > 0 else None)}
    effects: dict[int, dict] = {}
    n_addrs: dict[int, int] = {}
    # stored effect records seed the state chain ahead of the workers:
    # walk forward from the resume point while every geometry's effect
    # is on disk, so pump_sends never waits on a phase-A message for a
    # chunk this store has seen before (snapshots are ~KB each and the
    # send-side prune below keeps the live set O(workers))
    if effect_keys and resolver.caches:
        need: dict[tuple, str] = {}
        for mn, ekey in effect_keys.items():
            need.setdefault(resolver.cache_keys[mn], ekey)
        if set(need) == set(resolver.caches):
            k = first_live
            while k < n_chunks:
                recs = {geo: _rc.get_effect(ekey, k)
                        for geo, ekey in need.items()}
                if any(r is None for r in recs.values()):
                    break
                state_at[k + 1] = _compose_state(
                    state_at[k],
                    {geo: (r[0], r[1]) for geo, r in recs.items()})
                n_addrs[k] = next(iter(recs.values()))[2]
                k += 1
    deltas: dict[int, dict] = {}
    done: dict[int, dict] = {}
    cum_draws = dict(resolver.draws)
    geo_cum = {geo: (sim.hits, sim.misses)
               for geo, sim in resolver.caches.items()}
    final_cums: dict[str, dict] = {}
    #: master-side replay log for worker-death recovery: every state /
    #: draws message stays addressable until its chunk's ``done``
    #: arrives, so a respawned worker can be fed the exact same
    #: messages (bit-identical replay; bounded by ``W * _WINDOW``)
    sent_state: dict[int, dict] = {}
    sent_draws: dict[int, dict] = {}
    retries = 0

    # speculative straggler re-dispatch (the StragglerPolicy
    # bounded-staleness rule applied to chunk dispatch): a phase-C
    # chunk whose wall exceeds the SpeculationPolicy threshold is
    # replayed in full (task + state + draws) on a second live worker.
    # The master's fold stalls at the straggling chunk while its peers
    # drain to idle, so the duplicate lands on an idle worker;
    # resolution is deterministic, so the first "done" wins and the
    # loser's messages die on the ordinary duplicate guards below.
    spec_after = float(os.environ.get("REPRO_SPECULATE_AFTER_S",
                                      "30") or 0)
    spec_policy = None
    if W > 1 and spec_after > 0:
        from ..runtime.fault_tolerance import SpeculationPolicy
        spec_policy = SpeculationPolicy(min_wait_s=spec_after,
                                        max_inflight=max(1, W // 2))
    draws_t: dict[int, float] = {}   # chunk -> phase-C dispatch time
    spec_owner: dict[int, int] = {}  # chunk -> speculative worker
    # the idle-poll interval is also the straggler-detection latency:
    # shrink it when the speculation threshold is below the default
    poll_s = 5.0 if spec_policy is None else \
        min(5.0, max(0.25, spec_policy.min_wait_s / 2))

    dispatched = first_live
    state_sent = first_live
    draws_sent = first_live
    solved = 0
    failure: str | None = None
    try:
        def dispatch_upto(limit: int) -> None:
            nonlocal dispatched
            while dispatched < min(limit, n_chunks):
                k = dispatched
                task_qs[owner(k)].put(
                    ("task", k, k * C, min((k + 1) * C, n_iters)))
                dispatched += 1

        def pump_sends() -> None:
            nonlocal state_sent, draws_sent
            while state_sent < dispatched and state_sent in state_at:
                k = state_sent
                sent_state[k] = state_at[k] or {}
                task_qs[owner(k)].put(("state", k, sent_state[k]))
                state_sent += 1
            while draws_sent < dispatched and draws_sent in deltas:
                k = draws_sent
                msg = {}
                for mn, mem in plan.live.items():
                    geo = resolver.cache_keys[mn]
                    entry = {"base": cum_draws[mn]}
                    if mem.backing_hit_rate > 0.0:
                        # draws consumed this chunk: every backing trip
                        # (misses + write-around stores) for cached
                        # models, every participating access otherwise
                        cum_draws[mn] += deltas[k][geo][2] \
                            if geo is not None else n_addrs[k]
                    if geo is not None:
                        h, m = geo_cum[geo]
                        entry["hits_after"] = h + deltas[k][geo][0]
                        entry["misses_after"] = m + deltas[k][geo][1]
                    msg[mn] = entry
                for geo, d in deltas[k].items():
                    h, m = geo_cum[geo]
                    geo_cum[geo] = (h + d[0], m + d[1])
                sent_draws[k] = msg
                task_qs[owner(k)].put(("draws", k, msg))
                draws_t[k] = time.monotonic()
                del deltas[k]  # fully consumed: keep the master O(W)
                n_addrs.pop(k, None)
                effects.pop(k, None)  # duplicate after a retry replay
                draws_sent += 1
            # a state snapshot is dead once it was sent and composed
            # into its successor — prune so a thousand-chunk run keeps
            # O(workers) snapshots, not O(chunks)
            for j in [j for j in state_at
                      if j < state_sent and j + 1 in state_at]:
                del state_at[j]

        dispatch_upto(first_live + W * _WINDOW)
        pump_sends()
        # chunks below the resume point solve immediately from records
        while solved < first_live:
            solve_chunk(solved, None)
            solved += 1
        while solved < n_chunks:
            if solved in done:
                cums, ops = done.pop(solved)
                final_cums.update(cums)
                solve_chunk(solved, ops)
                solved += 1
                dispatch_upto(solved + W * _WINDOW)
                pump_sends()
                continue
            try:
                msg = result_q.get(timeout=poll_s)
            except queue.Empty:
                if spec_policy is not None:
                    now = time.monotonic()
                    for k in sorted(draws_t):
                        if (k in spec_owner or k in done
                                or len(spec_owner)
                                >= spec_policy.max_inflight
                                or not spec_policy.overdue(
                                    now - draws_t[k])):
                            continue
                        alts = [w for w in range(W)
                                if w != owner_of.get(k)
                                and procs[w].is_alive()]
                        if not alts:
                            continue
                        w2 = alts[k % len(alts)]
                        task_qs[w2].put(
                            ("task", k, k * C,
                             min((k + 1) * C, n_iters)))
                        task_qs[w2].put(("state", k, sent_state[k]))
                        task_qs[w2].put(("draws", k, sent_draws[k]))
                        spec_owner[k] = w2
                        spec_policy.issued += 1
                        _rc.note_speculation()
                dead = [w for w, pr in enumerate(procs)
                        if not pr.is_alive()]
                if not dead:
                    continue
                # died without posting (OOM kill, segfault): respawn
                # the slot and replay its in-flight chunks' messages
                # verbatim — resolution is deterministic, so the retry
                # is bit-identical — under a bounded budget
                for k in [k for k, w in spec_owner.items()
                          if w in dead]:
                    spec_owner.pop(k)  # spec copy lost with its worker
                redo = [k for k in range(solved, dispatched)
                        if k not in done and owner_of.get(k) in dead]
                retries += len(redo)
                _rc.note_worker_retries(len(redo))
                if retries > RETRY_BUDGET:
                    failure = (
                        f"worker(s) {dead} exited with code(s) "
                        f"{[procs[w].exitcode for w in dead]}; retry "
                        f"budget exhausted ({retries} > {RETRY_BUDGET})")
                    break
                for w in dead:
                    task_qs[w] = ctx.Queue()
                    procs[w] = ctx.Process(
                        target=_worker_main,
                        args=(payload, task_qs[w], result_q),
                        daemon=True)
                    procs[w].start()
                for k in sorted(redo):
                    w = owner_of[k]
                    task_qs[w].put(
                        ("task", k, k * C, min((k + 1) * C, n_iters)))
                    if k < state_sent:
                        task_qs[w].put(("state", k, sent_state[k]))
                    if k < draws_sent:
                        task_qs[w].put(("draws", k, sent_draws[k]))
                continue
            kind = msg[0]
            if kind == "error":
                failure = msg[2]
                break
            if kind == "effect":
                _, k, eff, na = msg
                if k + 1 in state_at or k < draws_sent:
                    continue  # duplicate from a retried chunk
                effects[k] = eff
                n_addrs[k] = na
                while (k + 1 not in state_at) and k in state_at \
                        and k in effects:
                    state_at[k + 1] = _compose_state(state_at[k],
                                                     effects.pop(k))
                    k += 1
            elif kind == "replay":
                if msg[1] >= draws_sent:  # else: retry duplicate
                    deltas[msg[1]] = msg[2]
            elif kind == "done":
                t0 = draws_t.pop(msg[1], None)
                if spec_policy is not None:
                    if msg[1] in spec_owner:
                        spec_policy.wins += 1  # a duplicate was live
                    if t0 is not None:
                        spec_policy.observe(time.monotonic() - t0)
                spec_owner.pop(msg[1], None)
                if msg[1] >= solved:
                    if msg[1] not in done:  # not a speculative dup
                        _eng.merge_walls(msg[4])
                    done[msg[1]] = (msg[2], msg[3])
                    sent_state.pop(msg[1], None)
                    sent_draws.pop(msg[1], None)
            pump_sends()
        if failure is not None:
            raise RuntimeError(
                f"chunk-graph worker failed:\n{failure}")
    except _ServeLost:
        for q in task_qs:
            q.put(("stop",))
        for pr in procs:
            pr.terminate()
        return _stream(False)
    finally:
        for q in task_qs:
            try:
                q.put(("stop",))
            except Exception:
                pass
        for pr in procs:
            pr.join(timeout=5)
            if pr.is_alive():
                pr.terminate()

    global _POOL_RUNS
    _POOL_RUNS += 1
    _rc.note_chunks(cold=len(live_cold))
    out: dict[tuple[str, int], SimResult] = {}
    for (mn, d), solver in solvers.items():
        if mn in plan.served:
            ch, cm = plan.served[mn].stats_upto(n_iters)
        else:
            cum = final_cums.get(mn, {})
            ch, cm = int(cum.get("hits", 0)), int(cum.get("misses", 0))
        out[(mn, d)] = SimResult("dataflow", solver.last_finish, n_iters,
                                 freq_mhz, solver.stall, ch, cm)
    return out
"""Memoized trace resolution: the content-addressed ``ResolvedTrace`` store.

Resolving an address trace against a memory model — cache replay,
backing-store draws, folding into per-stage ``(c, lat_add)`` arrays — is
the expensive half of the cycle simulator, and it is *identical* across
every sweep cell that shares a ``(trace, memory model, seed)`` triple:
FIFO depths, chunk sizes, and host processes only change the cheap
wavefront solve.  This module caches that resolution product:

* **in process** — a byte-capped LRU of :class:`ResolvedTrace` artifacts,
  shared by every simulation in the interpreter (``paper_fig5``,
  ``sweep``, ``Compiled.sweep`` cells alike);
* **on disk** — an atomic store under ``experiments/.rescache/`` (or
  ``$REPRO_RESCACHE_DIR``) so spawn-based process pools and repeated
  benchmark runs share work; corrupt or concurrent writes degrade to a
  cache miss, never an error.

The cache key is a blake2b digest of

* the **trace fingerprints** — full content for materialized arrays up
  to :data:`FULL_HASH_MAX` addresses, and a deterministic sample of
  windows plus the length for window-generated traces (``gen`` must be
  pure in ``(lo, hi)``, which the :class:`~repro.core.simulator.MemAccess`
  contract already requires);
* the **op signature** — the iteration-major stream of per-op
  ``(fingerprint, is_store, serialized?)`` triples, with *no stage
  grouping*: two partitions of one kernel that merely regroup the same
  memory ops (the DSE explorer's merge/split candidates) produce the
  same key and share one artifact.  Stage *latency* and *II* are
  deliberately excluded: they shift the solver, never the resolved
  per-access latencies;
* the **memory model**, restricted to the fields that reach the
  resolved latencies: port/DRAM latencies, backing hit rate, cache
  geometry including ``write_allocate``, and — through the burst
  masks — ``line_bytes``.  Fold-only fields (``words_per_cycle``,
  ``max_outstanding``, and — for the dataflow engine —
  ``posted_writes``) are excluded: sweep lanes that only vary the port
  knobs share one artifact.  The model's *name* is excluded too;
* the **seed** and **iteration count**.  The chunk size is excluded —
  resolution is chunk-invariant (asserted by the streaming tests).

The stored artifact is correspondingly **per-op**: the ``(n_iters, K)``
matrix of resolved per-access latencies (zero where an op issued no
request that iteration — invalid or burst-continuation slots).  Serving
re-derives windows/burst masks from the traces (cheap, stateless) and
folds the matrix into each consumer's per-stage ``(c, lat_add)`` arrays
(:class:`repro.core.simulator._OpFolder`), so one artifact serves every
stage grouping, chunk size, and fold-only model variant.  v1 per-stage
artifacts are unreadable under the v2 keys and age out of the store.

Results served from the cache are bit-identical to a fresh resolution;
disable with ``REPRO_RESCACHE=0``, ``configure(enabled=False)``, or the
benchmarks' ``--no-rescache`` flag.  Artifacts whose raw size exceeds
:func:`configure`'s ``artifact_mb`` (Floyd–Warshall's 10⁹-iteration
grid) are never stored — those runs still share resolution *within* a
process through :func:`~repro.core.simulator.simulate_dataflow_many`'s
lanes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from collections import OrderedDict
from typing import Any, Sequence
from zipfile import BadZipFile as _BadZipFile

import numpy as np

from .simulator import MemAccess, MemoryModel, SimStage

#: Materialized traces up to this many addresses are fingerprinted by
#: full content; longer or generated traces by deterministic sampling.
FULL_HASH_MAX = 1 << 22

#: Number × size of sampled windows for long/generated traces.
SAMPLE_WINDOWS = 16
SAMPLE_LEN = 4096

_KEY_VERSION = "rescache-v2"


@dataclasses.dataclass
class _Config:
    enabled: bool = os.environ.get("REPRO_RESCACHE", "1") != "0"
    directory: str | None = os.environ.get("REPRO_RESCACHE_DIR")
    memory_mb: int = int(os.environ.get("REPRO_RESCACHE_MEM_MB", "256"))
    artifact_mb: int = int(os.environ.get("REPRO_RESCACHE_ART_MB", "256"))
    disk_mb: int = int(os.environ.get("REPRO_RESCACHE_DISK_MB", "2048"))


_cfg = _Config()
_mem: "OrderedDict[str, ResolvedTrace]" = OrderedDict()
_mem_bytes = 0
_summaries: "OrderedDict[str, dict]" = OrderedDict()
_stats = {"mem_hits": 0, "disk_hits": 0, "misses": 0, "stores": 0,
          "too_large": 0, "disk_errors": 0}


def configure(*, enabled: bool | None = None, directory: str | None = None,
              memory_mb: int | None = None, artifact_mb: int | None = None,
              disk_mb: int | None = None) -> None:
    """Adjust the cache at runtime (tests, benchmark flags)."""
    if enabled is not None:
        _cfg.enabled = enabled
    if directory is not None:
        _cfg.directory = directory
    if memory_mb is not None:
        _cfg.memory_mb = memory_mb
    if artifact_mb is not None:
        _cfg.artifact_mb = artifact_mb
    if disk_mb is not None:
        _cfg.disk_mb = disk_mb


def enabled(override: bool | None = None) -> bool:
    return _cfg.enabled if override is None else override


def stats() -> dict[str, int]:
    return dict(_stats, memory_bytes=_mem_bytes, entries=len(_mem))


def clear(*, disk: bool = False) -> None:
    """Drop the in-process cache (and optionally the disk store)."""
    global _mem_bytes
    _mem.clear()
    _summaries.clear()
    _mem_bytes = 0
    for k in _stats:
        _stats[k] = 0
    if disk:
        d = _dir()
        if d and os.path.isdir(d):
            for f in os.listdir(d):
                if f.endswith((".npz", ".json")):
                    try:
                        os.unlink(os.path.join(d, f))
                    except OSError:
                        pass


def evict(key: str) -> None:
    """Drop one artifact (or summary) from the in-process LRU and the
    disk store.  Benchmark meters use this to keep cold-timing probes
    cold across runs; missing keys are a no-op."""
    global _mem_bytes
    art = _mem.pop(key, None)
    if art is not None:
        _mem_bytes -= art.nbytes
    _summaries.pop(key, None)
    d = _dir()
    if d:
        for suffix in (".npz", ".json"):
            try:
                os.unlink(os.path.join(d, key + suffix))
            except OSError:
                pass


def _dir() -> str | None:
    if _cfg.directory:
        return _cfg.directory
    # default: next to the benchmark artifacts when run from a repo,
    # else a per-user cache directory
    if os.path.isdir("experiments"):
        return os.path.join("experiments", ".rescache")
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro-rescache")


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------

def trace_fingerprint(acc: MemAccess) -> str:
    """Content digest of one address trace (cached on the object).

    Materialized traces up to :data:`FULL_HASH_MAX` addresses hash their
    full contents; longer or window-generated traces hash a deterministic
    spread of :data:`SAMPLE_WINDOWS` windows plus the length (``gen``
    must be pure in its arguments — already part of the ``MemAccess``
    contract, since the simulators re-window traces freely)."""
    fp = acc.__dict__.get("_fingerprint")
    if fp is not None:
        return fp
    h = hashlib.blake2b(digest_size=16)
    n = len(acc)
    h.update(str(n).encode())
    if acc.addrs is not None and n <= FULL_HASH_MAX:
        h.update(b"full")
        h.update(np.ascontiguousarray(acc.addrs).tobytes())
    else:
        h.update(b"sampled")
        if acc.gen is not None:
            # fold in the generator itself — bytecode plus any scalar
            # closure parameters — so two generators that happen to agree
            # on the sampled windows still get distinct keys unless they
            # are literally the same code with the same parameters
            code = getattr(acc.gen, "__code__", None)
            if code is not None:
                h.update(code.co_code)
                h.update(repr(code.co_consts).encode())
            for cell in getattr(acc.gen, "__closure__", None) or ():
                try:
                    v = cell.cell_contents
                except ValueError:
                    continue
                if isinstance(v, (int, float, str, bytes, bool)):
                    h.update(repr(v).encode())
                elif isinstance(v, np.ndarray) and v.size <= 4096:
                    h.update(v.tobytes())
        step = max(1, (n - SAMPLE_LEN) // max(1, SAMPLE_WINDOWS - 1))
        for i in range(SAMPLE_WINDOWS):
            lo = min(i * step, max(0, n - SAMPLE_LEN))
            hi = min(n, lo + SAMPLE_LEN)
            if hi <= lo:
                break
            h.update(acc._raw_window(lo, hi).tobytes())
    fp = h.hexdigest()
    acc.__dict__["_fingerprint"] = fp
    return fp


def _cache_signature(mem: MemoryModel) -> tuple | None:
    if mem.cache is None:
        return None
    c = mem.cache
    return (c.size_bytes, c.line_bytes, c.ways, c.hit_cycles,
            c.write_allocate)


def resolution_key(kind: str, stages: Sequence[SimStage],
                   mem: MemoryModel, seed: int, n_iters: int,
                   extra: Any = None) -> str:
    """Content-addressed key for one resolution product.

    The signature is **per-op**, not per-stage (see the module
    docstring): stage grouping, latency, and II are absent, as are the
    fold-only memory-model fields.  ``kind`` selects which per-op and
    model fields matter:

    * ``"dataflow"`` — ops carry their serialized flag (a
      ``mem_in_scc`` stage's accesses never burst and serialize into
      the II); the model contributes ``line_bytes`` (burst masks) but
      not ``posted_writes`` (fold-only).
    * ``"conventional"`` — no bursts and no serialization (every valid
      access resolves), so neither flag keys; ``posted_writes`` *does*
      (posted stores never stall the static engine, changing the stored
      stall totals).
    """
    cache = _cache_signature(mem)
    if kind == "conventional":
        ops = tuple((trace_fingerprint(acc), acc.is_store)
                    for st in stages for acc in st.accesses)
        msig = (mem.port_latency, mem.dram_latency, mem.backing_hit_rate,
                mem.posted_writes, cache)
    else:
        ops = tuple((trace_fingerprint(acc), acc.is_store, st.mem_in_scc)
                    for st in stages for acc in st.accesses)
        msig = (mem.port_latency, mem.dram_latency, mem.backing_hit_rate,
                mem.line_bytes, cache)
    payload = (_KEY_VERSION, kind, ops, msig, seed, n_iters, extra)
    return hashlib.blake2b(repr(payload).encode(),
                           digest_size=16).hexdigest()


def processor_key(accesses: Sequence[MemAccess], model: Any,
                  n_iters: int) -> str:
    payload = (_KEY_VERSION, "processor",
               tuple((trace_fingerprint(a), a.is_store) for a in accesses),
               (model.l1_kb, model.l2_kb, model.l1_hit, model.l2_hit),
               n_iters)
    return hashlib.blake2b(repr(payload).encode(),
                           digest_size=16).hexdigest()


# ---------------------------------------------------------------------------
# Artifacts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResolvedTrace:
    """One memoized resolution product: the **per-op** latency matrix
    ``ops`` (``(n_iters, K)`` int32; ``ops[i, k]`` is the resolved
    latency of the kernel's ``k``-th memory op at iteration ``i``, zero
    when that op issued no request — invalid or burst-continuation
    slot) plus the cache statistics.  ``chunk(lo, hi)`` serves zero-copy
    views; consumers fold them into per-stage arrays via
    :class:`repro.core.simulator._OpFolder`, so any stage grouping and
    any chunking scheme replays bit-identically."""

    key: str
    n_iters: int
    ops: np.ndarray
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def nbytes(self) -> int:
        return self.ops.nbytes

    def chunk(self, lo: int, hi: int) -> np.ndarray:
        return self.ops[lo:hi]


class ArtifactWriter:
    """Accumulates per-op latency chunks while a live run streams, and
    commits the assembled :class:`ResolvedTrace` when the run finishes —
    unless the artifact would exceed the size cap, in which case it
    silently abandons collection (the run itself is unaffected)."""

    def __init__(self, key: str, n_ops: int, n_iters: int):
        self.key = key
        self.n_iters = n_iters
        est = n_ops * n_iters * 4  # int32 per (op, iteration)
        self.dead = est > _cfg.artifact_mb * (1 << 20)
        if self.dead:
            _stats["too_large"] += 1
        self.chunks: list[np.ndarray] = []

    def add(self, ops_chunk: np.ndarray) -> None:
        if not self.dead:
            self.chunks.append(ops_chunk)

    def finish(self, cache_hits: int, cache_misses: int) -> None:
        if self.dead or not self.chunks:
            return
        art = ResolvedTrace(self.key, self.n_iters,
                            np.concatenate(self.chunks, axis=0),
                            cache_hits, cache_misses)
        put(art)


def _touch_lru(key: str) -> None:
    _mem.move_to_end(key)


def _insert_mem(art: ResolvedTrace) -> None:
    global _mem_bytes
    cap = _cfg.memory_mb * (1 << 20)
    if art.nbytes > cap:
        return
    if art.key in _mem:
        _mem_bytes -= _mem[art.key].nbytes
        del _mem[art.key]
    _mem[art.key] = art
    _mem_bytes += art.nbytes
    while _mem_bytes > cap and _mem:
        _, old = _mem.popitem(last=False)
        _mem_bytes -= old.nbytes


def get(key: str) -> ResolvedTrace | None:
    """Look an artifact up in the in-process LRU, then the disk store."""
    art = _mem.get(key)
    if art is not None:
        _stats["mem_hits"] += 1
        _touch_lru(key)
        return art
    d = _dir()
    path = os.path.join(d, key + ".npz") if d else None
    if path and os.path.exists(path):
        try:
            with np.load(path) as z:
                meta = z["meta"]
                art = ResolvedTrace(key, int(meta[2]), z["ops"],
                                    int(meta[0]), int(meta[1]))
            os.utime(path)  # LRU recency for the disk evictor
            _stats["disk_hits"] += 1
            _insert_mem(art)
            return art
        except (OSError, KeyError, ValueError, _BadZipFile):
            _stats["disk_errors"] += 1
    _stats["misses"] += 1
    return None


def put(art: ResolvedTrace) -> None:
    """Commit an artifact to the in-process LRU and the disk store."""
    if art.nbytes > _cfg.artifact_mb * (1 << 20):
        _stats["too_large"] += 1
        return
    _stats["stores"] += 1
    _insert_mem(art)
    d = _dir()
    if not d:
        return
    try:
        os.makedirs(d, exist_ok=True)
        payload = {"meta": np.array(
            [art.cache_hits, art.cache_misses, art.n_iters,
             art.ops.shape[1]],
            dtype=np.int64), "ops": art.ops}
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
            os.replace(tmp, os.path.join(d, art.key + ".npz"))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        _evict_disk(d)
    except OSError:
        _stats["disk_errors"] += 1


def _evict_disk(d: str) -> None:
    """Keep the store under the disk cap, oldest access first."""
    cap = _cfg.disk_mb * (1 << 20)
    try:
        files = [(os.path.join(d, f)) for f in os.listdir(d)
                 if f.endswith(".npz")]
        sizes = {f: os.path.getsize(f) for f in files}
        total = sum(sizes.values())
        if total <= cap:
            return
        for f in sorted(files, key=os.path.getmtime):
            try:
                os.unlink(f)
                total -= sizes[f]
            except OSError:
                pass
            if total <= cap:
                break
    except OSError:
        pass


# ---------------------------------------------------------------------------
# Tiny summary artifacts (conventional stalls, processor hit counts)
# ---------------------------------------------------------------------------

def get_summary(key: str) -> dict | None:
    s = _summaries.get(key)
    if s is not None:
        _stats["mem_hits"] += 1
        return s
    d = _dir()
    path = os.path.join(d, key + ".json") if d else None
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                s = json.load(f)
            _stats["disk_hits"] += 1
            _summaries[key] = s
            return s
        except (OSError, ValueError):
            _stats["disk_errors"] += 1
    _stats["misses"] += 1
    return None


def put_summary(key: str, summary: dict) -> None:
    _stats["stores"] += 1
    _summaries[key] = summary
    while len(_summaries) > 4096:
        _summaries.popitem(last=False)
    d = _dir()
    if not d:
        return
    try:
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(summary, f)
            os.replace(tmp, os.path.join(d, key + ".json"))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
    except OSError:
        _stats["disk_errors"] += 1

"""Memoized trace resolution: the chunk-granular, prefix-serving store.

Resolving an address trace against a memory model — cache replay,
backing-store draws, folding into per-stage ``(c, lat_add)`` arrays — is
the expensive half of the cycle simulator, and it is *identical* across
every sweep cell that shares a ``(trace, memory model, seed)`` triple:
FIFO depths, chunk sizes, and host processes only change the cheap
wavefront solve.  This module caches that resolution product:

* **in process** — a byte-capped LRU of per-chunk records, shared by
  every simulation in the interpreter (``paper_fig5``, ``sweep``,
  ``Compiled.sweep`` cells alike);
* **on disk** — an atomic store under ``experiments/.rescache/`` (or
  ``$REPRO_RESCACHE_DIR``) so spawn-based process pools and repeated
  benchmark runs share work; corrupt or concurrent writes degrade to a
  cache miss, never an error.

The cache key (**v3**) is a blake2b digest of

* the **trace fingerprints** — full content for materialized arrays up
  to :data:`FULL_HASH_MAX` addresses, and a deterministic sample of
  windows plus the length for window-generated traces (``gen`` must be
  pure in ``(lo, hi)``, which the :class:`~repro.core.simulator.MemAccess`
  contract already requires);
* the **op signature** — the iteration-major stream of per-op
  ``(fingerprint, is_store, serialized?)`` triples, with *no stage
  grouping*: two partitions of one kernel that merely regroup the same
  memory ops (the DSE explorer's merge/split candidates) produce the
  same key and share one artifact.  Stage *latency* and *II* are
  deliberately excluded: they shift the solver, never the resolved
  per-access latencies;
* the **memory model**, restricted to the fields that reach the
  resolved latencies: port/DRAM latencies, backing hit rate, cache
  geometry including ``write_allocate``, and — through the burst
  masks — ``line_bytes``.  Fold-only fields (``words_per_cycle``,
  ``max_outstanding``, ``store_buffer_depth``, and ``posted_writes``)
  are excluded: sweep lanes that only vary the port knobs share one
  artifact.  Since v3 the conventional engine's ``posted_writes`` and
  static-overlap credit are fold-only too (its artifact stores raw
  per-access latencies, not pre-folded stall sums).  The model's *name*
  is excluded;
* the **seed**.  Unlike v2, the **iteration count is NOT part of the
  key**: resolution is forward-causal (the latency of access *i*
  depends only on accesses before it), so an artifact resolved for N
  iterations is byte-identical on its first M rows to one resolved for
  M < N.  The chunk size is likewise excluded — resolution is
  chunk-invariant (asserted by the streaming tests).

The stored artifact is a **sequence of chunk records** at the canonical
granularity :data:`CHUNK_ITERS`, one ``<key>.c<idx>.npz`` file each:

* ``ops`` — the chunk's per-op resolved latency matrix
  (``(n, K)`` int32; zero where an op issued no request — invalid or
  burst-continuation slots).  The processor artifact stores a per-op
  *hit-level* matrix instead (int8: 0 none, 1 L1, 2 L2, 3 DRAM).
* ``hitbits`` — the packed on-PL-cache hit flags (models with a cache),
  so cache statistics for *any* prefix are exact without re-deriving
  them from latencies.
* the **resume state** at the chunk's end — the cache's per-set recency
  stacks and the cumulative RNG draw count — so an interrupted run
  resumes from its last completed chunk, bit-identically.
* cumulative hit/miss counters at the chunk boundary.

This layout is what makes v3 **prefix-serving**: a run of M iterations
reads chunk records ``0 .. ceil(M/CHUNK_ITERS)-1`` and trims the last,
regardless of the N the artifact was originally resolved for; a run of
N' > N serves the stored prefix and resolves only the missing chunks,
seeded from the last record's resume state.

**v2→v3 invalidation:** v2 stored one whole-run ``<key>.npz`` per
``(…, n_iters)`` key plus ``<key>.json`` stall/hit summaries for the
conventional/processor engines.  v3 keys do not collide with v2 keys
(the version string is part of the digest) and v2 payloads do not parse
as v3 chunk records (a failed load degrades to a cache miss), so v2
files are simply dead weight: run :func:`gc` — or let the byte-cap
evictor age them out — to reclaim the space.  The first post-upgrade
run of each configuration resolves cold and stores v3 chunks.

Results served from the cache are bit-identical to a fresh resolution;
disable with ``REPRO_RESCACHE=0``, ``configure(enabled=False)``, or the
benchmarks' ``--no-rescache`` flag.  An artifact whose full size would
exceed :func:`configure`'s ``artifact_mb`` (Floyd–Warshall's
10⁹-iteration grid) stores only its first ``artifact_mb``-worth of
chunks: short reruns still prefix-serve and long reruns resume from the
stored prefix's end, while the tail beyond it shares resolution
*within* a run through
:func:`~repro.core.simulator.simulate_dataflow_many`'s lanes and
across cores through the chunk-graph executor.
"""

from __future__ import annotations

import dataclasses
import glob as _glob
import hashlib
import os
import re
import tempfile
from collections import OrderedDict
from typing import Any, Sequence
from zipfile import BadZipFile as _BadZipFile

import numpy as np

from .simulator import MemAccess, MemoryModel, SimStage

#: Materialized traces up to this many addresses are fingerprinted by
#: full content; longer or generated traces by deterministic sampling.
FULL_HASH_MAX = 1 << 22

#: Number × size of sampled windows for long/generated traces.
SAMPLE_WINDOWS = 16
SAMPLE_LEN = 4096

#: Canonical chunk granularity of stored artifacts (iterations).  Every
#: producer emits records on these boundaries no matter how the run
#: itself was chunked, so artifacts written at any ``chunk_iters`` (and
#: by any worker of the sharded executor) tile identically.  The env
#: override exists for cross-process harnesses (the serving smoke test
#: shrinks the grid so a 20k-iteration run spans many chunks); every
#: process sharing one store must agree on the value.
CHUNK_ITERS = int(os.environ.get("REPRO_CHUNK_ITERS", str(1 << 20)))

_KEY_VERSION = "rescache-v3"

#: v3 chunk-record file names; anything else in the store directory is
#: an orphan from an earlier key version (see :func:`gc`).
_CHUNK_RE = re.compile(r"^[0-9a-f]{32}\.c\d{5,}\.npz$")

#: v3 effect-record file names — one chunk's cache-effect monoid (the
#: per-set recency stacks from an empty-cache replay, see
#: ``BatchedCacheSim.export_stacks``) keyed alongside the artifact's
#: chunk records.  A sharded master composes stored effects instead of
#: waiting for phase-A messages, so a re-shard (or daemon respawn)
#: skips the effect chain entirely (see ``docs/engine.md``).
_EFFECT_RE = re.compile(r"^[0-9a-f]{32}\.e\d{5,}\.npz$")


@dataclasses.dataclass
class _Config:
    enabled: bool = os.environ.get("REPRO_RESCACHE", "1") != "0"
    directory: str | None = os.environ.get("REPRO_RESCACHE_DIR")
    memory_mb: int = int(os.environ.get("REPRO_RESCACHE_MEM_MB", "256"))
    artifact_mb: int = int(os.environ.get("REPRO_RESCACHE_ART_MB", "256"))
    # sized so one full Fig. 5 regeneration (all kernels × engines ×
    # memory models, Floyd–Warshall capped to its stored prefix) fits
    # without the evictor cannibalizing earlier kernels' records
    disk_mb: int = int(os.environ.get("REPRO_RESCACHE_DISK_MB", "4096"))
    #: hard byte cap on the on-disk store; overrides ``disk_mb`` when set
    max_bytes: int | None = (
        int(os.environ["REPRO_RESCACHE_MAX_BYTES"])
        if os.environ.get("REPRO_RESCACHE_MAX_BYTES") else None)


_cfg = _Config()
_mem: "OrderedDict[tuple[str, int], ChunkRecord]" = OrderedDict()
_mem_bytes = 0
_evict_accum = 0  # bytes stored since the last disk-evictor sweep
_stats = {"mem_hits": 0, "disk_hits": 0, "misses": 0, "stores": 0,
          "too_large": 0, "disk_errors": 0,
          #: chunks resolved live (cold) vs served from the store —
          #: the store census the benchmarks and acceptance tests read
          "cold_chunks": 0, "served_chunks": 0,
          #: chunk re-dispatches after a pool worker died mid-chunk
          #: (the chunk-graph executor and the resolution daemon both
          #: respawn and retry under a bounded budget)
          "worker_retries": 0,
          #: records failing their blake2b checksum or unreadable as a
          #: zip — moved aside (``.quarantine``) and re-resolved, never
          #: served (see ``get_chunk``)
          "quarantined": 0,
          #: served runs that lost their daemon mid-stream and fell
          #: back to library mode, resuming from the committed prefix
          "serve_failovers": 0,
          #: speculative duplicate dispatches of straggling chunks
          #: (first commit wins; the loser is discarded by the
          #: executors' duplicate guards)
          "speculated": 0,
          #: cache-effect monoid records written / served (the sharded
          #: master composes served effects instead of waiting for
          #: phase-A worker messages — see ``put_effect``)
          "effect_stores": 0, "effect_hits": 0}


def configure(*, enabled: bool | None = None, directory: str | None = None,
              memory_mb: int | None = None, artifact_mb: int | None = None,
              disk_mb: int | None = None,
              max_bytes: int | None = None) -> None:
    """Adjust the cache at runtime (tests, benchmark flags)."""
    if enabled is not None:
        _cfg.enabled = enabled
    if directory is not None:
        _cfg.directory = directory
    if memory_mb is not None:
        _cfg.memory_mb = memory_mb
    if artifact_mb is not None:
        _cfg.artifact_mb = artifact_mb
    if disk_mb is not None:
        _cfg.disk_mb = disk_mb
    if max_bytes is not None:
        _cfg.max_bytes = max_bytes


def enabled(override: bool | None = None) -> bool:
    return _cfg.enabled if override is None else override


def stats() -> dict[str, int]:
    return dict(_stats, memory_bytes=_mem_bytes, entries=len(_mem))


def note_chunks(*, cold: int = 0, served: int = 0) -> None:
    """Census hook: producers report live-resolved vs store-served
    chunks (a prefix-served run must report ``cold == 0``)."""
    _stats["cold_chunks"] += cold
    _stats["served_chunks"] += served


def note_worker_retries(n: int = 1) -> None:
    """Census hook: a pool master re-dispatched ``n`` chunks after a
    worker died (respawn-and-retry; see the chunk-graph executor and
    :mod:`repro.serve`).  Surfaced by :func:`census` and the daemon's
    ``stats`` endpoint so silent worker churn is visible."""
    _stats["worker_retries"] += n


def note_speculation(n: int = 1) -> None:
    """Census hook: a pool master issued ``n`` speculative duplicate
    dispatches for straggling chunks (see
    :class:`repro.runtime.fault_tolerance.SpeculationPolicy`)."""
    _stats["speculated"] += n


def note_failover(n: int = 1) -> None:
    """Census hook: a served run lost its daemon (death, socket drop,
    deadline) mid-stream and completed in library mode from the
    committed store prefix.  Failovers are part of the contract — the
    counter keeps them from being *silently* part of it."""
    _stats["serve_failovers"] += n


def _faults():
    """The armed fault-injection plan's module, or ``None`` — a cheap
    check (module import is cached; ``active()`` reads one env var
    once) so production writes pay nothing."""
    try:
        from ..serve import faults as _f
    except ImportError:  # pragma: no cover - serve is part of the tree
        return None
    return _f if _f.active() else None


def _disk_cap_bytes() -> int:
    return _cfg.max_bytes if _cfg.max_bytes is not None \
        else _cfg.disk_mb * (1 << 20)


def clear(*, disk: bool = False) -> None:
    """Drop the in-process cache (and optionally the disk store)."""
    global _mem_bytes
    _mem.clear()
    _mem_bytes = 0
    for k in _stats:
        _stats[k] = 0
    if disk:
        d = _dir()
        if d and os.path.isdir(d):
            for f in os.listdir(d):
                if f.endswith((".npz", ".json", ".quarantine")):
                    try:
                        os.unlink(os.path.join(d, f))
                    except OSError:
                        pass


def evict(key: str) -> None:
    """Drop every chunk of one artifact from the in-process LRU and the
    disk store.  Benchmark meters use this to keep cold-timing probes
    cold across runs; missing keys are a no-op."""
    global _mem_bytes
    for k in [k for k in _mem if k[0] == key]:
        _mem_bytes -= _mem[k].nbytes
        del _mem[k]
    d = _dir()
    if d:
        for pat in (key + ".c*.npz", key + ".e*.npz"):
            for path in _glob.glob(os.path.join(d, pat)):
                try:
                    os.unlink(path)
                except OSError:
                    pass


def _dir() -> str | None:
    if _cfg.directory:
        return _cfg.directory
    # default: next to the benchmark artifacts when run from a repo,
    # else a per-user cache directory
    if os.path.isdir("experiments"):
        return os.path.join("experiments", ".rescache")
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro-rescache")


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------

def trace_fingerprint(acc: MemAccess) -> str:
    """Content digest of one address trace (cached on the object).

    Materialized traces up to :data:`FULL_HASH_MAX` addresses hash their
    full contents; longer or window-generated traces hash a deterministic
    spread of :data:`SAMPLE_WINDOWS` windows plus the length (``gen``
    must be pure in its arguments — already part of the ``MemAccess``
    contract, since the simulators re-window traces freely)."""
    fp = acc.__dict__.get("_fingerprint")
    if fp is not None:
        return fp
    h = hashlib.blake2b(digest_size=16)
    n = len(acc)
    h.update(str(n).encode())
    if acc.addrs is not None and n <= FULL_HASH_MAX:
        h.update(b"full")
        h.update(np.ascontiguousarray(acc.addrs).tobytes())
    else:
        h.update(b"sampled")
        if acc.gen is not None:
            # fold in the generator itself — bytecode plus any scalar
            # closure parameters — so two generators that happen to agree
            # on the sampled windows still get distinct keys unless they
            # are literally the same code with the same parameters
            code = getattr(acc.gen, "__code__", None)
            if code is not None:
                h.update(code.co_code)
                h.update(repr(code.co_consts).encode())
            for cell in getattr(acc.gen, "__closure__", None) or ():
                try:
                    v = cell.cell_contents
                except ValueError:
                    continue
                if isinstance(v, (int, float, str, bytes, bool)):
                    h.update(repr(v).encode())
                elif isinstance(v, np.ndarray) and v.size <= 4096:
                    h.update(v.tobytes())
        step = max(1, (n - SAMPLE_LEN) // max(1, SAMPLE_WINDOWS - 1))
        for i in range(SAMPLE_WINDOWS):
            lo = min(i * step, max(0, n - SAMPLE_LEN))
            hi = min(n, lo + SAMPLE_LEN)
            if hi <= lo:
                break
            h.update(acc._raw_window(lo, hi).tobytes())
    fp = h.hexdigest()
    acc.__dict__["_fingerprint"] = fp
    return fp


def _cache_signature(mem: MemoryModel) -> tuple | None:
    if mem.cache is None:
        return None
    c = mem.cache
    return (c.size_bytes, c.line_bytes, c.ways, c.hit_cycles,
            c.write_allocate)


def resolution_key(kind: str, stages: Sequence[SimStage],
                   mem: MemoryModel, seed: int,
                   extra: Any = None) -> str:
    """Content-addressed key for one resolution product.

    The signature is **per-op**, not per-stage, and — new in v3 —
    **length-free**: neither the iteration count nor any fold-only
    model field participates (see the module docstring).  ``kind``
    selects which per-op and model fields matter:

    * ``"dataflow"`` — ops carry their serialized flag (a
      ``mem_in_scc`` stage's accesses never burst and serialize into
      the II); the model contributes ``line_bytes`` (burst masks).
    * ``"conventional"`` — no bursts and no serialization (every valid
      access resolves), so neither flag keys.  ``posted_writes`` no
      longer keys either: the v3 artifact stores raw per-access
      latencies, and posted stores are excluded at fold time.

    ``MemAccess.width`` (burst width of a coalesced vector access — see
    ``repro.dataflow.transforms``) is **fold-only** under the v3
    contract: latency draws are per-*request* and identical addresses
    draw identical latencies, so a width-``w`` access resolves exactly
    like its width-1 head; only the burst-bandwidth fold reads ``w``.
    A *transformed* op stream, on the other hand, keys differently by
    construction — its closure cells (unroll factor, lane, base
    fingerprint) and sampled windows change the trace fingerprint — so
    transformed candidates are new cache entries, never invalidations
    of untransformed ones.
    """
    cache = _cache_signature(mem)
    if kind == "conventional":
        ops = tuple((trace_fingerprint(acc), acc.is_store)
                    for st in stages for acc in st.accesses)
        msig = (mem.port_latency, mem.dram_latency, mem.backing_hit_rate,
                cache)
    else:
        ops = tuple((trace_fingerprint(acc), acc.is_store, st.mem_in_scc)
                    for st in stages for acc in st.accesses)
        msig = (mem.port_latency, mem.dram_latency, mem.backing_hit_rate,
                mem.line_bytes, cache)
    payload = (_KEY_VERSION, kind, ops, msig, seed, extra)
    return hashlib.blake2b(repr(payload).encode(),
                           digest_size=16).hexdigest()


def processor_key(accesses: Sequence[MemAccess], model: Any) -> str:
    """Processor-hierarchy key: the cache *sizes* key the stored hit
    levels; hit latencies (``l1_hit``/``l2_hit``/``dram``) are fold-only
    — the cycle count is rebuilt from the level matrix."""
    payload = (_KEY_VERSION, "processor",
               tuple((trace_fingerprint(a), a.is_store) for a in accesses),
               (model.l1_kb, model.l2_kb))
    return hashlib.blake2b(repr(payload).encode(),
                           digest_size=16).hexdigest()


# ---------------------------------------------------------------------------
# Chunk records
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChunkRecord:
    """One stored resolution chunk: iterations
    ``[idx*CHUNK_ITERS, idx*CHUNK_ITERS + n)`` of one base key.

    ``ops`` is the per-op latency matrix (int32) — or, for the
    processor artifact, the per-op hit-level matrix (int8).  ``hitbits``
    packs the on-PL-cache hit flags of the same ``(n, K)`` layout
    (``None`` for cache-less models); ``hitbits2`` is the processor's
    L2 plane.  ``states`` maps state names (``"cache"``, ``"l1"``,
    ``"l2"``) to per-set MRU-first recency-stack snapshots taken at the
    chunk's END; ``cum`` holds cumulative counters at the same point
    (``hits``/``misses``/``draws``/``max_tag`` and processor
    equivalents).  Together they are the resume point: a run needing
    more iterations seeds its resolver from the last stored record and
    continues bit-identically."""

    key: str
    idx: int
    n: int
    ops: np.ndarray
    hitbits: np.ndarray | None = None
    hitbits2: np.ndarray | None = None
    states: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    cum: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        b = self.ops.nbytes
        for a in (self.hitbits, self.hitbits2, *self.states.values()):
            if a is not None:
                b += a.nbytes
        return b

    def hit_flags(self, plane: int = 1) -> np.ndarray | None:
        bits = self.hitbits if plane == 1 else self.hitbits2
        if bits is None:
            return None
        K = self.ops.shape[1]
        return np.unpackbits(bits, count=self.n * K).reshape(
            self.n, K).astype(bool)


def pack_flags(flags: np.ndarray) -> np.ndarray:
    """Pack an ``(n, K)`` bool matrix for a :class:`ChunkRecord`."""
    return np.packbits(flags.reshape(-1))


def shrink_ops(ops: np.ndarray) -> np.ndarray:
    """Narrow a latency matrix to the smallest integer dtype that holds
    it (resolved latencies are bounded by the DRAM trip — typically
    < 128, so records shrink 4×).  Consumers widen back to int32 before
    folding; values are preserved exactly."""
    if ops.dtype == np.int8 or ops.size == 0:
        return ops
    mx = int(ops.max())
    if mx < 128:
        return ops.astype(np.int8)
    if mx < (1 << 15) and ops.dtype != np.int16:
        return ops.astype(np.int16)
    return ops


def _chunk_path(d: str, key: str, idx: int) -> str:
    return os.path.join(d, f"{key}.c{idx:05d}.npz")


def _record_digest(n: int, ops: np.ndarray,
                   hitbits: np.ndarray | None,
                   hitbits2: np.ndarray | None,
                   states: dict[str, np.ndarray],
                   cum: dict[str, int]) -> str:
    """Content digest of one chunk record — dtype, shape, and bytes of
    every array plus the counters, so any bit-flip or torn array is
    detected on read.  Stored inside the npz (``checksum``) since this
    PR; records without one (older stores) load unverified."""
    h = hashlib.blake2b(digest_size=16)
    h.update(str(int(n)).encode())
    planes = [("ops", ops), ("hitbits", hitbits), ("hitbits2", hitbits2)]
    planes += [("st_" + k, states[k]) for k in sorted(states)]
    for name, arr in planes:
        if arr is None:
            continue
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(repr(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(repr(sorted(cum.items())).encode())
    return h.hexdigest()


def _quarantine(path: str) -> None:
    """Move a damaged record aside (``<name>.quarantine``) so the next
    prefix scan treats the chunk as absent and re-resolves it — the
    evidence survives for post-mortems, the serving path never sees it
    again.  :func:`gc` reclaims quarantined files."""
    _stats["quarantined"] += 1
    try:
        os.replace(path, path + ".quarantine")
    except OSError:
        try:
            os.unlink(path)
        except OSError:
            pass


def _touch_lru(k: tuple[str, int]) -> None:
    _mem.move_to_end(k)


def _insert_mem(rec: ChunkRecord) -> None:
    global _mem_bytes
    cap = _cfg.memory_mb * (1 << 20)
    if rec.nbytes > cap:
        return
    k = (rec.key, rec.idx)
    if k in _mem:
        _mem_bytes -= _mem[k].nbytes
        del _mem[k]
    _mem[k] = rec
    _mem_bytes += rec.nbytes
    while _mem_bytes > cap and _mem:
        _, old = _mem.popitem(last=False)
        _mem_bytes -= old.nbytes


def get_chunk(key: str, idx: int,
              refresh: bool = False) -> ChunkRecord | None:
    """Look one chunk record up in the in-process LRU, then disk.

    ``refresh=True`` skips the LRU and reloads from disk (still
    re-inserting the fresh copy): a partial tail record can be
    *overwritten* with a longer one by a resuming run or a pool worker,
    and a consumer that knows a rewrite just happened must not trust
    its cached copy."""
    k = (key, idx)
    if not refresh:
        rec = _mem.get(k)
        if rec is not None:
            _stats["mem_hits"] += 1
            _touch_lru(k)
            return rec
    d = _dir()
    path = _chunk_path(d, key, idx) if d else None
    if path and os.path.exists(path):
        try:
            with np.load(path) as z:
                cum_keys = [str(s) for s in z["cum_keys"]]
                cum_vals = z["cum_vals"]
                states = {name[3:]: z[name] for name in z.files
                          if name.startswith("st_")}
                rec = ChunkRecord(
                    key, idx, int(z["n"]), z["ops"],
                    z["hitbits"] if "hitbits" in z.files else None,
                    z["hitbits2"] if "hitbits2" in z.files else None,
                    states,
                    {kk: int(v) for kk, v in zip(cum_keys, cum_vals)})
                want = str(z["checksum"]) if "checksum" in z.files \
                    else None
            if want is not None and want != _record_digest(
                    rec.n, rec.ops, rec.hitbits, rec.hitbits2,
                    rec.states, rec.cum):
                # bit-rot / torn write: never serve it — quarantine and
                # miss, so the caller re-resolves the chunk cold
                _stats["disk_errors"] += 1
                _quarantine(path)
                _stats["misses"] += 1
                return None
            os.utime(path)  # LRU recency for the disk evictor
            _stats["disk_hits"] += 1
            _insert_mem(rec)
            return rec
        except (KeyError, ValueError, _BadZipFile):
            # structurally damaged (truncated zip, missing arrays):
            # same treatment as a checksum mismatch
            _stats["disk_errors"] += 1
            _quarantine(path)
        except OSError:
            _stats["disk_errors"] += 1
    _stats["misses"] += 1
    return None


def chunk_len(key: str, idx: int) -> int | None:
    """Length (iterations) of one stored chunk without loading its
    payload — ``None`` when the chunk is absent."""
    rec = _mem.get((key, idx))
    if rec is not None:
        return rec.n
    d = _dir()
    path = _chunk_path(d, key, idx) if d else None
    if path and os.path.exists(path):
        try:
            with np.load(path) as z:
                return int(z["n"])
        except (KeyError, ValueError, _BadZipFile):
            _stats["disk_errors"] += 1
            _quarantine(path)  # unreadable ⇒ the prefix ends here
        except OSError:
            _stats["disk_errors"] += 1
    return None


def put_chunk(rec: ChunkRecord) -> None:
    """Commit one chunk record to the in-process LRU and the disk
    store (atomic file replace; concurrent writers race benignly)."""
    _stats["stores"] += 1
    _insert_mem(rec)
    d = _dir()
    if not d:
        return
    try:
        os.makedirs(d, exist_ok=True)
        payload: dict[str, np.ndarray] = {
            "n": np.int64(rec.n), "ops": rec.ops,
            "cum_keys": np.array(sorted(rec.cum)),
            "cum_vals": np.array([rec.cum[k] for k in sorted(rec.cum)],
                                 dtype=np.int64),
            "checksum": np.array(_record_digest(
                rec.n, rec.ops, rec.hitbits, rec.hitbits2,
                rec.states, rec.cum))}
        if rec.hitbits is not None:
            payload["hitbits"] = rec.hitbits
        if rec.hitbits2 is not None:
            payload["hitbits2"] = rec.hitbits2
        for name, arr in rec.states.items():
            payload["st_" + name] = arr
        final = _chunk_path(d, rec.key, rec.idx)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
                # crash safety: the rename below must never publish a
                # record whose bytes are still in the page cache only —
                # a torn record after power loss would cost a checksum
                # quarantine + re-resolution on the next run
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        fi = _faults()
        if fi is not None:  # chaos harness: damage the published record
            fi.maybe_corrupt(final, key=rec.key, chunk=rec.idx)
        # amortized eviction: a full directory scan per stored chunk
        # would be O(chunks × files); sweep once per 1/16th of the cap
        global _evict_accum
        _evict_accum += rec.nbytes
        if _evict_accum >= _disk_cap_bytes() // 16:
            _evict_accum = 0
            _evict_disk(d)
    except OSError:
        _stats["disk_errors"] += 1


def prefix(key: str | None,
           chunk_iters: int | None = None) -> tuple[int, int]:
    """The stored contiguous prefix of one artifact:
    ``(full_chunks, avail_iters)``.

    ``full_chunks`` counts leading records of exactly ``chunk_iters``
    iterations — the resume point is ``full_chunks * chunk_iters``
    (a trailing partial record extends ``avail_iters`` for prefix
    *serving* but cannot seed a resume, because its resume state sits
    mid-chunk off the canonical grid; a longer run re-resolves it)."""
    if key is None:
        return 0, 0
    if chunk_iters is None:
        chunk_iters = CHUNK_ITERS
    full = 0
    avail = 0
    idx = 0
    while True:
        n = chunk_len(key, idx)
        if n is None:
            break
        avail += n
        if n < chunk_iters:
            break
        full += 1
        idx += 1
    return full, avail


# ---------------------------------------------------------------------------
# Cache-effect records (v3 ``<key>.eNNNNN.npz``)
# ---------------------------------------------------------------------------

def _effect_path(d: str, key: str, idx: int) -> str:
    return os.path.join(d, f"{key}.e{idx:05d}.npz")


def _effect_digest(stacks: np.ndarray, max_tag: int,
                   n_addrs: int) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str(stacks.dtype).encode())
    h.update(repr(stacks.shape).encode())
    h.update(np.ascontiguousarray(stacks).tobytes())
    h.update(str(int(max_tag)).encode())
    h.update(str(int(n_addrs)).encode())
    return h.hexdigest()


def put_effect(key: str | None, idx: int,
               effect: tuple[np.ndarray, int], n_addrs: int) -> None:
    """Commit one chunk's cache-effect monoid — the ``(stacks,
    max_tag)`` snapshot of an empty-cache replay — plus the chunk's
    participating-access count.  The record is a pure function of
    (artifact key, chunk index), so an existing file is already correct
    and the write is skipped; damage is caught by the checksum on read.
    Effect records share the chunk store's byte cap and mtime-LRU
    eviction (they are tiny next to the per-op matrices)."""
    d = _dir()
    if key is None or not d or not _cfg.enabled:
        return
    final = _effect_path(d, key, idx)
    if os.path.exists(final):
        return
    stacks, max_tag = effect
    stacks = np.ascontiguousarray(stacks)
    if stacks.size and int(np.abs(stacks).max()) < (1 << 31):
        stacks = stacks.astype(np.int32)  # tags fit: halve the record
    try:
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, stacks=stacks,
                         max_tag=np.int64(max_tag),
                         n_addrs=np.int64(n_addrs),
                         checksum=np.array(_effect_digest(
                             stacks, max_tag, n_addrs)))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        _stats["effect_stores"] += 1
    except OSError:
        _stats["disk_errors"] += 1


def get_effect(key: str | None,
               idx: int) -> tuple[np.ndarray, int, int] | None:
    """Load one stored cache-effect record: ``(stacks, max_tag,
    n_addrs)`` with the stacks widened back to int64, or ``None`` when
    absent.  Damaged records are quarantined and reported as absent —
    the master then falls back to the worker's phase-A message, so a
    bad effect record can never change results."""
    d = _dir()
    if key is None or not d:
        return None
    path = _effect_path(d, key, idx)
    if not os.path.exists(path):
        return None
    try:
        with np.load(path) as z:
            stacks = z["stacks"]
            max_tag = int(z["max_tag"])
            n_addrs = int(z["n_addrs"])
            want = str(z["checksum"]) if "checksum" in z.files else None
        if want is not None and want != _effect_digest(
                stacks, max_tag, n_addrs):
            _stats["disk_errors"] += 1
            _quarantine(path)
            return None
        os.utime(path)  # LRU recency for the disk evictor
        _stats["effect_hits"] += 1
        return stacks.astype(np.int64), max_tag, n_addrs
    except (KeyError, ValueError, _BadZipFile):
        _stats["disk_errors"] += 1
        _quarantine(path)
    except OSError:
        _stats["disk_errors"] += 1
    return None


class ChunkWriter:
    """Commits canonical-grid chunk records as a live run streams.

    Unlike the v2 whole-run writer, records hit the store the moment
    their chunk completes — an interrupted run keeps every completed
    chunk, and a later run resumes from the last one.  An artifact
    whose full size would blow the ``artifact_mb`` cap (Floyd–
    Warshall's 10⁹-iteration grid) stores only its first
    ``artifact_mb``-worth of chunks: reduced-iteration reruns still
    prefix-serve (zero cold resolution for any run inside the stored
    prefix) and full reruns resume from its end, while the store stays
    bounded."""

    def __init__(self, key: str | None, n_ops: int, n_iters: int,
                 itemsize: int = 4):
        self.key = key
        cap = _cfg.artifact_mb * (1 << 20)
        per_chunk = max(1, n_ops * CHUNK_ITERS * itemsize)
        self.max_chunks = cap // per_chunk
        self.dead = key is None or self.max_chunks == 0
        if key is not None and n_ops * n_iters * itemsize > cap:
            _stats["too_large"] += 1  # truncated to a stored prefix

    def add(self, idx: int, n: int, ops: np.ndarray,
            hitbits: np.ndarray | None = None,
            hitbits2: np.ndarray | None = None,
            states: dict[str, np.ndarray] | None = None,
            cum: dict[str, int] | None = None) -> None:
        if self.dead or idx >= self.max_chunks:
            return
        put_chunk(ChunkRecord(self.key, idx, n, shrink_ops(ops),
                              hitbits, hitbits2,
                              dict(states or {}), dict(cum or {})))


def _scan_store(d: str, suffix: str = ".npz") -> dict[str, tuple]:
    """``path -> (size, mtime)`` for the store's files; entries that
    vanish mid-scan (concurrent evictors) are simply skipped."""
    out: dict[str, tuple] = {}
    for f in os.listdir(d):
        if not f.endswith(suffix):
            continue
        path = os.path.join(d, f)
        try:
            st = os.stat(path)
        except OSError:
            continue
        out[path] = (st.st_size, st.st_mtime)
    return out


def _evict_disk(d: str) -> None:
    """Keep the store under the byte cap, oldest access first."""
    cap = _disk_cap_bytes()
    try:
        stat = _scan_store(d)
        total = sum(sz for sz, _ in stat.values())
        if total <= cap:
            return
        for f in sorted(stat, key=lambda p: stat[p][1]):
            try:
                os.unlink(f)
                total -= stat[f][0]
            except OSError:
                pass
            if total <= cap:
                break
    except OSError:
        pass


def gc(max_bytes: int | None = None) -> dict[str, int]:
    """Garbage-collect the on-disk store.

    Removes **orphans** — files that are not v3 chunk records (v1
    whole-run and v2 per-op ``<key>.npz`` artifacts, v2 ``.json``
    summaries, stray ``.tmp`` files) and effect records whose artifact
    has no chunk records left — then enforces the byte cap
    (``max_bytes`` argument, else ``$REPRO_RESCACHE_MAX_BYTES``, else
    ``disk_mb``) by evicting the least-recently-used chunk files.
    Returns a small report; safe to call concurrently with readers
    (missing files degrade to cache misses)."""
    d = _dir()
    report = {"orphans_removed": 0, "orphan_bytes": 0,
              "evicted": 0, "evicted_bytes": 0, "remaining_bytes": 0}
    if not d or not os.path.isdir(d):
        return report
    cap = max_bytes if max_bytes is not None else _disk_cap_bytes()
    keep: list[str] = []
    effect_files: list[tuple[str, str]] = []  # (key, path)
    chunk_keys: set[str] = set()
    for f in os.listdir(d):
        path = os.path.join(d, f)
        if not os.path.isfile(path):
            continue
        if _CHUNK_RE.match(f):
            keep.append(path)
            chunk_keys.add(f.split(".")[0])
            continue
        if _EFFECT_RE.match(f):
            effect_files.append((f.split(".")[0], path))
            continue
        if f.endswith((".npz", ".json", ".tmp", ".quarantine")):
            try:
                sz = os.path.getsize(path)
                os.unlink(path)
                report["orphans_removed"] += 1
                report["orphan_bytes"] += sz
            except OSError:
                pass
    # effect records ride with their artifact's chunk records: once the
    # last chunk of a key is gone (evicted, cleared), its effects are
    # orphans
    for key, path in effect_files:
        if key in chunk_keys:
            keep.append(path)
            continue
        try:
            sz = os.path.getsize(path)
            os.unlink(path)
            report["orphans_removed"] += 1
            report["orphan_bytes"] += sz
        except OSError:
            pass
    stat = {}
    for path in keep:
        try:
            st = os.stat(path)
        except OSError:
            continue  # raced away: already gone
        stat[path] = (st.st_size, st.st_mtime)
    total = sum(sz for sz, _ in stat.values())
    for path in sorted(stat, key=lambda p: stat[p][1]):
        if total <= cap:
            break
        try:
            os.unlink(path)
            total -= stat[path][0]
            report["evicted"] += 1
            report["evicted_bytes"] += stat[path][0]
        except OSError:
            pass
    report["remaining_bytes"] = total
    return report


def census() -> dict[str, Any]:
    """Store census: artifact count, chunk count, bytes on disk, plus
    the live cold/served chunk counters — what the acceptance checks
    ("a prefix-served rerun performs zero cold resolutions") read."""
    d = _dir()
    keys: set[str] = set()
    chunks = 0
    quarantine_files = 0
    total = 0
    effect_count = 0
    effect_bytes = 0
    if d and os.path.isdir(d):
        for f in os.listdir(d):
            if _CHUNK_RE.match(f):
                keys.add(f.split(".")[0])
                chunks += 1
                try:
                    total += os.path.getsize(os.path.join(d, f))
                except OSError:
                    pass
            elif _EFFECT_RE.match(f):
                effect_count += 1
                try:
                    effect_bytes += os.path.getsize(
                        os.path.join(d, f))
                except OSError:
                    pass
            elif f.endswith(".quarantine"):
                quarantine_files += 1
    try:
        from ..serve import faults as _fa
        injected = _fa.stats()
    except ImportError:  # pragma: no cover
        injected = {}
    return {"dir": d, "artifacts": len(keys), "chunks": chunks,
            "bytes": total,
            "effects": {"count": effect_count, "bytes": effect_bytes,
                        "stores": _stats["effect_stores"],
                        "hits": _stats["effect_hits"]},
            "cold_chunks": _stats["cold_chunks"],
            "served_chunks": _stats["served_chunks"],
            "worker_retries": _stats["worker_retries"],
            "quarantined": _stats["quarantined"],
            "quarantine_files": quarantine_files,
            "serve_failovers": _stats["serve_failovers"],
            "speculated": _stats["speculated"],
            "faults_injected": injected}

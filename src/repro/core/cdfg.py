"""CDFG construction from jaxprs — the front end of the dataflow template mapper.

The paper (Cheng & Wawrzynek 2016) operates on the control-dataflow graph of a
performance-critical loop nest, produced by the LLVM front end from C.  Our
front end is ``jax.make_jaxpr``: the jaxpr of a step function (or of a loop
body) plays the role of the LLVM IR in SSA form — it "facilitates dependency
tracking between operations" exactly as §IV describes.

Two views are provided:

* :func:`CDFG.from_function` — acyclic dataflow graph of a traced function.
  ``scan`` / ``while`` equations appear as single nodes: they are *already
  collapsed SCCs* (the loop carry is the dependence cycle).
* :func:`CDFG.from_loop_body` — the faithful §III view: the body of a loop is
  traced, and back-edges are added from each carry output to the matching
  carry input, recreating the cyclic CDFG on which Algorithm 1's
  ``allStronglyConnComps`` runs for real.

Memory-dependence edges (§III-A: "explicit edges between memory access
operations are added") are inserted between memory operations that touch the
same *region*.  Regions are discovered by tracing each memory primitive's
operand back through layout-only ops to a jaxpr input, and can be overridden
by user annotation — the analogue of the paper's user-guided alias results.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
from jax.extend import core as jex_core

# ---------------------------------------------------------------------------
# Operation classification (the paper's "long latency" table, §III-A).
#
# The paper derives per-op latencies from Vivado HLS at a 150 MHz target:
# a 32-bit integer add completes in one cycle, a floating point multiply
# takes four.  The TPU analogue: VPU element-wise integer/logical ops are
# "one cycle" (cheap, freely duplicable per §III-B1), while MXU contractions,
# transcendentals, sorts and loop primitives are multi-cycle ("long").
# ---------------------------------------------------------------------------

#: primitives that perform data-dependent / strided memory traffic — the
#: template's "memory operations".  On TPU these lower to HBM gathers /
#: scatters / dynamic addressing, the ops whose latency the template hides.
MEMORY_PRIMITIVES: frozenset[str] = frozenset({
    "gather",
    "scatter",
    "scatter-add",
    "scatter-mul",
    "scatter-min",
    "scatter-max",
    "scatter_add",
    "dynamic_slice",
    "dynamic_update_slice",
    "take",
    "argsort",  # permutation materialization reads/writes HBM irregularly
})

#: default per-primitive latency (abstract cycles).  Anything > 1 is "long
#: latency" in the Algorithm-1 sense.  Unlisted primitives default to 1.
DEFAULT_LATENCY: dict[str, int] = {
    # MXU / contraction
    "dot_general": 8,
    "conv_general_dilated": 8,
    # transcendentals (VPU multi-pass)
    "exp": 4, "log": 4, "log1p": 4, "tanh": 4, "logistic": 4, "erf": 4,
    "sin": 4, "cos": 4, "pow": 4, "integer_pow": 2, "rsqrt": 4, "sqrt": 4,
    "div": 4, "cbrt": 4, "exp2": 4,
    # float multiply-class ops: the paper's canonical 4-cycle example
    "mul": 4,
    # reductions / scans are multi-pass
    "reduce_sum": 2, "reduce_max": 2, "reduce_min": 2, "reduce_prod": 2,
    "cumsum": 4, "cumlogsumexp": 4, "cummax": 4, "cumprod": 4,
    "sort": 8, "top_k": 8,
    # loop / control primitives carry their body's latency; treated long
    "scan": 8, "while": 8, "cond": 2, "pjit": 8, "custom_call": 8,
    # memory ops: the *issue* cost; the stall cost is the memory model's job
    "gather": 2, "scatter": 2, "scatter-add": 2,
    "dynamic_slice": 2, "dynamic_update_slice": 2,
}

#: layout-only primitives that are transparent when tracing a memory operand
#: back to its root buffer.  In-place-update ops (scatter, dus) are also
#: transparent on operand 0: the functional output aliases the input buffer,
#: so loads from the updated array belong to the same memory region.
_TRANSPARENT = frozenset({
    "convert_element_type", "reshape", "transpose", "broadcast_in_dim",
    "squeeze", "bitcast_convert_type", "copy", "rev", "slice",
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "dynamic_update_slice",
})

# integer "cheap" ops eligible for duplication instead of a channel (§III-B1)
CHEAP_PRIMITIVES: frozenset[str] = frozenset({
    "add", "sub", "and", "or", "xor", "not", "lt", "le", "gt", "ge", "eq",
    "ne", "select_n", "max", "min", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "convert_element_type", "broadcast_in_dim",
    "reshape", "squeeze", "iota", "concatenate", "pad", "slice", "transpose",
    "rem", "sign", "neg", "abs", "floor", "ceil", "round", "clamp",
})


@dataclasses.dataclass
class LatencyModel:
    """Maps primitives to abstract cycle latencies (paper §III-A).

    ``table`` overrides :data:`DEFAULT_LATENCY`; ``default`` is used for
    unknown primitives.  ``long_threshold`` is the Algorithm-1 cut: ops that
    "cannot be completed within one clock cycle".
    """

    table: Mapping[str, int] = dataclasses.field(default_factory=dict)
    default: int = 1
    long_threshold: int = 1

    def latency(self, prim_name: str) -> int:
        if prim_name in self.table:
            return self.table[prim_name]
        return DEFAULT_LATENCY.get(prim_name, self.default)

    def is_long(self, prim_name: str) -> bool:
        return self.latency(prim_name) > self.long_threshold


@dataclasses.dataclass
class Node:
    """One CDFG node == one jaxpr equation (before SCC collapse)."""

    id: int
    prim: str
    eqn: Any  # jex_core.JaxprEqn
    is_memory: bool
    latency: int
    region: str | None = None  # memory region for memory ops
    is_store: bool = False

    @property
    def is_long(self) -> bool:
        return self.latency > 1

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        tag = "M" if self.is_memory else ("L" if self.is_long else ".")
        return f"<n{self.id} {self.prim} [{tag}]>"


@dataclasses.dataclass
class Edge:
    src: int
    dst: int
    var: Any | None  # jaxpr Var carried (None for memory-order edges)
    kind: str = "data"  # "data" | "mem" | "carry"


class CDFG:
    """Control-dataflow graph over jaxpr equations.

    Nodes are equations; edges are SSA def-use pairs plus explicit
    memory-ordering edges and (for the loop view) carry back-edges.
    """

    def __init__(
        self,
        closed_jaxpr: Any,
        nodes: list[Node],
        edges: list[Edge],
        invars: Sequence[Any],
        outvars: Sequence[Any],
        region_of_invar: Mapping[int, str],
    ) -> None:
        self.closed_jaxpr = closed_jaxpr
        self.nodes = nodes
        self.edges = edges
        self.invars = list(invars)
        self.outvars = list(outvars)
        self.region_of_invar = dict(region_of_invar)
        self._by_id = {n.id: n for n in nodes}
        #: active TransformConfig, set by the driver's ``transform`` pass
        #: (None = untransformed); read by ``partition.materialize``
        self.transforms = None

    # -- construction -------------------------------------------------------

    @classmethod
    def from_function(
        cls,
        fn: Callable,
        *example_args: Any,
        latency_model: LatencyModel | None = None,
        regions: Mapping[int, str] | None = None,
        add_memory_edges: bool = True,
        **example_kwargs: Any,
    ) -> "CDFG":
        closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
        return cls.from_jaxpr(
            closed,
            latency_model=latency_model,
            regions=regions,
            add_memory_edges=add_memory_edges,
        )

    @classmethod
    def from_jaxpr(
        cls,
        closed_jaxpr: Any,
        *,
        latency_model: LatencyModel | None = None,
        regions: Mapping[int, str] | None = None,
        add_memory_edges: bool = True,
        annotate_regions: bool = True,
        carry_pairs: Sequence[tuple[int, int]] = (),
    ) -> "CDFG":
        """Build the CDFG.  ``carry_pairs`` is a list of
        ``(outvar_index, invar_index)`` pairs: a back-edge is added from the
        producer of ``outvars[o]`` to every consumer of ``invars[i]``,
        recreating loop-carried dependence cycles (the §III loop view).

        ``annotate_regions=False`` defers the memory-dependence analysis
        (region discovery + ordering edges) so it can run as a separate
        compiler pass — see :func:`annotate_memory_regions` and
        :func:`add_memory_order_edges`.
        """
        lm = latency_model or LatencyModel()
        jaxpr = closed_jaxpr.jaxpr

        nodes: list[Node] = []
        producer: dict[Any, int] = {}  # var -> node id
        for i, eqn in enumerate(jaxpr.eqns):
            prim = eqn.primitive.name
            is_mem = prim in MEMORY_PRIMITIVES
            node = Node(
                id=i,
                prim=prim,
                eqn=eqn,
                is_memory=is_mem,
                latency=lm.latency(prim),
                is_store=prim.startswith("scatter")
                or prim == "dynamic_update_slice",
            )
            nodes.append(node)
            for ov in eqn.outvars:
                producer[ov] = i

        edges: list[Edge] = []
        for i, eqn in enumerate(jaxpr.eqns):
            for iv in eqn.invars:
                if isinstance(iv, jex_core.Literal):
                    continue
                if iv in producer:
                    edges.append(Edge(producer[iv], i, iv, "data"))

        cdfg = cls(closed_jaxpr, nodes, edges, jaxpr.invars, jaxpr.outvars,
                   dict(regions or {}))

        if annotate_regions or add_memory_edges:
            annotate_memory_regions(cdfg, regions, producer=producer)
        if add_memory_edges:
            add_memory_order_edges(cdfg)

        # loop-carried back-edges (the §III faithful view)
        for out_idx, in_idx in carry_pairs:
            ov = jaxpr.outvars[out_idx]
            if isinstance(ov, jex_core.Literal) or ov not in producer:
                continue
            src = producer[ov]
            iv = jaxpr.invars[in_idx]
            for j, eqn in enumerate(jaxpr.eqns):
                if any((not isinstance(x, jex_core.Literal)) and x is iv
                       for x in eqn.invars):
                    cdfg.edges.append(Edge(src, j, None, "carry"))

        return cdfg

    @classmethod
    def from_loop_body(
        cls,
        body_fn: Callable,
        carry_example: Any,
        *xs_example: Any,
        latency_model: LatencyModel | None = None,
        regions: Mapping[int, str] | None = None,
        nonaliasing_carries: Sequence[int] = (),
    ) -> "CDFG":
        """Trace ``body_fn(carry, *xs) -> new_carry`` and add carry
        back-edges so loop-carried dependence becomes a real cycle.

        ``carry_example`` may be a pytree; every leaf becomes one carry pair.

        ``nonaliasing_carries`` is the paper's §III-A *user annotation*:
        carried arrays whose per-iteration writes provably do not feed the
        reads of nearby iterations (Floyd–Warshall's dist within one k pass,
        knapsack's previous DP row).  Conservative alias analysis would
        serialize them; the annotation drops their back-edge so Algorithm 1
        can pipeline across the false dependence.
        """
        closed = jax.make_jaxpr(body_fn)(carry_example, *xs_example)
        n_carry = len(jax.tree_util.tree_leaves(carry_example))
        skip = set(nonaliasing_carries)
        carry_pairs = [(i, i) for i in range(n_carry) if i not in skip]
        return cls.from_jaxpr(
            closed,
            latency_model=latency_model,
            regions=regions,
            carry_pairs=carry_pairs,
        )

    # -- queries ------------------------------------------------------------

    def node(self, nid: int) -> Node:
        return self._by_id[nid]

    def successors(self, nid: int) -> Iterable[int]:
        return (e.dst for e in self.edges if e.src == nid)

    def to_networkx(self):
        import networkx as nx

        g = nx.MultiDiGraph()
        for n in self.nodes:
            g.add_node(n.id, prim=n.prim, is_memory=n.is_memory,
                       latency=n.latency, region=n.region)
        for e in self.edges:
            g.add_edge(e.src, e.dst, kind=e.kind)
        return g

    @property
    def memory_nodes(self) -> list[Node]:
        return [n for n in self.nodes if n.is_memory]

    @property
    def long_nodes(self) -> list[Node]:
        return [n for n in self.nodes if n.is_long]

    def summary(self) -> str:
        lines = [f"CDFG: {len(self.nodes)} nodes, {len(self.edges)} edges, "
                 f"{len(self.memory_nodes)} memory ops, "
                 f"{len(self.long_nodes)} long-latency ops"]
        for n in self.nodes:
            tag = "MEM" if n.is_memory else ("LONG" if n.is_long else "")
            reg = f" region={n.region}" if n.region else ""
            lines.append(f"  n{n.id:<3} {n.prim:<24} lat={n.latency}"
                         f" {tag}{reg}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Memory-dependence analysis (§III-A) — standalone so the compiler driver
# can schedule it as a named pass (repro.dataflow.passes.MemoryDepPass).
# ---------------------------------------------------------------------------


def producer_map(cdfg: CDFG) -> dict[Any, int]:
    """var -> id of the node that defines it."""
    return {ov: n.id for n in cdfg.nodes for ov in n.eqn.outvars}


def annotate_memory_regions(
    cdfg: CDFG, regions: Mapping[int, str] | None = None,
    *, producer: Mapping[Any, int] | None = None,
) -> dict[int, str]:
    """Region discovery: walk each memory op's buffer operand back through
    layout ops to a jaxpr invar (or a closed-over constvar) and record the
    region on the node.  ``regions`` overrides names per invar index — the
    paper's user-guided alias annotation.  ``producer`` accepts a
    precomputed :func:`producer_map` to avoid rebuilding it."""
    jaxpr = cdfg.closed_jaxpr.jaxpr
    if producer is None:
        producer = producer_map(cdfg)
    invar_index = {v: k for k, v in enumerate(jaxpr.invars)}
    constvar_index = {v: k for k, v in enumerate(jaxpr.constvars)}
    region_of_invar = cdfg.region_of_invar
    if regions:
        region_of_invar.update(regions)

    def root_invar(var: Any) -> int | None:
        seen = 0
        while True:
            if var in invar_index:
                return invar_index[var]
            if var in constvar_index:
                return -1 - constvar_index[var]  # consts: negative ids
            pid = producer.get(var)
            if pid is None:
                return None
            peqn = cdfg.nodes[pid].eqn
            if peqn.primitive.name in _TRANSPARENT and peqn.invars:
                nxt = peqn.invars[0]
                if isinstance(nxt, jex_core.Literal):
                    return None
                var = nxt
                seen += 1
                if seen > 100:
                    return None
            else:
                return None

    for node in cdfg.nodes:
        if not node.is_memory or not node.eqn.invars:
            continue
        op0 = node.eqn.invars[0]
        if isinstance(op0, jex_core.Literal):
            continue
        ridx = root_invar(op0)
        if ridx is not None:
            default = (f"arg{ridx}" if ridx >= 0
                       else f"const{-1 - ridx}")
            name = region_of_invar.get(ridx, default)
            region_of_invar.setdefault(ridx, name)
            node.region = name
        else:
            node.region = "_anon"
    return region_of_invar


def add_memory_order_edges(cdfg: CDFG) -> list[Edge]:
    """§III-A: explicit ordering edges between memory ops of one region.
    Loads commute; stores serialize against everything in the region.
    Appends the new edges to ``cdfg.edges`` and returns them."""
    added: list[Edge] = []
    by_region: dict[str, list[Node]] = {}
    for n in cdfg.nodes:
        if n.is_memory and n.region is not None:
            by_region.setdefault(n.region, []).append(n)
    for reg_nodes in by_region.values():
        reg_nodes.sort(key=lambda n: n.id)
        last_store: Node | None = None
        loads_since_store: list[Node] = []
        for n in reg_nodes:
            if n.is_store:
                if last_store is not None:
                    added.append(Edge(last_store.id, n.id, None, "mem"))
                for ld in loads_since_store:
                    added.append(Edge(ld.id, n.id, None, "mem"))
                last_store = n
                loads_since_store = []
            else:
                if last_store is not None:
                    added.append(Edge(last_store.id, n.id, None, "mem"))
                loads_since_store.append(n)
    cdfg.edges.extend(added)
    return added

"""repro.core — the paper's contribution as a composable JAX module.

Pipeline:  trace (CDFG) → partition (Algorithm 1) → decouple (stage
programs) → execute (systolic / pipeline-parallel) or simulate (Fig. 2/5).
"""

from .cdfg import (CDFG, LatencyModel, MEMORY_PRIMITIVES, DEFAULT_LATENCY,
                   add_memory_order_edges, annotate_memory_regions)
from .partition import (Partition, Stage, StagePlan, Channel, partition_cdfg,
                        stage_groups, merge_costly_boundaries, materialize,
                        duplicate_cheap_rewrite, derive_channels,
                        plan_signature, plan_is_legal, merge_move,
                        split_move, neighbor_plans, fused_plan, maximal_plan)
from .decouple import (DecoupledProgram, decouple, decoupled_call,
                       run_stages_sequential)
from .channels import ChannelSpec, DeviceFIFO, FIFOState, HostFIFO
from .pipeline import (SystolicPipeline, pipeline_apply,
                       pipeline_apply_emulated, gpipe_bubble_fraction,
                       shard_map_compat)
from . import simulator

__all__ = [
    "CDFG", "LatencyModel", "MEMORY_PRIMITIVES", "DEFAULT_LATENCY",
    "add_memory_order_edges", "annotate_memory_regions",
    "Partition", "Stage", "StagePlan", "Channel", "partition_cdfg",
    "stage_groups", "merge_costly_boundaries", "materialize",
    "duplicate_cheap_rewrite", "derive_channels",
    "plan_signature", "plan_is_legal", "merge_move", "split_move",
    "neighbor_plans", "fused_plan", "maximal_plan",
    "DecoupledProgram", "decouple", "decoupled_call",
    "run_stages_sequential",
    "ChannelSpec", "DeviceFIFO", "FIFOState", "HostFIFO",
    "SystolicPipeline", "pipeline_apply", "pipeline_apply_emulated",
    "gpipe_bubble_fraction", "shard_map_compat",
    "simulator",
]

"""repro.core — the paper's contribution as a composable JAX module.

Pipeline:  trace (CDFG) → partition (Algorithm 1) → decouple (stage
programs) → execute (systolic / pipeline-parallel) or simulate (Fig. 2/5).
"""

from .cdfg import CDFG, LatencyModel, MEMORY_PRIMITIVES, DEFAULT_LATENCY
from .partition import Partition, Stage, Channel, partition_cdfg
from .decouple import (DecoupledProgram, decouple, decoupled_call,
                       run_stages_sequential)
from .channels import ChannelSpec, DeviceFIFO, FIFOState, HostFIFO
from .pipeline import (SystolicPipeline, pipeline_apply,
                       pipeline_apply_emulated, gpipe_bubble_fraction)
from . import simulator

__all__ = [
    "CDFG", "LatencyModel", "MEMORY_PRIMITIVES", "DEFAULT_LATENCY",
    "Partition", "Stage", "Channel", "partition_cdfg",
    "DecoupledProgram", "decouple", "decoupled_call",
    "run_stages_sequential",
    "ChannelSpec", "DeviceFIFO", "FIFOState", "HostFIFO",
    "SystolicPipeline", "pipeline_apply", "pipeline_apply_emulated",
    "gpipe_bubble_fraction",
    "simulator",
]

"""The backend-switchable resolution engine.

The simulator's hot loops — the "last N distinct lines" recency-stack
monoid, the segmented N-way LRU replay, and the wavefront solver's
running-max sweeps — are all scan-shaped: exactly the computation the
paper's dataflow template (and this repo's jax_pallas stack) pipelines.
This module holds one implementation of each kernel per backend and a
tiny selection layer:

* ``REPRO_ENGINE=auto|numpy|jax`` picks the backend process-wide
  (``auto`` is the default: jitted JAX when an accelerator backend is
  present, numpy on plain CPU hosts where XLA's log-depth scans lose to
  the cache-friendly serial forms);
* :func:`use` overrides it per call (the ``engine=`` keyword on the
  ``simulate_*`` entry points), :func:`select` process-wide;
* explicit ``jax`` uses the jitted kernels even on CPU — they are
  bit-identical by construction (integer max/compare only, no floats),
  which is what the CI ``REPRO_ENGINE=jax`` lane asserts.

Every kernel here is exact integer arithmetic; backends may only differ
in wall clock, never in results.  Sizes below the ``JIT_MIN_*``
thresholds keep the numpy form even under ``jax`` selection *when
auto-selected* — dispatch + host-transfer overhead dominates tiny
calls — but an explicit selection is honoured as asked.

The module also owns the per-phase wall-clock accounting
(:func:`phase` / :func:`walls`) that the ``worker_scaling`` benchmark
probe and the chunk-graph master use to attribute time to the
effect / replay / fold / solve phases across process boundaries.
"""

from __future__ import annotations

import contextlib
import os
import time

import numpy as np

__all__ = [
    "current", "select", "use", "jax_modules",
    "phase", "walls", "reset_walls", "merge_walls",
    "running_max", "nway_core", "lru_insert", "stack_compose",
]

_VALID = ("auto", "numpy", "jax")

#: per-call / process-wide override installed by :func:`use` /
#: :func:`select`; ``None`` defers to ``$REPRO_ENGINE``
_forced: str | None = None

#: cached ``(jax, jax.numpy, jax.lax)`` triple, ``False`` when the
#: import failed — one attempt per process
_jax_mods = None

#: below this many scan elements the numpy running max is kept even on
#: the jax engine when auto-selected (dispatch overhead > kernel time)
JIT_MIN_ELEMS = 1 << 15

#: below this many segments the numpy N-way core is kept likewise
JIT_MIN_SEGMENTS = 1 << 9


def _env_choice() -> str:
    v = (os.environ.get("REPRO_ENGINE") or "auto").strip().lower()
    return v if v in _VALID else "auto"


def jax_modules():
    """``(jax, jnp, lax)``, or ``None`` when jax is not importable.

    Importing here never touches global jax config: the engine's
    kernels run under a *scoped* :func:`_x64` context instead (see
    there for why 64-bit mode is mandatory for them but must not leak
    into the process default).
    """
    global _jax_mods
    if _jax_mods is None:
        try:
            import jax
            import jax.numpy as jnp
            from jax import lax
            _jax_mods = (jax, jnp, lax)
        except Exception:
            _jax_mods = False
    return _jax_mods or None


def _x64():
    """Scoped 64-bit mode for one engine kernel call.

    x64 is mandatory for the kernels: carried cache tags exceed
    ``2**31`` on large address spaces (there is a regression test),
    and jax silently truncates int64 arrays to int32 without it.  But
    flipping ``jax_enable_x64`` process-wide breaks code that relies
    on jax's default 32-bit weak typing (mixed int32/int64 index
    errors in the model stack), so the engine enables it around
    exactly its own traces and calls — jit caches key on the flag, so
    scoped-x64 traces never collide with the host program's."""
    from jax.experimental import enable_x64
    return enable_x64()


def current() -> str:
    """The engine this call site resolves to: ``"numpy"`` or ``"jax"``.

    Order: :func:`use`/:func:`select` override, then ``$REPRO_ENGINE``,
    then ``auto`` — which picks jax only when jax imports *and* its
    default backend is an accelerator (on CPU the serial numpy scans
    beat XLA's log-depth ones; see docs/engine.md for the measurement).
    A jax selection without an importable jax degrades to numpy.
    """
    choice = _forced or _env_choice()
    if choice == "auto":
        m = jax_modules()
        if m is not None and m[0].default_backend() != "cpu":
            return "jax"
        return "numpy"
    if choice == "jax" and jax_modules() is None:
        return "numpy"
    return choice


def _explicit() -> bool:
    """True when jax was asked for by name (override or env) rather
    than auto-selected — explicit selections bypass the size
    thresholds so the CI lane exercises the jitted kernels on every
    call size."""
    return (_forced or _env_choice()) == "jax"


def select(name: str | None) -> None:
    """Process-wide engine selection (``None`` reverts to the env)."""
    global _forced
    if name is not None and name not in _VALID:
        raise ValueError(f"unknown engine {name!r}; pick from {_VALID}")
    _forced = name


@contextlib.contextmanager
def use(name: str | None):
    """Scoped engine override — the ``engine=`` keyword of the
    ``simulate_*`` entry points.  ``None`` is a no-op."""
    if name is None:
        yield
        return
    if name not in _VALID:
        raise ValueError(f"unknown engine {name!r}; pick from {_VALID}")
    global _forced
    prev = _forced
    _forced = name
    try:
        yield
    finally:
        _forced = prev


# ---------------------------------------------------------------------------
# Per-phase wall-clock accounting
# ---------------------------------------------------------------------------

#: phase name -> accumulated seconds in this process; the chunk-graph
#: workers drain theirs into the ``done`` message and the master merges,
#: so a sharded run's walls cover the whole pool
_WALLS: dict[str, float] = {}


@contextlib.contextmanager
def phase(name: str):
    """Accumulate the wall clock of the enclosed block under ``name``
    (effect / replay / fold / solve are the canonical phases)."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        _WALLS[name] = _WALLS.get(name, 0.0) \
            + time.perf_counter() - t0


def walls() -> dict[str, float]:
    return dict(_WALLS)


def reset_walls() -> None:
    _WALLS.clear()


def merge_walls(other: dict[str, float] | None) -> None:
    for k, v in (other or {}).items():
        _WALLS[k] = _WALLS.get(k, 0.0) + float(v)


# ---------------------------------------------------------------------------
# Running max (the wavefront solver's serial recurrence)
# ---------------------------------------------------------------------------

#: block width of the dominated-block numpy running max — big enough
#: that the per-block bookkeeping vanishes, small enough that one block
#: sits in L1
_RMAX_BLOCK = 4096

_cummax_jit = None


def _running_max_np(a: np.ndarray) -> np.ndarray:
    """In-place inclusive running max, skipping dominated blocks.

    ``np.maximum.accumulate`` is a serial scalar loop.  The solver's
    arrays are ``b - cumsum(c)`` shapes that trend *down* (the paper's
    pipelines are mostly self-recurrence-bound), so most blocks never
    beat the carry from the left: per-block maxima are computed
    vectorized, blocks whose max is dominated by the incoming carry are
    filled with the carry constant, and only the rest pay the scalar
    accumulate — ~8x on trending data, bounded regression (~1.1x) on
    monotonically increasing data.
    """
    n = a.size
    B = _RMAX_BLOCK
    if n < 2 * B or not a.flags.c_contiguous:
        np.maximum.accumulate(a, out=a)
        return a
    nb = n // B
    m2 = a[:nb * B].reshape(nb, B)
    M = m2.max(axis=1)
    C = np.maximum.accumulate(M)
    np.maximum.accumulate(m2[0], out=m2[0])
    need = np.nonzero(M[1:] > C[:-1])[0] + 1
    for i in need:
        row = m2[i]
        np.maximum.accumulate(row, out=row)
        np.maximum(row, C[i - 1], out=row)
    dom = np.ones(nb, dtype=bool)
    dom[0] = False
    dom[need] = False
    if dom.any():
        m2[dom] = C[np.nonzero(dom)[0] - 1, None]
    tail = a[nb * B:]
    if tail.size:
        np.maximum.accumulate(tail, out=tail)
        np.maximum(tail, C[-1], out=tail)
    return a


def running_max(a: np.ndarray) -> np.ndarray:
    """In-place inclusive running maximum of a 1-D integer array.

    Dispatches to the jitted ``lax.cummax`` on the jax engine (above
    the dispatch threshold) and to the dominated-block numpy form
    otherwise; both are exact, so results never depend on the engine.
    """
    if a.size >= JIT_MIN_ELEMS and current() == "jax":
        jx, jnp, lax = jax_modules()
        if jx.default_backend() != "cpu":
            try:
                a[:] = pallas_running_max(a)
                return a
            except Exception:
                pass  # lowering gap on this backend: XLA scan below
        global _cummax_jit
        if _cummax_jit is None:
            _cummax_jit = jx.jit(lambda x: lax.cummax(x, axis=0))
        with _x64():
            a[:] = np.asarray(_cummax_jit(a))
        return a
    return _running_max_np(a)


# ---------------------------------------------------------------------------
# The recency-stack monoid (shared by both backends)
# ---------------------------------------------------------------------------

def lru_insert(stk: np.ndarray, x: np.ndarray) -> np.ndarray:
    """One LRU step over per-row recency stacks.

    ``stk`` is ``(rows, ways)`` with slot 0 the MRU tag (−1 = empty);
    ``x`` is one tag per row (−2 = inactive row this round).  Returns
    the updated stacks: a present tag rotates to the front, an absent
    one shifts the whole stack (evicting the last slot).
    """
    ways = stk.shape[1]
    cmp = stk == x[:, None]
    found = cmp.any(axis=1)
    # rotate depth: the hit way, or the whole stack on a miss
    j = np.where(found, np.argmax(cmp, axis=1), ways - 1)
    j[x == -2] = -1  # inactive rows rotate nothing
    shifted = np.empty_like(stk)
    shifted[:, 1:] = stk[:, :-1]
    shifted[:, 0] = x
    return np.where(np.arange(ways) <= j[:, None], shifted, stk)


def stack_compose(older: np.ndarray, newer: np.ndarray) -> np.ndarray:
    """Compose two recency stacks: ``newer`` applied after ``older``.

    The "last N distinct lines" monoid: the result is ``newer``'s tags
    followed by ``older``'s tags not already present, truncated to N.
    Associative — tags pushed past slot N can never resurface.
    """
    rows, ways = newer.shape
    nb = (newer >= 0).sum(axis=1)
    in_newer = (older[:, :, None] == newer[:, None, :]).any(axis=2)
    keep = (older >= 0) & ~in_newer
    tgt = nb[:, None] + np.cumsum(keep, axis=1) - 1
    out = newer.copy()
    mask = keep & (tgt < ways)
    r_idx = np.broadcast_to(np.arange(rows)[:, None], tgt.shape)
    out[r_idx[mask], tgt[mask]] = older[mask]
    return out


# ---------------------------------------------------------------------------
# The segmented N-way replay core
# ---------------------------------------------------------------------------

def _nway_core_np(T: np.ndarray, seg_grp: np.ndarray,
                  seg_first: np.ndarray, carried: np.ndarray,
                  max_run: int) -> tuple[np.ndarray, np.ndarray]:
    """numpy reference of :func:`nway_core` (see there for the
    contract) — pass A, the segmented Hillis–Steele compose, pass B."""
    W, G = T.shape
    ways = carried.shape[1]
    # pass A: per-segment own stacks, replayed from empty
    stk = np.full((G, ways), -1, dtype=T.dtype)
    for r in range(W):
        stk = lru_insert(stk, T[r])
    # incoming[g] = carried ∘ own[first..g-1]: inclusive segmented scan
    # over E = [carried at set-first segments, own[g-1] elsewhere]
    E = np.empty_like(stk)
    E[1:] = stk[:-1]
    E[seg_first] = carried[seg_grp[seg_first]]
    d = 1
    while d < max_run:
        composed = stack_compose(E[:-d], E[d:])
        valid = seg_grp[d:] == seg_grp[:-d]
        E[d:] = np.where(valid[:, None], composed, E[d:])
        d *= 2
    # pass B: replay from the incoming stacks, recording hits
    HIT = np.empty((W, G), dtype=bool)
    stk = E
    for r in range(W):
        x = T[r]
        HIT[r] = (stk == x[:, None]).any(axis=1) & (x != -2)
        stk = lru_insert(stk, x)
    return HIT, stk


_nway_jit = None


def _build_nway_jit():
    """The jitted N-way core.  One traced function; XLA's own cache
    keys on shapes, which the caller pads to powers of two so a long
    run compiles a handful of variants, not one per chunk."""
    jx, jnp, lax = jax_modules()

    def insert(stk, x):
        ways = stk.shape[1]
        cmp = stk == x[:, None]
        found = cmp.any(axis=1)
        j = jnp.where(found, jnp.argmax(cmp, axis=1), ways - 1)
        j = jnp.where(x == -2, -1, j)
        shifted = jnp.concatenate([x[:, None], stk[:, :-1]], axis=1)
        return jnp.where(jnp.arange(ways)[None, :] <= j[:, None],
                         shifted, stk)

    def compose(older, newer):
        # the scatter of the numpy form recast as a gather (XLA-
        # friendly): out[:, w] takes older's unique source column with
        # keep & tgt == w, else newer[:, w]
        ways = newer.shape[1]
        nb = (newer >= 0).sum(axis=1)
        in_newer = (older[:, :, None] == newer[:, None, :]).any(axis=2)
        keep = (older >= 0) & ~in_newer
        tgt = nb[:, None] + jnp.cumsum(keep, axis=1) - 1
        sel = keep & (tgt < ways)
        hitm = sel[:, None, :] & (tgt[:, None, :]
                                  == jnp.arange(ways)[None, :, None])
        has = hitm.any(axis=2)
        src = jnp.argmax(hitm, axis=2)
        vals = jnp.take_along_axis(older, src, axis=1)
        return jnp.where(has, vals, newer)

    def core(T, seg_grp, seg_first, carried, run):
        W, G = T.shape
        ways = carried.shape[1]
        stk0 = jnp.full((G, ways), -1, T.dtype)
        own = lax.fori_loop(0, W, lambda r, s: insert(s, T[r]), stk0)
        E = jnp.concatenate([own[:1], own[:-1]], axis=0)
        idx = jnp.clip(seg_grp, 0, carried.shape[0] - 1)
        E = jnp.where(seg_first[:, None], carried[idx], E)
        rows = jnp.arange(G)

        def body(c):
            d, E = c
            older = jnp.roll(E, d, axis=0)
            valid = (jnp.roll(seg_grp, d) == seg_grp) & (rows >= d)
            E = jnp.where(valid[:, None], compose(older, E), E)
            return d * 2, E

        _, E = lax.while_loop(lambda c: c[0] < run, body,
                              (jnp.int64(1), E))

        def bodyB(r, c):
            stk, HIT = c
            x = T[r]
            h = (stk == x[:, None]).any(axis=1) & (x != -2)
            return insert(stk, x), HIT.at[r].set(h)

        stk, HIT = lax.fori_loop(
            0, W, bodyB, (E, jnp.zeros((W, G), dtype=bool)))
        return HIT, stk

    return jx.jit(core)


def _pow2(n: int, floor: int = 16) -> int:
    return max(floor, 1 << (max(1, n) - 1).bit_length())


def _nway_core_jax(T, seg_grp, seg_first, carried, max_run):
    """Pad to power-of-two shapes (bounding recompiles) and run the
    jitted core; padding segments are inert (tag −2 rows, distinct
    negative segment ids, never set-first)."""
    global _nway_jit
    if _nway_jit is None:
        _nway_jit = _build_nway_jit()
    W, G = T.shape
    ways = carried.shape[1]
    Gp = _pow2(G)
    Cp = _pow2(len(carried), 1)
    if Gp != G:
        Tp = np.full((W, Gp), -2, dtype=T.dtype)
        Tp[:, :G] = T
        sg = np.empty(Gp, dtype=seg_grp.dtype)
        sg[:G] = seg_grp
        sg[G:] = -np.arange(1, Gp - G + 1, dtype=seg_grp.dtype)
        sf = np.zeros(Gp, dtype=bool)
        sf[:G] = seg_first
    else:
        Tp, sg, sf = T, seg_grp, seg_first
    if Cp != len(carried):
        cp = np.full((Cp, ways), -1, dtype=carried.dtype)
        cp[:len(carried)] = carried
    else:
        cp = carried
    with _x64():
        HIT, stk = _nway_jit(Tp, sg, sf, cp, max_run)
    return np.asarray(HIT)[:, :G], np.asarray(stk)[:G]


def nway_core(T: np.ndarray, seg_grp: np.ndarray, seg_first: np.ndarray,
              carried: np.ndarray, max_run: int,
              ) -> tuple[np.ndarray, np.ndarray]:
    """The segmented N-way LRU replay over pre-cut segments.

    ``T`` is ``(W, G)``: per-segment tag columns, −2 where inactive;
    ``seg_grp`` maps each segment to its touched-set row in ``carried``
    (the incoming recency stacks, MRU first); ``seg_first`` flags each
    set's first segment; ``max_run`` is the longest per-set segment
    run.  Returns ``(HIT, final)`` — per-position hit flags and each
    segment's outgoing stack (the caller keeps only each set's last).

    Backends are bit-identical: the jax path runs the same pass A /
    segmented-compose / pass B algorithm under ``jit`` (integer
    compares and shifts only).
    """
    G = T.shape[1]
    if current() == "jax" and (G >= JIT_MIN_SEGMENTS or _explicit()):
        return _nway_core_jax(T, seg_grp, seg_first, carried, max_run)
    return _nway_core_np(T, seg_grp, seg_first, carried, max_run)


# ---------------------------------------------------------------------------
# Pallas (GPU/TPU only; the CPU path never reaches this)
# ---------------------------------------------------------------------------

def pallas_running_max(x, block: int = 1024, interpret: bool = False):
    """Blocked inclusive running max as a Pallas grid kernel.

    Grid steps execute in order on TPU (and per-core on GPU), so the
    carry — the running max of all earlier blocks — lives in a one-cell
    scratch accumulator; each step scans its block with an associative
    scan and folds the carry in.  This is the monoid-scan shape the
    whole engine is built on, lowered to the accelerator the paper
    targets.  ``interpret=True`` runs the kernel on CPU for tests.
    """
    jx, jnp, lax = jax_modules()
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    n = x.shape[0]
    nb = -(-n // block)

    def kernel(x_ref, o_ref, carry_ref):
        i = pl.program_id(0)
        scanned = lax.associative_scan(jnp.maximum, x_ref[...])

        @pl.when(i == 0)
        def _seed():
            o_ref[...] = scanned
            carry_ref[0] = scanned[-1]

        @pl.when(i != 0)
        def _fold():
            out = jnp.maximum(scanned, carry_ref[0])
            o_ref[...] = out
            carry_ref[0] = out[-1]

    with _x64():
        # padding blocks run after every real one, so their carry
        # never reaches a kept output — any fill value works
        xp = jnp.pad(jnp.asarray(x), (0, nb * block - n))
        out = pl.pallas_call(
            kernel,
            grid=(nb,),
            in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i: (i,)),
            out_shape=jx.ShapeDtypeStruct((nb * block,), x.dtype),
            scratch_shapes=[pltpu.SMEM((1,), x.dtype)],
            interpret=interpret,
        )(xp)
        return np.asarray(out[:n])
